"""Sharded KV arenas + head-parallel kernel wrappers (DESIGN.md §13).

Three gates, in order of strength:

1. Partition-rule coverage: every attention-paged zoo config maps its
   block-arena leaves to structurally valid PartitionSpecs in every
   mode — Hkv-divisible (heads), Hkv-non-divisible-but-Dh-divisible
   (Dh fallback), and neither (replicate).  Pure-function tests; run
   on a single device.
2. Kernel-level bitwise identity: with a >1 'model' mesh configured,
   the shard_map paged/fused wrappers return EXACTLY the single-device
   result (head-parallel attention has no cross-head reduction, so no
   collective and no reduction-order drift).  Needs >= 2 devices —
   skipped unless ``XLA_FLAGS=--xla_force_host_platform_device_count``
   provides them (the CI ``multidevice`` job; EXPERIMENTS.md).
3. Engine-level token identity: a ``shard_engine``'d ServingEngine
   serves token-identically to the plain engine over flat and chained
   prefixes — f32/XLA (GSPMD-sharded gather path) and bf16/Pallas
   (shard_map kernel path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.data.tokenizer import Tokenizer
from repro.distributed import kv_sharding as KS
from repro.kernels import ops as kops
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.engine import Request, ServingEngine

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count>=2")


# ----------------------------------------------------------------------
# 1. partition rules (single device; FakeMesh drives the pure functions)
# ----------------------------------------------------------------------
class FakeMesh:
    axis_names = ("data", "model")

    def __init__(self, nm):
        self.shape = {"data": 1, "model": nm}


def _paged_cfgs():
    """Zoo configs whose stacks the paged arena covers (attention-only,
    no cross-attention)."""
    out = []
    for arch in R.ASSIGNED_ARCHS:
        cfg = R.get_config(arch)
        try:
            jax.eval_shape(lambda c=cfg: M.init_block_arena(c, 2, 8))
        except ValueError:
            continue
        out.append(arch)
    return out


PAGED_ARCHS = _paged_cfgs()


@pytest.mark.parametrize("arch", PAGED_ARCHS)
@pytest.mark.parametrize("nm", [2, 4, 8, 16])
def test_arena_pspecs_zoo(arch, nm):
    """Every paged zoo config gets structurally valid arena specs: the
    'model' axis lands on Hkv (heads mode) or Dh (fallback) only when
    it divides, positions always replicate, and every spec's rank
    matches its leaf."""
    cfg = R.get_config(arch)
    mesh = FakeMesh(nm)
    mode = KS.kv_shard_mode(cfg, mesh)
    if cfg.num_kv_heads % nm == 0:
        assert mode == "heads"
    elif cfg.head_dim_ % nm == 0:
        assert mode == "dh"
    else:
        assert mode == "replicate"
    arena = jax.eval_shape(lambda: M.init_block_arena(cfg, 4, 16))
    specs = jax.tree_util.tree_map_with_path(
        lambda p, x: KS.arena_leaf_spec(
            getattr(p[-1], "key", None), x.shape, cfg, mesh), arena)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    assert flat
    for kp, spec in flat:
        key = getattr(kp[-1], "key", None)
        if key == "pos":
            assert all(s is None for s in spec)
        elif mode == "heads":
            assert spec[-2] == "model" and spec[-1] is None
        elif mode == "dh":
            assert spec[-1] == "model" and spec[-2] is None
        else:
            assert all(s is None for s in spec)


def test_big_configs_shard_heads_on_production_width():
    """The ISSUE's named big configs all run heads mode on an 8-wide
    model axis (Hkv = 8 across the board)."""
    for arch in ("mixtral-8x22b", "arctic-480b", "command-r-35b"):
        if arch not in R.ASSIGNED_ARCHS:
            continue
        cfg = R.get_config(arch)
        assert KS.kv_shard_mode(cfg, FakeMesh(8)) == "heads", arch


def test_quantized_scale_leaves_shard_with_heads():
    """qarena scale leaves [NB, Hkv] carry 'model' on their head dim in
    heads mode and replicate otherwise."""
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=64, dtype="float32")
    heads = KS.arena_leaf_spec("k_scale", (8, 2), cfg, FakeMesh(2))
    assert tuple(heads) == (None, "model")
    # Hkv=2 on a 4-wide axis: Dh fallback — scales replicate
    assert KS.kv_shard_mode(cfg, FakeMesh(4)) == "dh"
    rep = KS.arena_leaf_spec("k_scale", (8, 2), cfg, FakeMesh(4))
    assert all(s is None for s in rep)


# ----------------------------------------------------------------------
# 2. kernel-level bitwise identity under shard_map
# ----------------------------------------------------------------------
def _mesh2():
    return jax.make_mesh((1, 2), ("data", "model"))


def _kernel_case(seed=0, b=3, hq=4, hkv=2, d=16, bs=8, nb=12):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    k = jax.random.normal(ks[0], (nb, hkv, bs, d))
    v = jax.random.normal(ks[1], (nb, hkv, bs, d))
    npb = 4
    k_pos = jnp.arange(nb * bs).reshape(nb, bs) % (npb * bs)
    k_pos = jnp.where(jnp.arange(nb)[:, None] == 0, -1, k_pos)
    pt = jnp.asarray(
        np.random.default_rng(seed).integers(1, nb, size=(b, npb)),
        jnp.int32)
    tq = 8
    q = jax.random.normal(ks[2], (b, hq, tq, d))
    q_pos = npb * bs + jnp.broadcast_to(jnp.arange(tq), (b, tq))
    return q, k, v, q_pos, k_pos, pt


@multidevice
def test_paged_partial_bitwise_under_mesh():
    q, k, v, q_pos, k_pos, pt = _kernel_case()
    base = kops.paged_attention_partial(q, k, v, q_pos, k_pos, pt)
    kops.configure_mesh(_mesh2())
    try:
        got = kops.paged_attention_partial(q, k, v, q_pos, k_pos, pt)
    finally:
        kops.configure_mesh(None)
    for a, b_ in zip(base, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


@multidevice
def test_paged_decode_bitwise_under_mesh():
    q, k, v, q_pos, k_pos, pt = _kernel_case()
    qd, qdp = q[:, :, 0], q_pos[:, 0]
    base = kops.paged_decode_gqa(qd, k, v, qdp, k_pos, pt)
    basep = kops.paged_decode_gqa_partial(qd, k, v, qdp, k_pos, pt)
    kops.configure_mesh(_mesh2())
    try:
        got = kops.paged_decode_gqa(qd, k, v, qdp, k_pos, pt)
        gotp = kops.paged_decode_gqa_partial(qd, k, v, qdp, k_pos, pt)
    finally:
        kops.configure_mesh(None)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))
    for a, b_ in zip(basep, gotp):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


@multidevice
@pytest.mark.parametrize("quantized", [False, True], ids=["f32", "int8"])
def test_fused_cascade_bitwise_under_mesh(quantized):
    q, pk, pv, q_pos, p_kpos, ppt = _kernel_case(seed=1)
    _, sk, sv, _, s_kpos, spt = _kernel_case(seed=2)
    ks = vs = None
    if quantized:
        amax = jnp.max(jnp.abs(pk), axis=(2, 3))
        ks = jnp.where(amax > 0, amax / 127.0, 1.0)
        vs = jnp.ones_like(ks)
        pk = jnp.clip(jnp.round(pk / ks[..., None, None]),
                      -127, 127).astype(jnp.int8)
        pv = jnp.clip(jnp.round(pv), -127, 127).astype(jnp.int8)
        # kernel expects scales [NB, Hkv]
        ks, vs = ks, vs
    args = (q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos, ppt, spt, ks, vs)
    base = kops.fused_paged_attention(*args)
    based = kops.fused_paged_decode_gqa(q[:, :, 0], pk, pv, sk, sv,
                                        q_pos[:, 0], p_kpos, s_kpos,
                                        ppt, spt, ks, vs)
    kops.configure_mesh(_mesh2())
    try:
        got = kops.fused_paged_attention(*args)
        gotd = kops.fused_paged_decode_gqa(q[:, :, 0], pk, pv, sk, sv,
                                           q_pos[:, 0], p_kpos, s_kpos,
                                           ppt, spt, ks, vs)
    finally:
        kops.configure_mesh(None)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))
    np.testing.assert_array_equal(np.asarray(based), np.asarray(gotd))


@multidevice
def test_nondivisible_heads_fall_through():
    """Hkv=3 on a 2-wide model axis: the wrappers must take the plain
    path (no shard_map) and still agree with themselves."""
    q, k, v, q_pos, k_pos, pt = _kernel_case(hq=6, hkv=3)
    base = kops.paged_attention_partial(q, k, v, q_pos, k_pos, pt)
    kops.configure_mesh(_mesh2())
    try:
        assert kops._model_shards(3) == 0
        got = kops.paged_attention_partial(q, k, v, q_pos, k_pos, pt)
    finally:
        kops.configure_mesh(None)
    for a, b_ in zip(base, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


# ----------------------------------------------------------------------
# 3. engine-level token identity
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def tok():
    return Tokenizer.train(["the quick brown fox jumps over the lazy dog "
                            "a graph of nodes and edges answers questions"])


def _cfg(vocab, dtype="float32", impl="xla"):
    return ModelConfig(name="shard-test", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=vocab, dtype=dtype,
                       attention_impl=impl)


def _serve_all(eng, tok):
    t0 = tok.encode("a graph of nodes and edges", bos=True)
    t1 = tok.encode("the quick brown fox jumps over the lazy dog")
    sfx = [tok.encode("answers questions"), tok.encode("and edges"),
           tok.encode("the quick")]
    flat, _ = eng.prefill_prefix(t0 + t1, _record=False)
    root, _ = eng.prefill_prefix(t0, _record=False)
    leaf, _ = eng.prefill_prefix_extension(root, t1, _record=False)
    out_flat, t = eng.serve([Request(s, flat) for s in sfx],
                            _record=False)
    assert t["paged"]
    out_tree, _ = eng.serve([Request(s, leaf) for s in sfx],
                            _record=False)
    for st in (leaf, root, flat):
        st.release()
    return out_flat, out_tree


@multidevice
@pytest.mark.parametrize("dtype,impl", [("float32", "xla"),
                                        ("bfloat16", "pallas")])
def test_sharded_engine_token_identity(tok, dtype, impl):
    """THE tentpole-(a) gate: an engine whose arenas are sharded over a
    2-wide model axis serves token-identically to the single-device
    engine — f32/XLA (GSPMD gathers the sharded arena) and bf16/Pallas
    (shard_map walks per-device head slices)."""
    cfg = _cfg(tok.vocab_size, dtype, impl)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    plain = ServingEngine(params, cfg, tok, max_cache_len=256,
                          max_new_tokens=5)
    base = _serve_all(plain, tok)
    mesh = _mesh2()
    sharded = ServingEngine(params, cfg, tok, max_cache_len=256,
                            max_new_tokens=5)
    try:
        mode = KS.shard_engine(sharded, mesh)
        assert mode == "heads"
        k_leaf = jax.tree_util.tree_leaves(
            sharded.block_pool.arena)[0]
        assert len(k_leaf.sharding.device_set) == 2
        got = _serve_all(sharded, tok)
    finally:
        kops.configure_mesh(None)
    assert got == base


# ----------------------------------------------------------------------
# 4. tensor-parallel x replica-router composition (ROADMAP known debt:
#    previously composed "only by construction, not yet by a test")
# ----------------------------------------------------------------------
@multidevice
@pytest.mark.parametrize("mode", ["drain", "continuous"])
def test_sharded_replicas_token_identical_to_oracle(mode):
    """Every replica of a 2-replica router runs with its KV arenas
    sharded over the 'model' mesh axis, and the routed trace stays
    token-identical to the UNSHARDED 1-replica drain oracle — the two
    scale-out mechanisms (tensor-parallel arenas within an engine,
    cluster-affinity routing across engines) compose without touching
    the math."""
    from repro.data.scenegraph import generate_scene_graph
    from repro.rag.pipeline import GraphRAGPipeline
    from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
    from repro.rag.text_encoder import TextEncoder
    from repro.serving.router import ReplicaRouter

    graph, queries = generate_scene_graph()
    tok2 = Tokenizer.train([q.question + " " + q.answer
                            for q in queries] + graph.node_text,
                           max_vocab=2048)
    cfg = ModelConfig(name="tp-replica", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=tok2.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(32))
    pipe = GraphRAGPipeline(
        index=index, retriever=GRetrieverRetriever(index),
        engine=ServingEngine(params, cfg, tok2, max_cache_len=512,
                             max_new_tokens=3),
        tokenizer=tok2, use_soft_prompt=False)
    items = queries[:8]
    arrivals = [0.0, 0.0, 0.1, 0.1, 0.2, 5.0, 5.0, 5.1]
    oracle, _, _ = pipe.serve_stream(items, arrivals, max_batch=4,
                                     threshold=0.25, mode="drain",
                                     pool_budget_bytes=1 << 26)

    # build the router FIRST so every replica (the reused engine AND
    # the clone) can be sharded before any routed serving traces a jit
    assigner = pipe._make_assigner(items, 0.25, None, 1, None)
    router = ReplicaRouter.build(
        pipe.engine, assigner, 2, pool_budget_bytes=1 << 26,
        prefix_tokens_fn=pipe._prefix_payload,
        segment_tokens_fn=pipe._segment_payload)
    mesh = _mesh2()
    try:
        for r in router.replicas:
            smode = KS.shard_engine(r.engine, mesh)
            assert smode == "heads"
            leaf = jax.tree_util.tree_leaves(r.engine.block_pool.arena)[0]
            assert len(leaf.sharding.device_set) == 2
        recs, summary, router2 = pipe.serve_stream(
            items, arrivals, max_batch=4, threshold=0.25, mode=mode,
            pool_budget_bytes=1 << 26, replicas=2, scheduler=router)
    finally:
        kops.configure_mesh(None)
    assert router2 is router
    assert [r.generated for r in recs] == [r.generated for r in oracle]
    assert sum(r.routed for r in router.replicas) == len(items)
    assert all(r.load == 0 for r in router.replicas)
