"""Replica serving cluster (DESIGN.md §13): cluster-affinity routing,
least-loaded spawn placement, hot-replica rebalancing via the host
round-trip migration path, byte-gauge reconciliation across a
migration, and end-to-end token identity of ``serve_stream(replicas=N)``
against the single-replica drain oracle."""
import types

import jax
import numpy as np
import pytest

from repro.core.cache import CacheStats
from repro.core.prefix_pool import state_bytes
from repro.core.subgraph import Subgraph
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.engine import ServingEngine
from repro.serving.metrics import router_report
from repro.serving.router import Replica, ReplicaRouter
from repro.serving.scheduler import OnlineClusterAssigner


def _sg(i):
    return Subgraph.from_lists([i], [])


def _stub_replica(idx):
    eng = types.SimpleNamespace(
        cache_mgr=types.SimpleNamespace(stats=CacheStats()))
    return Replica(idx=idx, engine=eng, scheduler=None)


def _policy_router(n=3, threshold=1.0):
    return ReplicaRouter([_stub_replica(i) for i in range(n)],
                         OnlineClusterAssigner(threshold=threshold))


# ----------------------------------------------------------------------
# placement policy (no engines)
# ----------------------------------------------------------------------
def test_affinity_stickiness_and_least_loaded_spawn():
    """New clusters spread round-robin over equally-loaded replicas;
    every later member of a cluster routes to ITS replica no matter how
    loads shift (the prefix chain lives there and nowhere else)."""
    router = _policy_router(n=3)
    a = np.array([0.0, 0.0])
    b = np.array([10.0, 0.0])
    c = np.array([0.0, 10.0])
    ra = router.route(a, _sg(0))
    rb = router.route(b, _sg(1))
    rc = router.route(c, _sg(2))
    assert ra.assignment.is_new and rb.assignment.is_new \
        and rc.assignment.is_new
    # three cold spawns spread over three idle replicas
    assert {ra.replica, rb.replica, rc.replica} == {0, 1, 2}

    # members stick to their cluster's replica even when it is the
    # most loaded one by far
    for _ in range(6):
        r = router.route(a + 0.01, _sg(0))
        assert r.replica == ra.replica
        assert not r.assignment.is_new
    assert router.replicas[ra.replica].load == 7
    assert router.affinity_hit_rate(ra.replica) == pytest.approx(6 / 7)
    # a fresh cluster avoids the hot replica (least-loaded spawn)
    rd = router.route(np.array([10.0, 10.0]), _sg(3))
    assert rd.replica != ra.replica


def test_retire_balances_load_accounting():
    router = _policy_router(n=2)
    r = router.route(np.array([0.0, 0.0]), _sg(0))
    assert router.replicas[r.replica].load == 1
    assert router.pending[r.assignment.cluster_id] == 1
    router.retire(r.replica, r.assignment.cluster_id)
    assert router.replicas[r.replica].load == 0
    assert r.assignment.cluster_id not in router.pending


def test_rebalance_moves_colocated_cluster_off_hot_replica():
    """The rebalance candidate is a CO-LOCATED cluster with a DRAINED
    queue (migration redirects future arrivals only — a backlogged
    cluster would leave its queries behind while taking its resident
    prefix with it), never the hot cluster itself (its traffic share
    is over the cap; moving it would swap which replica is hot)."""
    router = _policy_router(n=2)
    hot = np.array([0.0, 0.0])
    cold = np.array([10.0, 0.0])
    r_hot = router.route(hot, _sg(0))
    assert r_hot.replica == 0
    # force co-location: the cold cluster spawns on replica 1
    # (round-robin), so re-pin it onto replica 0 for the scenario
    r_cold = router.route(cold, _sg(1))
    cid_hot, cid_cold = (r_hot.assignment.cluster_id,
                         r_cold.assignment.cluster_id)
    router.placement[cid_cold] = 0
    router.replicas[r_cold.replica].routed -= 1
    router.replicas[0].routed += 1
    for _ in range(7):
        router.route(hot + 0.01, _sg(0))
    for _ in range(3):
        assert router.route(cold + 0.01, _sg(1)).replica == 0
    # a third cluster keeps replica 1 NON-idle (an idle coldest replica
    # means the fleet is draining — rebalancing then only thrashes)
    r3 = router.route(np.array([0.0, 10.0]), _sg(2))
    assert r3.replica == 1
    router.route(np.array([0.0, 10.0]) + 0.01, _sg(2))

    moves = []
    router.migrate = lambda cid, s, d: moves.append((cid, s, d))
    # the cold cluster still has queries queued -> NOT movable yet
    assert router.maybe_rebalance() is None
    # its queue drains; the hot cluster stays backlogged
    router.retire(0, cid_cold, n=4)
    router.replicas[0].routed += 4      # keep replica 0 the hot one
    moved = router.maybe_rebalance()
    # loads: replica0 = 12, replica1 = 2 -> hot; candidates need
    # pending == 0 and routed <= half the hot replica's traffic:
    # cold (routed 4, drained) fits, hot (routed 8, backlogged) never
    assert moved == cid_cold
    assert moves == [(cid_cold, 0, 1)]
    assert cid_hot != cid_cold
    # one move per cluster per run: the same candidate never ping-pongs
    assert router.maybe_rebalance() is None
    router.reset_counters()
    assert not router._migrated
    assert not router.cluster_routed


def test_rebalance_noop_when_balanced():
    router = _policy_router(n=2)
    a, b = np.array([0.0, 0.0]), np.array([10.0, 0.0])
    router.route(a, _sg(0))
    router.route(b, _sg(1))
    assert router.maybe_rebalance() is None
    assert router.migrations == 0


# ----------------------------------------------------------------------
# migration over real engines: gauges reconciled, tokens unchanged
# ----------------------------------------------------------------------
def _cfg(vocab, dtype="float32", impl="xla"):
    return ModelConfig(name="router-t", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=vocab,
                       dtype=dtype, attention_impl=impl)


@pytest.fixture(scope="module")
def tok():
    return Tokenizer.train(["the quick brown fox jumps over the lazy dog "
                            "a graph of nodes and edges answers questions"])


def _check_replica_invariants(rep):
    """The PoolMachine invariants (tests/test_pool_properties.py),
    applied to one replica's pool/tier/stats stack."""
    pool = rep.scheduler.pool
    bp = rep.engine.block_pool
    assert pool.bytes_in_use == sum(
        state_bytes(pool.entry(k).state) for k in pool.keys)
    if pool.tier is not None:
        assert pool.tier.bytes_in_use == sum(
            pool.tier.peek(k).nbytes for k in pool.tier.keys())
    st = rep.stats
    st.record_blocks(bp)
    assert st.block_bytes_in_use == (bp.prefix_blocks_in_use
                                     * bp.prefix_block_bytes)
    if pool.tier is not None:
        st.record_host(pool.tier)
        assert st.host_bytes_in_use == pool.tier.bytes_in_use


@pytest.mark.parametrize("quantize", [False, True])
def test_migration_reconciles_gauges_and_keeps_tokens(tok, quantize):
    """Migrate a cluster between two real replicas: the source frees
    its device blocks, the segment lands in the DESTINATION host tier,
    pool/tier/CacheStats byte gauges stay reconciled on both sides, and
    the cluster's next query — now served by the destination through a
    lazy promotion — produces the SAME tokens it produced on the
    source."""
    cfg = _cfg(tok.vocab_size)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, tok, max_cache_len=512,
                        max_new_tokens=4, quantize_prefix=quantize)
    reps = {0: tok.encode("a graph of nodes and edges", bos=True),
            1: tok.encode("the quick brown fox", bos=True)}
    router = ReplicaRouter.build(
        eng, OnlineClusterAssigner(threshold=1.0), 2,
        pool_budget_bytes=1 << 30,
        prefix_tokens_fn=lambda sg: reps[min(sg.nodes)])
    emb = {0: np.array([0.0, 0.0]), 1: np.array([10.0, 0.0])}
    sfx = tok.encode("answers questions")

    rt = router.route(emb[0], _sg(0))
    cid = rt.assignment.cluster_id
    src = router.replicas[rt.replica]
    served = src.scheduler.serve_batch([emb[0]], [_sg(0)], [sfx],
                                       assignments=[rt.assignment])
    router.retire(rt.replica, cid)
    tokens_before = served[0].tokens
    assert cid in src.scheduler.pool
    for rep in router.replicas:
        _check_replica_invariants(rep)

    dst = router.replicas[1 - rt.replica]
    moved = router.migrate(cid, src.idx, dst.idx)
    assert moved == 1
    assert router.placement[cid] == dst.idx
    # source: entry gone, device blocks freed, nothing left hosted
    assert cid not in src.scheduler.pool
    assert src.engine.block_pool.blocks_in_use == 0
    assert len(src.scheduler.pool.tier) == 0
    # destination: the segment is host-resident, not yet on device
    assert dst.scheduler.pool.tier.peek(cid) is not None
    assert cid not in dst.scheduler.pool
    assert src.stats.migrations_out == 1 and dst.stats.migrations_in == 1
    for rep in router.replicas:
        _check_replica_invariants(rep)

    # the next member routes to the destination (affinity follows the
    # placement) and is served from a host-tier promotion — same tokens
    rt2 = router.route(emb[0] + 0.01, _sg(0))
    assert rt2.replica == dst.idx and not rt2.assignment.is_new
    served2 = dst.scheduler.serve_batch([emb[0]], [_sg(0)], [sfx],
                                        assignments=[rt2.assignment])
    router.retire(rt2.replica, cid)
    assert served2[0].tokens == tokens_before
    assert served2[0].pool_hit            # promotion counts as a hit
    assert dst.stats.tier_promotions == 1
    assert dst.stats.pool_reprefills == 0  # promoted, never recomputed
    for rep in router.replicas:
        _check_replica_invariants(rep)


def test_migration_skips_pinned_segments(tok):
    """A pinned (in-flight) segment refuses to demote: the migration
    moves the placement but hands over nothing — the destination will
    recompute through the ordinary miss path."""
    cfg = _cfg(tok.vocab_size)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    eng = ServingEngine(params, cfg, tok, max_cache_len=512,
                        max_new_tokens=3)
    reps = {0: tok.encode("a graph of nodes", bos=True)}
    router = ReplicaRouter.build(
        eng, OnlineClusterAssigner(threshold=1.0), 2,
        pool_budget_bytes=1 << 30,
        prefix_tokens_fn=lambda sg: reps[min(sg.nodes)])
    rt = router.route(np.array([0.0, 0.0]), _sg(0))
    cid = rt.assignment.cluster_id
    src = router.replicas[rt.replica]
    src.scheduler.serve_batch([np.array([0.0, 0.0])], [_sg(0)],
                              [tok.encode("answers")],
                              assignments=[rt.assignment])
    src.scheduler.pool.pin(cid)           # an in-flight row holds it
    moved = router.migrate(cid, src.idx, 1 - src.idx)
    assert moved == 0
    assert cid in src.scheduler.pool      # untouched on the source
    assert router.placement[cid] == 1 - src.idx
    src.scheduler.pool.release(cid)


# ----------------------------------------------------------------------
# end-to-end: serve_stream(replicas=N) vs the single-replica oracle
# ----------------------------------------------------------------------
def _stream_pipe():
    from repro.data.scenegraph import generate_scene_graph
    from repro.rag.pipeline import GraphRAGPipeline
    from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
    from repro.rag.text_encoder import TextEncoder

    graph, queries = generate_scene_graph()
    tok2 = Tokenizer.train([q.question + " " + q.answer
                            for q in queries] + graph.node_text,
                           max_vocab=2048)
    cfg = ModelConfig(name="router-stream", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=tok2.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(32))
    pipe = GraphRAGPipeline(
        index=index, retriever=GRetrieverRetriever(index),
        engine=ServingEngine(params, cfg, tok2, max_cache_len=512,
                             max_new_tokens=3),
        tokenizer=tok2, use_soft_prompt=False)
    return pipe, queries


@pytest.mark.parametrize("mode", ["drain", "continuous"])
def test_serve_stream_replicas_token_identical_to_oracle(mode):
    """Every query's token stream through 2 routed replicas matches the
    single-replica drain oracle — placement only decides WHERE a prefix
    is resident, and the shared assigner sees arrivals in the same
    global order either way."""
    pipe, queries = _stream_pipe()
    items = queries[:8]
    arrivals = [0.0, 0.0, 0.1, 0.1, 0.2, 5.0, 5.0, 5.1]
    oracle, _, _ = pipe.serve_stream(items, arrivals, max_batch=4,
                                     threshold=0.25, mode="drain",
                                     pool_budget_bytes=1 << 26)
    recs, summary, router = pipe.serve_stream(
        items, arrivals, max_batch=4, threshold=0.25, mode=mode,
        pool_budget_bytes=1 << 26, replicas=2)
    assert [r.generated for r in recs] == [r.generated for r in oracle]
    assert all(r.replica in (0, 1) for r in recs)
    assert summary.num_queries == len(items)
    assert all(r.queue_wait_s >= 0 for r in recs)
    # the router accounted every query exactly once, and drained
    assert sum(r.routed for r in router.replicas) == len(items)
    assert all(r.load == 0 for r in router.replicas)
    assert router.makespan > 0.0
    rep = router_report(router, recs)
    assert rep["num_replicas"] == 2
    assert set(rep["replicas"]) == {"0", "1"}
    for row in rep["replicas"].values():
        assert 0.0 <= row["affinity_hit_rate"] <= 1.0
    assert rep["clusters"] == len(router.placement)
    # trace_summary grows the per-replica breakdown for routed traces
    from repro.serving.metrics import trace_summary
    ts = trace_summary(recs)
    assert "replicas" in ts


def test_serve_stream_replicas_warm_router_replay():
    """A returned router replays warm through the ``scheduler`` slot:
    same engines, kept placements, fresh counters, and — with the
    cluster population already spawned — pure affinity routing."""
    pipe, queries = _stream_pipe()
    items = queries[:6]
    arrivals = [0.0, 0.0, 0.1, 0.1, 0.2, 0.2]
    recs, _, router = pipe.serve_stream(
        items, arrivals, max_batch=4, threshold=0.25, mode="drain",
        pool_budget_bytes=1 << 26, replicas=2)
    engines = [id(r.engine) for r in router.replicas]
    recs2, _, router2 = pipe.serve_stream(
        items, arrivals, max_batch=4, threshold=0.25, mode="drain",
        pool_budget_bytes=1 << 26, replicas=2, scheduler=router)
    assert router2 is router
    assert [id(r.engine) for r in router2.replicas] == engines
    # NOTE: no token-identity claim here — the warm assigner keeps its
    # drifted centroids, so a replayed query may legally land in a
    # different (drifted) cluster than on the cold run.  Token identity
    # is a COLD-run property (previous test); warm replay exists for
    # timing (jit caches + placements stay hot).
    assert len(recs2) == len(recs)
    assert all(r.generated is not None for r in recs2)
    assert sum(r.routed for r in router2.replicas) == len(items)
    assert all(r.load == 0 for r in router2.replicas)
    # the cold run's cluster population is still placed
    assert len(router2.placement) > 0
