"""Hierarchical prefix trees (DESIGN.md §10): dendrogram cut replay,
token-prefix stability of chain textualization, N-segment cascade
exactness vs the flat concatenated prefix (drain + continuous, paged +
dense), tree-aware pool eviction (leaf before ancestor), and ancestor
reuse after a leaf eviction."""
import math
import random

import jax
import numpy as np
import pytest

from repro.core.clustering import (LINKAGES, build_dendrogram,
                                   hierarchical_clustering)
from repro.core.planner import plan_batch, plan_prefix_tree
from repro.core.prefix_pool import PrefixPool, state_bytes
from repro.core.subgraph import (Subgraph, intersect_subgraphs,
                                 merge_subgraphs, textualize,
                                 textualize_delta)
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request, ServingEngine


# ----------------------------------------------------------------------
# dendrogram: one agglomeration, many cuts
# ----------------------------------------------------------------------
def _legacy_clustering(embeddings, num_clusters, linkage="ward"):
    """The pre-refactor one-shot loop, kept verbatim as the oracle: the
    dendrogram cut must reproduce its labels byte-for-byte."""
    x = np.asarray(embeddings, dtype=np.float64)
    m = x.shape[0]
    num_clusters = max(1, min(num_clusters, m))
    n2 = np.sum(x * x, axis=1)
    d = n2[:, None] + n2[None, :] - 2.0 * (x @ x.T)
    np.fill_diagonal(d, np.inf)
    d = np.maximum(d, 0.0)
    if linkage in ("single", "complete", "average"):
        d = np.sqrt(np.where(np.isfinite(d), d, np.inf))
        np.fill_diagonal(d, np.inf)
    active = list(range(m))
    size = np.ones(m)
    members = [[i] for i in range(m)]
    while len(active) > num_clusters:
        sub = d[np.ix_(active, active)]
        ai, aj = np.unravel_index(np.argmin(sub), sub.shape)
        i, j = active[ai], active[aj]
        if i > j:
            i, j = j, i
        ni, nj, dij = size[i], size[j], d[i, j]
        for k in active:
            if k in (i, j):
                continue
            dik, djk, nk = d[i, k], d[j, k], size[k]
            if linkage == "single":
                new = min(dik, djk)
            elif linkage == "complete":
                new = max(dik, djk)
            elif linkage == "average":
                new = (ni * dik + nj * djk) / (ni + nj)
            elif linkage == "centroid":
                new = ((ni * dik + nj * djk) / (ni + nj)
                       - ni * nj * dij / (ni + nj) ** 2)
            else:
                new = ((ni + nk) * dik + (nj + nk) * djk - nk * dij) \
                    / (ni + nj + nk)
            d[i, k] = d[k, i] = new
        size[i] = ni + nj
        members[i] = members[i] + members[j]
        active.remove(j)
        d[j, :] = np.inf
        d[:, j] = np.inf
    labels = np.zeros(m, dtype=np.int64)
    for c, root in enumerate(active):
        for idx in members[root]:
            labels[idx] = c
    return labels


@pytest.mark.parametrize("linkage", LINKAGES)
def test_dendrogram_cut_matches_legacy_labels(linkage):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((19, 4))
    dd = build_dendrogram(x, linkage)
    for k in (1, 2, 3, 5, 11, 19, 30):
        np.testing.assert_array_equal(dd.cut(k),
                                      _legacy_clustering(x, k, linkage))
    np.testing.assert_array_equal(hierarchical_clustering(x, 4, linkage),
                                  dd.cut(4))


def test_dendrogram_cuts_nest():
    """A coarser cut is a coarsening of a finer cut of the SAME
    dendrogram — the property multi-level prefix trees stand on."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((24, 3))
    dd = build_dendrogram(x)
    fine, coarse = dd.cut(8), dd.cut(3)
    parent = {}
    for i in range(24):
        assert parent.setdefault(fine[i], coarse[i]) == coarse[i]


# ----------------------------------------------------------------------
# chain textualization: token-prefix property, order stability
# ----------------------------------------------------------------------
def _chain_text(contents, node_text):
    segs = [textualize_delta(c, node_text,
                             contents[i - 1] if i else None)
            for i, c in enumerate(contents)]
    return "\n".join(segs)


def test_chain_text_is_literal_prefix_and_order_stable():
    """The ancestor's chain text must be a literal string prefix of
    every descendant's, and must not depend on the order members were
    unioned into the representatives (regression: an insertion-order-
    dependent textualization would silently serve wrong attention
    content through a reused ancestor segment)."""
    node_text = [f"w{i}" for i in range(40)]
    members = [Subgraph.from_lists([i, i + 1, 30], [(i, "r", 30)])
               for i in range(8)]
    texts = set()
    for seed in range(5):
        order = list(range(len(members)))
        random.Random(seed).shuffle(order)
        leaf = merge_subgraphs([members[i] for i in order[:4]])
        anc = intersect_subgraphs(
            [leaf, merge_subgraphs([members[i] for i in order[4:]])])
        chain = _chain_text([anc, leaf], node_text)
        assert chain.startswith(textualize_delta(anc, node_text))
        # same CONTENT sets => byte-identical text, any member order
        texts.add(_chain_text(
            [intersect_subgraphs([merge_subgraphs(members[:4]),
                                  merge_subgraphs(members[4:])]),
             merge_subgraphs(members[:4])], node_text))
    assert len(texts) == 1
    # token-level: chain token lists concatenate to the same ids
    tok = Tokenizer.train([" ".join(node_text)])
    anc = intersect_subgraphs([merge_subgraphs(members[:4]),
                               merge_subgraphs(members[4:])])
    leaf = merge_subgraphs(members[:4])
    t_anc = tok.encode(textualize_delta(anc, node_text))
    t_ext = tok.encode(textualize_delta(leaf, node_text, anc))
    t_full = tok.encode(_chain_text([anc, leaf], node_text))
    assert t_anc + t_ext == t_full


def test_textualize_delta_base_none_matches_flat():
    node_text = [f"w{i}" for i in range(10)]
    sg = Subgraph.from_lists([1, 3, 5], [(1, "r", 3), (3, "s", 5)])
    assert textualize_delta(sg, node_text) == textualize(sg, node_text)


def test_plan_prefix_tree_nests_and_preserves_leaves():
    rng = np.random.default_rng(2)
    sgs, emb = [], []
    for c in range(4):
        for _ in range(4):
            nodes = set(range(c * 2, c * 2 + 3)) | {20 + c // 2}
            sgs.append(Subgraph.from_lists(nodes, []))
            emb.append([10.0 * c, 0.0] + 0.05 * rng.standard_normal(2))
    emb = np.asarray(emb)
    plan = plan_prefix_tree(sgs, emb, num_clusters=4, tree_levels=3)
    flat = plan_batch(sgs, emb, 4)
    flat_reps = {tuple(sorted(cp.member_indices)): cp.representative
                 for cp in flat.clusters}
    served = []
    for leaf in plan.leaves:
        node = plan.nodes[leaf]
        served += node.member_indices
        # leaf content == the flat representative (same attention
        # content; only the token order changes)
        rep = flat_reps[tuple(sorted(node.member_indices))]
        assert node.content.nodes == rep.nodes
        assert node.content.edges == rep.edges
        chain = plan.chain(leaf)
        for a, b in zip(chain.contents, chain.contents[1:]):
            assert a.issubset(b) and not a.is_empty
    assert sorted(served) == list(range(len(sgs)))


# ----------------------------------------------------------------------
# N-segment LSE fold (kernel level)
# ----------------------------------------------------------------------
def test_fold_partials_matches_full_softmax():
    from repro.kernels import ops as kops
    from repro.kernels.ref import (attention_partial_ref,
                                   fold_partials_ref,
                                   prefix_attention_ref)
    rng = np.random.default_rng(3)
    b, hq, hkv, tq, s, d = 2, 4, 2, 3, 24, 8
    q = rng.standard_normal((b, hq, tq, d)).astype(np.float32)
    k = rng.standard_normal((b, hkv, s, d)).astype(np.float32)
    v = rng.standard_normal((b, hkv, s, d)).astype(np.float32)
    q_pos = np.tile(np.arange(s - tq, s, dtype=np.int32), (b, 1))
    k_pos = np.tile(np.arange(s, dtype=np.int32), (b, 1))
    full = prefix_attention_ref(q, k, v, q_pos, k_pos, causal=True)
    cuts = [0, 7, 13, s]
    parts = [attention_partial_ref(
        q, k[:, :, a:z], v[:, :, a:z], q_pos, k_pos[:, a:z],
        causal=True) for a, z in zip(cuts, cuts[1:])]
    out, _, _ = fold_partials_ref(parts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                               rtol=1e-5, atol=1e-5)
    out2, m2, l2 = kops.fold_partials([tuple(map(jax.numpy.asarray, p))
                                       for p in parts])
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# engine: chain serving exactness
# ----------------------------------------------------------------------
def _gqa_cfg(vocab, dtype="float32", impl="xla"):
    return ModelConfig(name="tree-test", family="dense", num_layers=3,
                       d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
                       d_ff=160, vocab_size=vocab, dtype=dtype,
                       attention_impl=impl)


@pytest.fixture(scope="module")
def tok():
    return Tokenizer.train(["the quick brown fox jumps over the lazy dog "
                            "a graph of nodes and edges answers questions"])


def _engine(tok, key=0, dtype="float32", impl="xla", **kw):
    cfg = _gqa_cfg(tok.vocab_size, dtype, impl)
    params = M.init_params(jax.random.PRNGKey(key), cfg)
    kw.setdefault("max_cache_len", 512)
    kw.setdefault("max_new_tokens", 5)
    return ServingEngine(params, cfg, tok, **kw)


@pytest.mark.parametrize("dtype,impl", [("float32", "xla"),
                                        ("bfloat16", "pallas")])
def test_chain_serve_token_identical_to_flat_concat(tok, dtype, impl):
    """A 3-segment chain must serve token-identically to flat-prefilling
    the concatenated path — drain (engine.serve) AND continuous
    (chunked decode + staggered admission) modes, including a batch
    mixing chain depths.  Every block reference releases with the
    states (chain pins are per-lifetime, not leaked)."""
    eng = _engine(tok, dtype=dtype, impl=impl)
    t0 = tok.encode("a graph of nodes and edges", bos=True)
    t1 = tok.encode("the quick brown fox jumps over the lazy dog " * 2)
    t2 = tok.encode("answers questions the lazy dog")
    sfx = [tok.encode("answers questions"), tok.encode("and edges"),
           tok.encode("the quick"), tok.encode("lazy dog jumps")]

    flat, _ = eng.prefill_prefix(t0 + t1 + t2, _record=False)
    root, _ = eng.prefill_prefix(t0, _record=False)
    mid, _ = eng.prefill_prefix_extension(root, t1, _record=False)
    leaf, _ = eng.prefill_prefix_extension(mid, t2, _record=False)
    assert leaf.prefix_len == flat.prefix_len
    assert leaf.chain_blocks()[:len(root.page.blocks)] == root.page.blocks

    oracle, t = eng.serve([Request(s, flat) for s in sfx], _record=False)
    assert t["paged"]
    out, _ = eng.serve([Request(s, leaf) for s in sfx], _record=False)
    assert out == oracle
    # mixed depths in one batch: chain leaf + bare root
    mixed, _ = eng.serve([Request(sfx[0], leaf), Request(sfx[1], root)],
                         _record=False)
    assert mixed[0] == oracle[0]

    # continuous: staggered admission against the chain state
    cont = ContinuousEngine(eng, max_slots=4, chunk=2, max_suffix_len=8)
    base = eng.block_pool.blocks_in_use
    cont.admit([Request(sfx[0], leaf), Request(sfx[1], leaf)],
               payloads=[0, 1])
    cont.step()
    cont.admit([Request(sfx[2], leaf), Request(sfx[3], leaf)],
               payloads=[2, 3])
    cont.flush()
    res = {r.payload: r for r in cont.pop_retired()}
    assert [res[i].tokens for i in range(4)] == oracle
    assert eng.block_pool.blocks_in_use == base

    for st in (leaf, mid, root, flat):
        st.release()
    assert eng.block_pool.blocks_in_use == 0


def test_dense_chain_matches_flat_concat(tok):
    """paged=False split cascade: the chain is a tuple of segment
    caches folded by the N-way LSE merge — same tokens as the flat
    concatenated prefix."""
    eng = _engine(tok, paged=False)
    assert eng.use_split_prefix and not eng.use_paged
    t0 = tok.encode("a graph of nodes", bos=True)
    t1 = tok.encode("the quick brown fox jumps")
    sfx = [tok.encode("answers questions"), tok.encode("and edges")]
    flat, _ = eng.prefill_prefix(t0 + t1, _record=False)
    root, _ = eng.prefill_prefix(t0, _record=False)
    leaf, _ = eng.prefill_prefix_extension(root, t1, _record=False)
    oracle, _ = eng.serve([Request(s, flat) for s in sfx], _record=False)
    out, _ = eng.serve([Request(s, leaf) for s in sfx], _record=False)
    assert out == oracle


def test_extension_failure_unwinds_refs(tok):
    """A failed extension prefill (suffix capacity overflow) must drop
    the ancestor increfs it took — no phantom references."""
    eng = _engine(tok)
    root, _ = eng.prefill_prefix(tok.encode("a graph of nodes", bos=True),
                                 _record=False)
    refs = [eng.block_pool.allocator.refcount(b) for b in root.page.blocks]
    with pytest.raises(Exception):
        eng.prefill_prefix_extension(root, [4] * 4096, _record=False)
    assert [eng.block_pool.allocator.refcount(b)
            for b in root.page.blocks] == refs
    root.release()
    assert eng.block_pool.blocks_in_use == 0


# ----------------------------------------------------------------------
# pool: tree-aware eviction
# ----------------------------------------------------------------------
def test_pool_never_evicts_ancestor_before_descendant(tok):
    """An ancestor whose descendant is resident (or pinned in flight)
    must never be an eviction victim, even when its cost score is the
    worst; pressure peels the path leaf-first."""
    eng = _engine(tok)
    root, _ = eng.prefill_prefix(
        tok.encode("the quick brown fox jumps over the lazy dog " * 6,
                   bos=True), _record=False)
    leaf, _ = eng.prefill_prefix_extension(
        root, tok.encode("a graph of nodes"), _record=False)
    pool = PrefixPool(state_bytes(root) + state_bytes(leaf),
                      eng.cache_mgr.stats)
    pool.put("root", root)
    pool.put("leaf", leaf)
    # make the ancestor the WORST-scored entry (old, long, never hit)
    for _ in range(5):
        pool.get("leaf")
    # budget pressure: admit a third state that only fits if one entry
    # goes — the victim must be the leaf, not the root it chains to
    extra, _ = eng.prefill_prefix(tok.encode("answers questions",
                                             bos=True), _record=False)
    pool.put("extra", extra)
    assert "root" in pool and "leaf" not in pool
    # root became a leaf-less entry; under further pressure it IS
    # evictable again (tree order, not immortality)
    pool.budget_bytes = 1
    pool._evict_to_budget()
    assert "root" not in pool
    assert eng.block_pool.blocks_in_use == 0 or True  # released via pool
    extra.release()


def test_leaf_reprefill_reuses_resident_ancestor(tok):
    """After a leaf eviction, re-materializing the chain must reuse the
    still-resident ancestor blocks (extension prefill only — the
    ancestor is neither recomputed nor moved), and the readmission is
    counted as a re-prefill."""
    import dataclasses
    from repro.core.planner import ChainSpec
    from repro.serving.scheduler import (OnlineCluster,
                                         OnlineClusterAssigner,
                                         OnlineScheduler)
    eng = _engine(tok)
    anc_sg = Subgraph.from_lists([0, 1, 2], [])
    leaf_sg = Subgraph.from_lists([0, 1, 2, 3, 4], [])
    assigner = OnlineClusterAssigner()
    assigner.clusters.append(OnlineCluster(
        cluster_id=0, centroid=np.zeros(2), representative=leaf_sg,
        chain=ChainSpec(keys=[10, 11], contents=[anc_sg, leaf_sg])))
    texts = {10: "the quick brown fox jumps over the lazy dog",
             11: "a graph of nodes and edges"}

    def seg_tokens(content, base):
        key = 10 if base is None else 11
        return tok.encode(texts[key], bos=base is None)

    pool = PrefixPool(1 << 30, eng.cache_mgr.stats)
    sched = OnlineScheduler(eng, assigner, pool, lambda sg: [],
                            segment_tokens_fn=seg_tokens)
    st, hit, dt, keys = sched.ensure_chain(0)
    assert not hit and keys == [("seg", 10), ("seg", 11)]
    root = pool.entry(("seg", 10)).state
    root_blocks = list(root.page.blocks)
    stats = eng.cache_mgr.stats
    assert stats.tree_misses == {0: 1, 1: 1}

    # evict ONLY the leaf (tree order guarantees the root survives)
    pool.budget_bytes = state_bytes(root)
    pool._evict_to_budget()
    assert ("seg", 10) in pool and ("seg", 11) not in pool

    pool.budget_bytes = 1 << 30
    st2, hit2, dt2, _ = sched.ensure_chain(0)
    assert not hit2                      # the LEAF was cold again
    assert stats.tree_hits.get(0) == 1   # ...but the ancestor was reused
    assert stats.ancestor_hit_rate == 0.5
    assert pool.entry(("seg", 10)).state is root
    assert st2.ancestor_blocks == root_blocks
    assert stats.pool_reprefills == 1
    # reused ancestor tokens are attributed to level 0
    assert stats.tree_reused_tokens.get(0) == root.segment_len
    pool.clear()
    assert eng.block_pool.blocks_in_use == 0


def test_ensure_chain_failure_drops_partial_pins(tok):
    """A mid-chain failure (here: an extension whose path overflows the
    capacity bucket) must release the pins the walk already took — a
    leaked pin would make the ancestor permanently unevictable."""
    from repro.core.planner import ChainSpec
    from repro.serving.scheduler import (OnlineCluster,
                                         OnlineClusterAssigner,
                                         OnlineScheduler)
    eng = _engine(tok)
    anc_sg = Subgraph.from_lists([0, 1], [])
    leaf_sg = Subgraph.from_lists([0, 1, 2], [])
    assigner = OnlineClusterAssigner()
    assigner.clusters.append(OnlineCluster(
        cluster_id=0, centroid=np.zeros(2), representative=leaf_sg,
        chain=ChainSpec(keys=[10, 11], contents=[anc_sg, leaf_sg])))

    def seg_tokens(content, base):
        if base is None:
            return tok.encode("a graph of nodes", bos=True)
        return [4] * 4096               # leaf extension overflows capacity

    pool = PrefixPool(1 << 30, eng.cache_mgr.stats)
    sched = OnlineScheduler(eng, assigner, pool, lambda sg: [],
                            segment_tokens_fn=seg_tokens)
    with pytest.raises(Exception):
        sched.ensure_chain(0, pin=True)
    e = pool.entry(("seg", 10))
    assert e is not None and e.refs == 0    # the root pin was unwound
    pool.clear()
    assert eng.block_pool.blocks_in_use == 0


# ----------------------------------------------------------------------
# pipeline: tree_levels=1 identity + tree mode end to end
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_pipe():
    from repro.data.scenegraph import generate_scene_graph
    from repro.rag.pipeline import GraphRAGPipeline
    from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
    from repro.rag.text_encoder import TextEncoder
    graph, queries = generate_scene_graph()
    tok2 = Tokenizer.train([q.question + " " + q.answer for q in queries]
                           + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="tree-pipe", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=tok2.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(32))
    pipe = GraphRAGPipeline(
        index=index, retriever=GRetrieverRetriever(index),
        engine=ServingEngine(params, cfg, tok2, max_cache_len=768,
                             max_new_tokens=4),
        tokenizer=tok2, use_soft_prompt=False)
    return pipe, queries[:8]


def test_tree_levels_one_is_token_identical_to_flat(small_pipe):
    pipe, items = small_pipe
    recs_flat, _, _, _ = pipe.run_subgcache(items, num_clusters=3)
    recs_one, _, _, _ = pipe.run_subgcache(items, num_clusters=3,
                                           tree_levels=1)
    assert [r.generated for r in recs_flat] == \
        [r.generated for r in recs_one]
    arr = np.cumsum(np.full(len(items), 0.01))
    rc, _, _ = pipe.serve_stream(items, arr, max_batch=4, tree_levels=1,
                                 mode="continuous", chunk=2)
    rd, _, _ = pipe.serve_stream(items, arr, max_batch=4, mode="drain")
    assert [r.generated for r in rc] == [r.generated for r in rd]


def test_tree_mode_offline_saves_prefix_tokens_and_balances_blocks(
        small_pipe):
    pipe, items = small_pipe
    # a previous serve_stream's pool may still hold resident prefixes;
    # the offline runs must return the arena to that baseline exactly
    base = pipe.engine.block_pool.blocks_in_use
    _, _, _, st_flat = pipe.run_subgcache(items, num_clusters=3)
    recs, _, plan, st_tree = pipe.run_subgcache(items, num_clusters=3,
                                                tree_levels=3)
    assert all(r is not None for r in recs)
    if plan.levels > 1:     # retrieval overlap decides the tree depth
        assert st_tree.prefix_tokens_computed < \
            st_flat.prefix_tokens_computed
        assert st_tree.ancestor_hits > 0
    assert pipe.engine.block_pool.blocks_in_use == base


def test_tree_serve_stream_continuous_matches_drain(small_pipe):
    pipe, items = small_pipe
    rng = np.random.default_rng(0)
    arr = np.cumsum(rng.exponential(0.05, size=len(items)))
    rc, _, sc = pipe.serve_stream(items, arr, max_batch=4, tree_levels=2,
                                  tree_clusters=3, mode="continuous",
                                  chunk=2)
    rd, _, sd = pipe.serve_stream(items, arr, max_batch=4, tree_levels=2,
                                  tree_clusters=3, mode="drain")
    assert [r.generated for r in rc] == [r.generated for r in rd]
    # per-level accounting is live in the serving report
    from repro.rag.workbench import serving_report
    rep = serving_report(pipe)
    assert "tree" in rep
