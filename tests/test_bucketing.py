"""Unit tests for the consolidated shape-bucketing rules
(serving/bucketing.py): the engine, the paged KV pool, and the
benchmarks all import from this one module."""
import pytest

from repro.serving.bucketing import (blocks_for, bucket_capacity, bucket_len,
                                     bucket_pow2)


def test_bucket_len_rounds_to_multiples():
    assert bucket_len(5, 32) == 32
    assert bucket_len(32, 32) == 32
    assert bucket_len(33, 32) == 64
    assert bucket_len(0, 32) == 32          # never below one bucket


def test_bucket_pow2():
    assert bucket_pow2(1) == 1
    assert bucket_pow2(2) == 2
    assert bucket_pow2(3) == 4
    assert bucket_pow2(5) == 8
    assert bucket_pow2(8) == 8
    assert bucket_pow2(9) == 16


def test_bucket_capacity_doubles_from_floor():
    assert bucket_capacity(100, 128, 1024, "t") == 128
    assert bucket_capacity(129, 128, 1024, "t") == 256
    assert bucket_capacity(300, 128, 1024, "t") == 512
    # the floor itself is clamped to the limit
    assert bucket_capacity(10, 128, 64, "t") == 64


def test_bucket_capacity_raises_past_limit():
    with pytest.raises(ValueError, match="raise max_cache_len"):
        bucket_capacity(2000, 128, 1024, "prompt")


def test_blocks_for_is_ceil_division():
    assert blocks_for(1, 64) == 1
    assert blocks_for(64, 64) == 1
    assert blocks_for(65, 64) == 2
    assert blocks_for(300, 64) == 5
    assert blocks_for(0, 64) == 1           # empty allocations own a block


def test_page_table_width_composes_blocks_and_pow2():
    """Block-count bucketing for page tables reuses the shared helpers:
    width = bucket_pow2(blocks_for(tokens)) — tokens stay data, the
    table shape is a bucket."""
    assert bucket_pow2(blocks_for(300, 64)) == 8     # 5 blocks -> width 8
    assert bucket_pow2(blocks_for(64, 64)) == 1
