"""Attention cache semantics: prefix split exactness, SWA, ring buffers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis; "
                           "pip install -e '.[test]'")
from hypothesis import given, settings, strategies as st

from repro.models import attention as A

KEY = jax.random.PRNGKey(0)
D_MODEL, HQ, HKV, HD = 48, 4, 2, 12


def _params():
    return A.init_attention(KEY, D_MODEL, HQ, HKV, HD, jnp.float32)


def _run(p, x, positions, cache=None, **kw):
    return A.self_attention(p, x, num_heads=HQ, num_kv_heads=HKV,
                            head_dim=HD, rope_theta=1e4,
                            positions=positions, cache=cache, **kw)


def _x(b, t, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, t, D_MODEL))


def _pos(b, t, off=0):
    return jnp.broadcast_to(off + jnp.arange(t, dtype=jnp.int32)[None],
                            (b, t))


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 10), st.integers(1, 8))
def test_prefix_split_exactness(p_len, s_len):
    """attention(full) == prefill(prefix) then suffix over cache — the
    invariant SubGCache's correctness rests on."""
    p = _params()
    b, t = 2, p_len + s_len
    x = _x(b, t)
    full, _ = _run(p, x, _pos(b, t))
    cache = A.init_kv_cache(b, HKV, 32, HD, jnp.float32)
    _, cache = _run(p, x[:, :p_len], _pos(b, p_len), cache=cache)
    suf, _ = _run(p, x[:, p_len:], _pos(b, s_len, off=p_len), cache=cache)
    np.testing.assert_allclose(np.asarray(full[:, p_len:]), np.asarray(suf),
                               atol=1e-5, rtol=1e-5)


def test_swa_equals_full_when_window_covers():
    p = _params()
    b, t = 2, 12
    x = _x(b, t)
    full, _ = _run(p, x, _pos(b, t))
    swa, _ = _run(p, x, _pos(b, t), window=t + 5)
    np.testing.assert_allclose(np.asarray(full), np.asarray(swa), atol=1e-6)


def test_swa_ring_decode_matches_windowed_full():
    """Decoding with a window-sized ring buffer == full-cache windowed."""
    p = _params()
    b, t, w = 1, 20, 8
    x = _x(b, t + 1)
    # reference: full cache, windowed attention
    cache_full = A.init_kv_cache(b, HKV, 64, HD, jnp.float32)
    _, cache_full = _run(p, x[:, :t], _pos(b, t), cache=cache_full, window=w)
    ref_out, _ = _run(p, x[:, t:], _pos(b, 1, off=t), cache=cache_full,
                      window=w)
    # ring: capacity == window
    cache_ring = A.init_kv_cache(b, HKV, w, HD, jnp.float32)
    _, cache_ring = _run(p, x[:, :t], _pos(b, t), cache=cache_ring, window=w)
    out, _ = _run(p, x[:, t:], _pos(b, 1, off=t), cache=cache_ring, window=w,
                  ring=True)
    np.testing.assert_allclose(np.asarray(ref_out), np.asarray(out),
                               atol=1e-5, rtol=1e-5)


def test_padded_suffix_rows_are_masked():
    """Right-padded suffix tokens must not contaminate later decode."""
    p = _params()
    b = 2
    x = _x(b, 6)
    cache = A.init_kv_cache(b, HKV, 32, HD, jnp.float32)
    valid = jnp.array([[1, 1, 1, 1, 0, 0], [1, 1, 1, 1, 1, 1]], bool)
    _, cache = _run(p, x, _pos(b, 6), cache=cache, valid=valid)
    # row 0 slots 4,5 must be invalid; row 1 fully valid
    assert cache["pos"][0, 4] == -1 and cache["pos"][0, 5] == -1
    assert cache["pos"][1, 5] == 5
    # decode for row 0 at position 4 (its true length)
    xq = _x(b, 1, seed=9)
    pos_q = jnp.array([[4], [6]], jnp.int32)
    out, _ = _run(p, xq, pos_q, cache=cache)
    # reference: row 0 recomputed with only its 4 valid tokens
    cache2 = A.init_kv_cache(1, HKV, 32, HD, jnp.float32)
    _, cache2 = _run(p, x[:1, :4], _pos(1, 4), cache=cache2)
    want, _ = _run(p, xq[:1], pos_q[:1], cache=cache2)
    np.testing.assert_allclose(np.asarray(out[:1]), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_cache_write_ring_wraps():
    cache = A.init_kv_cache(1, 1, 4, 8, jnp.float32)
    k = jnp.ones((1, 1, 1, 8))
    for pos in range(7):
        cache = A.cache_write(cache, k * pos, k * pos,
                              jnp.array([[pos]]), ring=True)
    # capacity 4: slots hold positions 4,5,6,3
    assert sorted(np.asarray(cache["pos"][0]).tolist()) == [3, 4, 5, 6]


def test_chunked_attend_matches_unchunked():
    b, t, s = 1, 2048, 64
    q = jax.random.normal(KEY, (b, HQ, t, HD))
    k = jax.random.normal(KEY, (b, s, HKV, HD))     # seq-major cache layout
    v = jax.random.normal(KEY, (b, s, HKV, HD))
    q_pos = _pos(b, t)
    k_pos = _pos(b, s)
    full = A._attend_block(q.reshape(b, HKV, HQ // HKV, t, HD), k, v, q_pos,
                           k_pos, causal=True, window=0, scale=HD ** -0.5)
    full = full.reshape(b, HQ, t, HD)
    chunked = A.attend(q, k, v, q_pos, k_pos, causal=True)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(chunked, np.float32),
                               atol=1e-5, rtol=1e-5)
