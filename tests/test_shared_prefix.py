"""Shared-prefix cascade attention: exactness vs the broadcast path and
the split-cache HBM accounting (DESIGN.md §5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import PrefixState
from repro.data.tokenizer import Tokenizer
from repro.models import attention as A
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.engine import ServingEngine

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------------
# attend_shared vs broadcast-then-attend (unit level)
# ----------------------------------------------------------------------
def _mk(b, hq, hkv, tq, p, s, d):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, hq, tq, d))
    pk = jax.random.normal(ks[1], (1, p, hkv, d))        # seq-major
    pv = jax.random.normal(ks[2], (1, p, hkv, d))
    sk = jax.random.normal(ks[3], (b, s, hkv, d))
    sv = jax.random.normal(ks[4], (b, s, hkv, d))
    return q, pk, pv, sk, sv


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])  # MHA/GQA/MQA
@pytest.mark.parametrize("plen,tq", [
    (9, 5),          # small, nothing aligned
    (128, 7),        # prefix exactly one attention block
    (129, 33),       # prefix + suffix both straddle block boundaries
])
def test_attend_shared_matches_broadcast(hq, hkv, plen, tq):
    b, d = 3, 16
    p_cap, s_cap = plen + 6, tq + 9                      # capacity > used
    q, pk, pv, sk, sv = _mk(b, hq, hkv, tq, p_cap, s_cap, d)
    slots = jnp.arange(p_cap)[None]
    p_pos = jnp.where(slots < plen, slots, -1)           # empty tail slots
    q_pos = jnp.broadcast_to(plen + jnp.arange(tq)[None], (b, tq))
    s_slots = jnp.arange(s_cap)[None]
    s_pos = jnp.broadcast_to(
        jnp.where(s_slots < tq, plen + s_slots, -1), (b, s_cap))

    prefix = {"k": pk, "v": pv, "pos": p_pos}
    got = A.attend_shared(q, q_pos, prefix, sk, sv, s_pos)

    # broadcast path: replicate the prefix KV and attend the concat
    k_all = jnp.concatenate([jnp.broadcast_to(pk, (b,) + pk.shape[1:]), sk], 1)
    v_all = jnp.concatenate([jnp.broadcast_to(pv, (b,) + pv.shape[1:]), sv], 1)
    pos_all = jnp.concatenate([jnp.broadcast_to(p_pos, (b, p_cap)), s_pos], 1)
    want = A.attend(q, k_all, v_all, q_pos, pos_all, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("window", [3, 8, 64])
def test_attend_shared_window(window):
    """Sliding windows that end inside the prefix, straddle the
    prefix/suffix seam, and cover everything."""
    b, hq, hkv, tq, plen, d = 2, 4, 2, 6, 20, 16
    q, pk, pv, sk, sv = _mk(b, hq, hkv, tq, plen, tq, d)
    p_pos = jnp.arange(plen)[None]
    q_pos = jnp.broadcast_to(plen + jnp.arange(tq)[None], (b, tq))
    s_pos = jnp.broadcast_to(plen + jnp.arange(tq)[None], (b, tq))

    got = A.attend_shared(q, q_pos, {"k": pk, "v": pv, "pos": p_pos},
                          sk, sv, s_pos, window=window)
    k_all = jnp.concatenate([jnp.broadcast_to(pk, (b,) + pk.shape[1:]), sk], 1)
    v_all = jnp.concatenate([jnp.broadcast_to(pv, (b,) + pv.shape[1:]), sv], 1)
    pos_all = jnp.concatenate([jnp.broadcast_to(p_pos, (b, plen)), s_pos], 1)
    want = A.attend(q, k_all, v_all, q_pos, pos_all, causal=True,
                    window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_self_attention_split_cache_matches_broadcast():
    """Full layer: suffix prefill + a decode step through the split
    cache equal the broadcast cache, including the suffix slot_offset
    remapping (token P+i at slot i)."""
    d_model, hq, hkv, hd = 48, 4, 2, 12
    p = A.init_attention(KEY, d_model, hq, hkv, hd, jnp.float32)
    b, plen, slen = 2, 10, 4

    def run(x, pos, cache=None, **kw):
        return A.self_attention(p, x, num_heads=hq, num_kv_heads=hkv,
                                head_dim=hd, rope_theta=1e4, positions=pos,
                                cache=cache, **kw)

    xp = jax.random.normal(jax.random.PRNGKey(1), (1, plen, d_model))
    xs = jax.random.normal(jax.random.PRNGKey(2), (b, slen, d_model))
    xd = jax.random.normal(jax.random.PRNGKey(3), (b, 1, d_model))
    pos_p = jnp.arange(plen)[None]
    pos_s = jnp.broadcast_to(plen + jnp.arange(slen)[None], (b, slen))
    pos_d = jnp.full((b, 1), plen + slen, jnp.int32)

    # batch-1 prefix cache
    pc = A.init_kv_cache(1, hkv, 16, hd, jnp.float32)
    _, pc = run(xp, pos_p, cache=pc)

    # broadcast reference: replicated prefix in a big cache
    bc = {k: jnp.broadcast_to(v, (b,) + v.shape[1:]) for k, v in
          A.init_kv_cache(b, hkv, 32, hd, jnp.float32).items()}
    _, bc = run(jnp.broadcast_to(xp, (b, plen, d_model)),
                jnp.broadcast_to(pos_p, (b, plen)), cache=bc)
    want_s, bc = run(xs, pos_s, cache=bc)
    want_d, _ = run(xd, pos_d, cache=bc)

    # split path: suffix-only cache + live prefix
    sc = A.init_kv_cache(b, hkv, 8, hd, jnp.float32)
    got_s, sc = run(xs, pos_s, cache=sc, prefix=pc, slot_offset=plen)
    got_d, sc = run(xd, pos_d, cache=sc, prefix=pc, slot_offset=plen)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               atol=1e-5, rtol=1e-5)
    # suffix token P+i must sit at slot i with its absolute position
    assert int(sc["pos"][0, 0]) == plen
    assert int(sc["pos"][0, slen]) == plen + slen


def test_windowed_padded_suffix_keeps_real_keys():
    """Regression (pre-existing in the broadcast tail-write, surfaced by
    the cascade review): a right-padded member's real suffix keys must
    survive the window-sized ring write — a column-tail write would
    drop them and land padding in live slots.  Reference: each row
    served length-exact at batch 1."""
    d_model, hq, hkv, hd, w = 48, 4, 2, 12, 8
    p = A.init_attention(KEY, d_model, hq, hkv, hd, jnp.float32)
    plen, t_pad = 10, 12                       # suffix block padded to 12
    row_lens = [2, 12]                         # row 0 heavily padded

    def run(x, pos, cache=None, **kw):
        return A.self_attention(p, x, num_heads=hq, num_kv_heads=hkv,
                                head_dim=hd, rope_theta=1e4, positions=pos,
                                cache=cache, window=w, **kw)

    xp = jax.random.normal(jax.random.PRNGKey(1), (1, plen, d_model))
    xs = jax.random.normal(jax.random.PRNGKey(2), (2, t_pad, d_model))
    pos_p = jnp.arange(plen)[None]
    pos_s = jnp.broadcast_to(plen + jnp.arange(t_pad)[None], (2, t_pad))
    valid = jnp.stack([jnp.arange(t_pad) < n for n in row_lens])

    pc = A.init_kv_cache(1, hkv, w, hd, jnp.float32)      # window-sized ring
    _, pc = run(xp, pos_p, cache=pc)
    sc = A.init_kv_cache(2, hkv, w, hd, jnp.float32)
    _, sc = run(xs, pos_s, cache=sc, valid=valid, prefix=pc,
                slot_offset=plen)

    for r, n in enumerate(row_lens):
        # reference: this row alone, unpadded, full-capacity cache
        cr = A.init_kv_cache(1, hkv, 64, hd, jnp.float32)
        _, cr = run(xp, pos_p, cache=cr)
        _, cr = run(xs[r:r + 1, :n], pos_s[r:r + 1, :n], cache=cr)
        xd = jax.random.normal(jax.random.PRNGKey(7), (1, 1, d_model))
        pos_d = jnp.full((1, 1), plen + n, jnp.int32)
        want, _ = run(xd, pos_d, cache=cr)
        got, _ = run(xd, pos_d, cache=jax.tree.map(lambda a: a[r:r + 1], sc),
                     prefix=pc, slot_offset=plen)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5,
                                   err_msg=f"row {r} len {n}")


# ----------------------------------------------------------------------
# engine end-to-end: cascade == broadcast, with the HBM bound asserted
# ----------------------------------------------------------------------
def _tinyllama_cfg(vocab: int) -> ModelConfig:
    """Scaled-down TinyLlama (dense GQA llama-2 arch, 4:1 head grouping)."""
    return ModelConfig(name="tinyllama-test", family="dense", num_layers=3,
                       d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
                       d_ff=160, vocab_size=vocab, dtype="float32")


@pytest.fixture(scope="module")
def engines():
    tok = Tokenizer.train(["the quick brown fox jumps over the lazy dog "
                           "a graph of nodes and edges answers questions"])
    cfg = _tinyllama_cfg(tok.vocab_size)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    # paged=False: these tests probe the DENSE split cascade internals
    # (suffix-cache allocation, live batch-1 prefix buffers); the paged
    # backend has its own exactness suite in tests/test_paged.py.
    split = ServingEngine(params, cfg, tok, max_cache_len=512,
                          max_new_tokens=6, paged=False)
    bcast = ServingEngine(params, cfg, tok, max_cache_len=512,
                          max_new_tokens=6, split_prefix=False)
    return tok, split, bcast


def test_split_mode_is_auto_enabled(engines):
    tok, split, bcast = engines
    assert split.use_split_prefix
    assert not bcast.use_split_prefix


def test_generate_with_prefix_matches_broadcast_end_to_end(engines):
    """Acceptance: cascade outputs == seed broadcast outputs (f32)."""
    tok, split, bcast = engines
    prefix = tok.encode("the quick brown fox jumps over the lazy dog",
                        bos=True)
    suffixes = [tok.encode("a graph of nodes"),
                tok.encode("and edges"),
                tok.encode("answers questions a graph")]
    st_s, _ = split.prefill_prefix(prefix)
    st_b, _ = bcast.prefill_prefix(prefix)
    out_s, t_s = split.generate_with_prefix(st_s, suffixes)
    out_b, t_b = bcast.generate_with_prefix(st_b, suffixes)
    assert t_s["split_prefix"] and not t_b["split_prefix"]
    assert out_s == out_b


def test_split_never_broadcasts_and_allocates_p_plus_bs(engines, monkeypatch):
    """Acceptance: on attention-only configs generate_with_prefix never
    calls PrefixState.broadcast, and allocated KV slots are
    prefix_capacity + B × suffix_capacity (pytree shape inspection)."""
    tok, split, _ = engines
    prefix = tok.encode("the quick brown fox", bos=True)
    suffixes = [tok.encode("lazy dog"), tok.encode("nodes and edges")]

    def boom(self, template):
        raise AssertionError("split path must not broadcast the prefix")
    monkeypatch.setattr(PrefixState, "broadcast", boom)

    allocated = []
    real_init = M.init_suffix_cache

    def spy(cfg, batch, capacity):
        cache = real_init(cfg, batch, capacity)
        allocated.append(cache)
        return cache
    monkeypatch.setattr("repro.serving.engine.M.init_suffix_cache", spy)

    state, _ = split.prefill_prefix(prefix)
    outs, _ = split.generate_with_prefix(state, suffixes)
    assert len(outs) == len(suffixes)

    def kv_slots(cache) -> int:
        """Total KV slots in a cache pytree = sum of ``pos`` elements
        (each pos entry marks one [Hkv, D] KV slot), across stacked
        layer groups."""
        leaves = [x for path, x in
                  jax.tree_util.tree_flatten_with_path(cache)[0]
                  if getattr(path[-1], "key", None) == "pos"]
        return sum(int(np.prod(x.shape)) for x in leaves)

    b = 2                                   # bucketed member batch
    n_attn_layers = len(split.cfg.layer_specs())
    # the prefix state holds prefix_capacity slots at batch 1
    assert kv_slots(state.cache) == n_attn_layers * 1 * state.capacity
    # the ONLY member-side allocation is the suffix cache: B × suffix_cap
    assert len(allocated) == 1
    suffix_slots = kv_slots(allocated[0])
    suffix_cap = suffix_slots // (n_attn_layers * b)
    assert suffix_slots == n_attn_layers * b * suffix_cap
    assert suffix_cap < state.capacity      # members never pay prefix HBM


def test_swa_config_split_matches_broadcast():
    """Sliding-window stack through the engine: cascade == broadcast
    (the default engine is PAGED here, so this also covers windowed
    paged serving — windows are masked positionally, never rung)."""
    tok = Tokenizer.train(["alpha beta gamma delta epsilon zeta eta theta"])
    cfg = ModelConfig(name="swa-test", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, head_dim=8,
                      d_ff=64, vocab_size=tok.vocab_size, dtype="float32",
                      sliding_window=8)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    split = ServingEngine(params, cfg, tok, max_cache_len=256,
                          max_new_tokens=4)
    bcast = ServingEngine(params, cfg, tok, max_cache_len=256,
                          max_new_tokens=4, split_prefix=False)
    assert split.use_split_prefix
    prefix = tok.encode("alpha beta gamma delta epsilon", bos=True)
    suffixes = [tok.encode("zeta eta"), tok.encode("theta")]
    st_s, _ = split.prefill_prefix(prefix)
    st_b, _ = bcast.prefill_prefix(prefix)
    out_s, _ = split.generate_with_prefix(st_s, suffixes)
    out_b, _ = bcast.generate_with_prefix(st_b, suffixes)
    assert out_s == out_b


def test_pallas_bf16_split_matches_broadcast():
    """Pallas cascade on a bf16 config: partials stay f32 so the merge
    rounds to bf16 exactly once, matching single-pass attention."""
    tok = Tokenizer.train(["one two three four five six seven eight"])
    cfg = ModelConfig(name="bf16-pallas", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, head_dim=8,
                      d_ff=64, vocab_size=tok.vocab_size, dtype="bfloat16",
                      attention_impl="pallas")
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    split = ServingEngine(params, cfg, tok, max_cache_len=256,
                          max_new_tokens=3)
    bcast = ServingEngine(params, cfg, tok, max_cache_len=256,
                          max_new_tokens=3, split_prefix=False)
    prefix = tok.encode("one two three four", bos=True)
    suffixes = [tok.encode("five six"), tok.encode("seven")]
    st_s, _ = split.prefill_prefix(prefix)
    st_b, _ = bcast.prefill_prefix(prefix)
    out_s, _ = split.generate_with_prefix(st_s, suffixes)
    out_b, _ = bcast.generate_with_prefix(st_b, suffixes)
    assert out_s == out_b


def test_engine_records_cache_stats(engines):
    """Satellite: the engine (not the pipeline) records accounting."""
    tok, split, _ = engines
    stats = split.cache_mgr.reset_stats()
    prefix = tok.encode("the quick brown fox", bos=True)
    suffixes = [tok.encode("lazy dog"), tok.encode("nodes and edges")]
    state, _ = split.prefill_prefix(prefix)
    split.generate_with_prefix(state, suffixes)
    assert stats.num_clusters == 1
    assert stats.clusters_split == 1          # observed cascade, not capability
    assert stats.num_queries == len(suffixes)
    assert stats.prefix_tokens_computed == state.prefix_len
    assert stats.suffix_tokens_computed == sum(len(s) for s in suffixes)
    assert stats.prefill_savings > 1.0
