"""Continuous in-flight batching (DESIGN.md §9): token-exactness vs the
drain-serve oracle, mid-flight retirement freeing suffix blocks,
admission under arena pressure vs pinned in-flight prefixes, the
prefixless dense fallback, and the accounting bugfix satellites."""
import jax
import numpy as np
import pytest

from repro.core.prefix_pool import PrefixPool
from repro.data.tokenizer import EOS, Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request, ServingEngine
from repro.serving.metrics import QueryRecord, trace_summary
from repro.serving.scheduler import OnlineClusterAssigner, OnlineScheduler


def _gqa_cfg(vocab, dtype="float32", impl="xla"):
    return ModelConfig(name="cont-test", family="dense", num_layers=3,
                       d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
                       d_ff=160, vocab_size=vocab, dtype=dtype,
                       attention_impl=impl)


@pytest.fixture(scope="module")
def tok():
    return Tokenizer.train(["the quick brown fox jumps over the lazy dog "
                            "a graph of nodes and edges answers questions"])


def _engine(tok, key=0, dtype="float32", impl="xla", **kw):
    cfg = _gqa_cfg(tok.vocab_size, dtype, impl)
    params = M.init_params(jax.random.PRNGKey(key), cfg)
    kw.setdefault("max_cache_len", 512)
    kw.setdefault("max_new_tokens", 5)
    return ServingEngine(params, cfg, tok, **kw)


# ----------------------------------------------------------------------
# token exactness vs the drain-serve oracle
# ----------------------------------------------------------------------
@pytest.mark.parametrize("dtype,impl", [("float32", "xla"),
                                        ("bfloat16", "pallas")])
def test_continuous_token_exact_vs_drain_oracle(tok, dtype, impl):
    """Mixed-cluster rows admitted in STAGGERED groups (one group lands
    mid-decode of the previous, like a Poisson trace) must reproduce
    the drain-serve batch token for token: chunked decode + mid-flight
    admission + retirement reschedule work, never change math."""
    eng = _engine(tok, dtype=dtype, impl=impl)
    st0, _ = eng.prefill_prefix(tok.encode("a graph of nodes", bos=True))
    st1, _ = eng.prefill_prefix(tok.encode(
        "the quick brown fox jumps over the lazy dog " * 8, bos=True))
    assert len(st0.page.blocks) < len(st1.page.blocks)
    sfx = [tok.encode("answers questions"), tok.encode("and edges"),
           tok.encode("lazy dog jumps"), tok.encode("the quick")]
    pids = [0, 1, 1, 0]
    oracle, t = eng.generate_multi_prefix([st0, st1], pids, sfx,
                                          _record=False)
    assert t["paged"]

    cont = ContinuousEngine(eng, max_slots=4, chunk=2, max_suffix_len=8)
    base = eng.block_pool.blocks_in_use
    cont.admit([Request(sfx[0], st0), Request(sfx[1], st1)],
               payloads=[0, 1])
    cont.step()                      # group 2 arrives mid-decode
    cont.admit([Request(sfx[2], st1), Request(sfx[3], st0)],
               payloads=[2, 3])
    cont.flush()
    res = {r.payload: r for r in cont.pop_retired()}
    assert [res[i].tokens for i in range(4)] == oracle
    # every reservation and prefix pin released with the rows
    assert eng.block_pool.blocks_in_use == base
    # exact attribution: decode shares sum to what was measured, and a
    # row never consumes more steps than its budget
    assert all(0 <= res[i].decode_steps <= eng.max_new_tokens - 1
               for i in range(4))
    st0.release()
    st1.release()


def test_continuous_matches_drain_through_serve_stream():
    """Pipeline-level A/B: the SAME Poisson trace served continuous and
    drain produces identical generations per query, and the continuous
    records carry exact decode-step counts."""
    from repro.data.scenegraph import generate_scene_graph
    from repro.rag.pipeline import GraphRAGPipeline
    from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
    from repro.rag.text_encoder import TextEncoder

    graph, queries = generate_scene_graph()
    tok2 = Tokenizer.train([q.question + " " + q.answer
                            for q in queries] + graph.node_text,
                           max_vocab=2048)
    cfg = ModelConfig(name="cont-stream", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=tok2.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(32))
    pipe = GraphRAGPipeline(
        index=index, retriever=GRetrieverRetriever(index),
        engine=ServingEngine(params, cfg, tok2, max_cache_len=512,
                             max_new_tokens=4),
        tokenizer=tok2, use_soft_prompt=False)
    items = queries[:6]
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(0.05, size=len(items)))

    recs_c, summ_c, _ = pipe.serve_stream(
        items, arrivals, max_batch=4, threshold=0.25,
        mode="continuous", chunk=2)
    recs_d, _, _ = pipe.serve_stream(
        items, arrivals, max_batch=4, threshold=0.25, mode="drain")
    assert [r.generated for r in recs_c] == [r.generated for r in recs_d]
    assert all(r.queue_wait_s >= 0 for r in recs_c)
    assert all(0 <= r.decode_steps <= 3 for r in recs_c)
    assert summ_c.num_queries == len(items)
    s = trace_summary(recs_c)
    assert s["p95_queue_wait_ms"] >= 0
    assert s["mean_decode_steps"] > 0


# ----------------------------------------------------------------------
# mid-flight retirement
# ----------------------------------------------------------------------
def test_midflight_retirement_frees_suffix_blocks(tok):
    """A row that exhausts its budget retires while another row is
    still decoding: its main-arena suffix reservation returns to the
    free list AT RETIREMENT (allocator free-count assertion), not when
    the whole batch drains."""
    eng = _engine(tok, max_new_tokens=4)
    st, _ = eng.prefill_prefix(tok.encode("a graph of nodes", bos=True))
    cont = ContinuousEngine(eng, max_slots=2, chunk=1, max_suffix_len=8)
    nbs = cont.batch.nbs
    cont.admit([Request(tok.encode("answers questions"), st)],
               payloads=["a"])
    cont.step()                                  # a: 1 of 3 steps
    cont.step()                                  # a: 2 of 3 steps
    cont.admit([Request(tok.encode("and edges"), st)], payloads=["b"])
    free_before = eng.block_pool.free_blocks
    freed_at_retire = None
    for _ in range(10):
        cont.step()
        retired = cont.pop_retired()
        if retired and freed_at_retire is None:
            assert retired[0].payload == "a"     # admitted first, out first
            freed_at_retire = eng.block_pool.free_blocks - free_before
            inflight_at_retire = cont.in_flight
        if not cont.in_flight:
            break
    assert freed_at_retire is not None
    # a's reservation freed the moment it retired...
    assert freed_at_retire >= nbs
    # ...while b was still in flight (no drain barrier)
    assert inflight_at_retire == 1
    st.release()
    assert eng.block_pool.blocks_in_use == 0


def test_instant_retirement_when_no_decode_owed(tok):
    """A row that owes no decode (budget of one token — and the same
    path serves a first-token EOS) retires AT ADMISSION, consuming zero
    scan steps; the drain loop burned ``max_new_tokens - 1`` scan steps
    on every such row."""
    eng = _engine(tok, max_new_tokens=1)
    st, _ = eng.prefill_prefix(tok.encode("a graph of nodes", bos=True))
    sfx = tok.encode("answers questions")
    oracle, _ = eng.generate_with_prefix(st, [sfx], _record=False)
    cont = ContinuousEngine(eng, max_slots=2, chunk=2, max_suffix_len=8)
    cont.admit([Request(sfx, st)], payloads=["x"])
    res = cont.pop_retired()                     # no step() needed
    assert len(res) == 1 and res[0].decode_steps == 0
    assert res[0].tokens == oracle[0]
    assert cont.in_flight == 0
    st.release()
    assert eng.block_pool.blocks_in_use == 0


def test_max_slots_cap_honored_at_non_pow2(tok):
    """The compiled decode batch is a power-of-two bucket, but the
    caller's concurrency cap must be honored exactly: max_slots=3 admits
    at most 3 concurrent rows (the 4th compiled row is done-padding)."""
    eng = _engine(tok)
    cont = ContinuousEngine(eng, max_slots=3, chunk=2, max_suffix_len=8)
    assert cont.free_slots == 3
    assert cont.batch.num_slots == 4
    st, _ = eng.prefill_prefix(tok.encode("a graph of nodes", bos=True))
    cont.admit([Request(tok.encode("answers"), st) for _ in range(3)])
    assert cont.free_slots == 0 and cont.in_flight <= 3
    cont.flush()
    cont.pop_retired()
    st.release()


def test_warmup_traces_decode_despite_instant_retirement(tok):
    """Warmup must compile the chunked-decode executable even when every
    warm row retires at admission (one-token budget), and must cover
    the TOP admission bucket of a non-power-of-two slot cap (3 drained
    arrivals bucket to a batch of 4): the first timed chunk or
    admission may not pay an XLA compile."""
    eng = _engine(tok, max_new_tokens=1)
    cont = ContinuousEngine(eng, max_slots=3, chunk=2, max_suffix_len=8)
    cont.warmup([4])                 # every warm row retires instantly
    assert cont.in_flight == 0
    assert eng.block_pool.blocks_in_use == 0
    # the decode executable for this width bucket is now cached
    key = (cont.batch.num_slots, cont.batch.chunk)
    assert eng._decode_step_jit.cache_info().currsize >= 1, key


# ----------------------------------------------------------------------
# admission under arena pressure
# ----------------------------------------------------------------------
def test_admission_pressure_cannot_evict_pinned_inflight_prefix(tok):
    """With the arena nearly full, admitting a NEW cluster's query must
    reclaim only COLD pooled prefixes; a prefix pinned by an in-flight
    row survives, and when nothing is evictable the admission fails
    CLEANLY (pins dropped, in-flight row unharmed and token-exact)."""
    eng = _engine(tok, arena_blocks=2, max_new_tokens=4)
    pool = PrefixPool(budget_bytes=1 << 30)      # byte budget never binds
    pool.attach_block_pool(eng.block_pool)
    reps = {0: tok.encode("a graph of nodes", bos=True),
            1: tok.encode("the quick brown fox", bos=True),
            2: tok.encode("lazy dog jumps over", bos=True)}
    sched = OnlineScheduler(eng, OnlineClusterAssigner(threshold=1.0),
                            pool, lambda sg: reps[min(sg.nodes)])
    from repro.core.subgraph import Subgraph
    _sg = lambda i: Subgraph.from_lists([i], [])
    emb = {i: np.array([10.0 * i, 0.0]) for i in range(3)}
    cont = ContinuousEngine(eng, max_slots=2, chunk=1, max_suffix_len=8)

    sfx = tok.encode("answers")
    oracle = None
    # cluster 0: admitted and in flight (1 prefix block + 1 reservation)
    admitted, _ = sched.serve_continuous(cont, [emb[0]], [_sg(0)], [sfx],
                                         payloads=["q0"])
    assert pool.entry(0).refs == 1               # pinned by the row
    blocks0 = list(pool.entry(0).state.page.blocks)
    cont.step()                                  # mid-decode
    # cluster 1: fits only by reclaiming... nothing is cold -> the
    # prefix PREFILL or reservation hits OutOfBlocks, cluster 0 intact
    from repro.core.paged import OutOfBlocks
    free_before = eng.block_pool.free_blocks
    with pytest.raises(OutOfBlocks):
        sched.serve_continuous(cont, [emb[1]], [_sg(1)], [sfx],
                               payloads=["q1"])
    assert 0 in pool and pool.entry(0).refs == 1   # survived, still pinned
    assert [eng.block_pool.allocator.refcount(b) for b in blocks0] \
        == [2] * len(blocks0)                    # pool + in-flight row
    assert eng.block_pool.free_blocks == free_before   # clean unwind
    assert cont.free_slots == 1                  # failed row took no slot
    # the in-flight row still decodes to the exact oracle
    cont.flush()
    [res] = cont.pop_retired()
    st0 = pool.get(0)
    o, _ = eng.generate_with_prefix(st0, [sfx], _record=False)
    assert res.tokens == o[0]
    assert pool.entry(0).refs == 0               # retirement released pin
    # with the row retired, cluster 0 is COLD: the same admission now
    # succeeds by evicting it (admission pressure = pool eviction)
    admitted, _ = sched.serve_continuous(cont, [emb[1]], [_sg(1)], [sfx],
                                         payloads=["q1"])
    assert 0 not in pool and 1 in pool
    assert pool.stats.pool_evictions >= 1
    cont.flush()


# ----------------------------------------------------------------------
# satellite: prefixless requests through the dense fallback
# ----------------------------------------------------------------------
def test_serve_dense_prefixless_matches_generate(tok):
    """Regression: ``serve`` on a prefixless request used to assert out
    on the dense fallback while the paged backend served it fine.  Both
    the stateful stack and a ``paged=False`` attention stack must now
    match ``generate`` token for token, mixed with prefixed rows."""
    # stateful (recurrent) stack: dense fallback is the ONLY path
    cfg = ModelConfig(name="ssm-cont", family="ssm", num_layers=2,
                      d_model=64, num_heads=0, num_kv_heads=0, d_ff=0,
                      ssm_state=8, vocab_size=tok.vocab_size,
                      dtype="float32")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    eng = ServingEngine(params, cfg, tok, max_cache_len=512,
                        max_new_tokens=4)
    assert eng._stateful and not eng.use_paged
    sfx = [tok.encode("answers questions"), tok.encode("the quick brown")]
    st, _ = eng.prefill_prefix(tok.encode("a graph of nodes", bos=True))
    outs, t = eng.serve([Request(sfx[0], None), Request(sfx[1], st),
                         Request(sfx[1], None)], _record=False)
    assert outs[0] == eng.generate(sfx[0])[0]
    assert outs[2] == eng.generate(sfx[1])[0]
    assert outs[1] == eng.generate_with_prefix(st, [sfx[1]],
                                               _record=False)[0][0]
    assert t["num_prefixes"] == 1        # the prefixless group is free

    # attention stack with the paged backend DISABLED: same contract,
    # and identical to what the paged backend serves
    eng_d = _engine(tok, key=2, paged=False, max_new_tokens=4)
    eng_p = ServingEngine(eng_d.params, eng_d.cfg, tok, max_cache_len=512,
                          max_new_tokens=4)
    assert not eng_d.use_paged and eng_p.use_paged
    outs_d, _ = eng_d.serve([Request(sfx[0], None)], _record=False)
    outs_p, _ = eng_p.serve([Request(sfx[0], None)], _record=False)
    assert outs_d[0] == outs_p[0] == eng_d.generate(sfx[0])[0]


# ----------------------------------------------------------------------
# satellite: fragmentation accounting reconciled at retirement
# ----------------------------------------------------------------------
def test_paged_note_tokens_reconciled_with_actual_decode(tok):
    """The drain path used to charge every row ``suffix +
    max_new_tokens`` stored tokens up front.  The gauge must now see
    (a) the suffix tokens charged BEFORE the in-flight observation —
    never zero-token suffix blocks — and (b) a post-decode observation
    reconciled to what each row actually generated (EOS-cut)."""
    eng = _engine(tok, max_new_tokens=5)
    st, _ = eng.prefill_prefix(tok.encode("a graph of nodes", bos=True),
                               _record=False)
    stats = eng.cache_mgr.reset_stats()
    snaps = []
    orig = stats.record_blocks
    stats.record_blocks = lambda pool: (
        snaps.append((pool.blocks_in_use, pool.tokens_stored)),
        orig(pool))[-1]
    sfx = [tok.encode("answers questions"), tok.encode("and edges")]
    outs, _ = eng.generate_with_prefix(st, sfx)    # batch 2 = bucket, no pads
    prefix_tokens = st.prefix_len
    lens = [len(s) for s in sfx]
    gens = [min(len(o) + 1, eng.max_new_tokens) for o in outs]
    # in-flight snapshot: prefix + every suffix token charged, no
    # decode budget padded on top
    assert snaps[0][1] == prefix_tokens + sum(lens)
    # reconciled snapshot: exactly what the rows stored incl. decode
    assert snaps[1][1] == prefix_tokens + sum(lens) + sum(gens)
    # post-free snapshot: only the resident prefix remains charged
    assert snaps[-1][1] == prefix_tokens
    st.release()


# ----------------------------------------------------------------------
# satellite: soft-prompt tokens visible to accounting
# ----------------------------------------------------------------------
def test_soft_prompt_counted_in_prompt_tokens(tok):
    """``use_soft_prompt=True`` runs consume ``n_soft`` embedding
    positions per prefix (and per baseline prompt); prompt-token
    accounting and the prefill-savings denominators must include
    them."""
    eng = _engine(tok, key=3)
    soft = np.ones((3, 64), np.float32) * 0.01
    ptoks = tok.encode("a graph of nodes", bos=True)
    st, _ = eng.prefill_prefix(ptoks, soft=soft, _record=False)
    assert st.n_soft == 3
    assert st.prefix_len == len(ptoks) + 3       # prefill consumed them
    stats = eng.cache_mgr.reset_stats()
    sfx = tok.encode("answers questions")
    eng.serve([Request(sfx, st)])
    # the member's baseline-equivalent prompt includes the soft tokens
    assert stats.prefill_tokens_baseline == st.prefix_len + len(sfx)
    st.release()


def test_pipeline_soft_prompt_prompt_tokens():
    """run_baseline / run_subgcache prompt_tokens include the soft
    prompt where the row actually consumed it."""
    from repro.data.scenegraph import generate_scene_graph
    from repro.gnn.graph_transformer import (apply_graph_transformer,
                                             init_graph_transformer)
    from repro.gnn.projector import init_projector
    from repro.rag.pipeline import GraphRAGPipeline
    from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
    from repro.rag.text_encoder import TextEncoder

    graph, queries = generate_scene_graph()
    tok2 = Tokenizer.train([q.question + " " + q.answer
                            for q in queries] + graph.node_text,
                           max_vocab=2048)
    cfg = ModelConfig(name="soft-acct", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=tok2.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(5), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(32))
    gnn_params = init_graph_transformer(jax.random.PRNGKey(7), 32, 32, 4, 4)
    proj = init_projector(jax.random.PRNGKey(8), 32, cfg.d_model, 2)
    pipe = GraphRAGPipeline(
        index=index, retriever=GRetrieverRetriever(index),
        engine=ServingEngine(params, cfg, tok2, max_cache_len=1024,
                             max_new_tokens=3),
        tokenizer=tok2, gnn_params=gnn_params,
        gnn_apply=apply_graph_transformer, proj_params=proj,
        use_soft_prompt=True)
    items = queries[:2]
    n_soft = pipe.soft_prompt(
        pipe.retriever.retrieve(items[0].question)).shape[0]
    assert n_soft == 2

    recs, _ = pipe.run_baseline(items)
    for r, it in zip(recs, items):
        sg = pipe.retriever.retrieve(it.question)
        full = pipe.prefix_text(sg) + " " + pipe.suffix_text(it.question)
        assert r.prompt_tokens == len(
            pipe.tokenizer.encode(full, bos=True)) + n_soft

    recs, _, plan, _ = pipe.run_subgcache(items, num_clusters=1)
    rep = plan.clusters[0].representative
    plen = len(pipe.tokenizer.encode(pipe.prefix_text(rep), bos=True))
    for r, it in zip(recs, items):
        sfx_len = len(pipe.tokenizer.encode(pipe.suffix_text(it.question)))
        assert r.prompt_tokens == plen + n_soft + sfx_len
        assert r.cached_tokens == plen + n_soft


# ----------------------------------------------------------------------
# metrics
# ----------------------------------------------------------------------
def test_trace_summary_quantities():
    recs = [QueryRecord(query="q", answer="a", generated="g", correct=True,
                        queue_wait_s=w, prefill_s=0.01, decode_s=d,
                        decode_steps=s)
            for w, d, s in [(0.0, 0.02, 2), (0.1, 0.04, 4)]]
    s = trace_summary(recs)
    assert s["mean_queue_wait_ms"] == pytest.approx(50.0)
    assert s["p95_queue_wait_ms"] == pytest.approx(95.0)
    assert s["mean_decode_steps"] == pytest.approx(3.0)
    assert s["mean_ttft_ms"] == pytest.approx(1e3 * (0.01 + 0.05))
