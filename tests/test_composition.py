"""Position-independent segment composition (DESIGN.md §14).

Gates, in order of strength:

1. Read-time RoPE rotation at a matching offset is BITWISE the
   write-time rotation — oracle level (XLA gather) and kernel level
   (fused Pallas, interpret): a canonical-K tile read with
   ``p_off = delta`` equals the same tile pre-rotated at
   ``stored_pos + delta`` and read without rotation.
2. An exact-offset composition (the chain's own segments at their
   original offsets, ``recompute_frac = 0``) serves token-identically
   to the chain path — f32/XLA and bf16/Pallas, drain path.
3. ``recompute_frac = 1.0`` (every spliced token re-prefilled, cached
   copies masked) is token-identical to the chain path too — the dense
   fallback end of the quality-vs-TTFT dial.
4. Cross-cluster splice: a segment cached under one chain composes at a
   DIFFERENT offset into another prompt; the serve runs, the compose
   stats count the spliced/recomputed tokens, and all pins unwind.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import (ComposedSegment, SegmentComposition,
                              recompute_window)
from repro.data.tokenizer import Tokenizer
from repro.kernels import ops
from repro.kernels import ref as R
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope
from repro.serving.engine import Request, ServingEngine

THETA = 10_000.0


# ----------------------------------------------------------------------
# gate 1: read-time rotation == write-time rotation, bitwise
# ----------------------------------------------------------------------
def _rot_arena(k, kpos, delta):
    """Write-time-style rotation of a whole head-major arena at the
    re-based positions (invalid slots keep -1 semantics via eff)."""
    eff = jnp.where(kpos >= 0, kpos + delta, -1)
    return apply_rope(k, eff[:, None, :], THETA), eff


def test_oracle_read_rotation_bitwise_matches_write_rotation():
    """XLA oracle: canonical K + (rope_theta, offsets=delta) must be
    EXACTLY the pre-rotated arena attended without rope — rotation
    commutes with the gather, so the bits agree, not just the values."""
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    nb, hkv, bs, d, hq, tq = 6, 2, 8, 16, 4, 5
    k = jax.random.normal(ks[0], (nb, hkv, bs, d))
    v = jax.random.normal(ks[1], (nb, hkv, bs, d))
    kpos = jnp.arange(nb * bs).reshape(nb, bs) % (4 * bs)
    kpos = jnp.where(jnp.arange(nb)[:, None] == 0, -1, kpos)
    table = jnp.array([[1, 2, 3], [4, 5, 0]], jnp.int32)
    delta = 24
    offs = jnp.full(table.shape, delta, jnp.int32)
    q = jax.random.normal(ks[2], (2, hq, tq, d))
    q_pos = 4 * bs + delta + jnp.broadcast_to(jnp.arange(tq)[None], (2, tq))

    got = R.paged_attention_partial_ref(
        q, k, v, q_pos, kpos, table, causal=True, rope_theta=THETA,
        offsets=offs)
    k_rot, eff = _rot_arena(k, kpos, delta)
    want = R.paged_attention_partial_ref(
        q, k_rot, v, q_pos, eff, table, causal=True)
    for g, w in zip(got, want):
        assert jnp.array_equal(g, w), "oracle read-rotation not bitwise"


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_kernel_read_rotation_bitwise_matches_write_rotation(dtype):
    """Kernel level (fused Pallas cascade, interpret): a prefix tile
    cached CANONICAL at base 0 and rotated by ``p_off`` in-register
    must produce bitwise the output of storing the write-time-rotated
    tile (apply_rope at stored+delta, cast to the arena dtype) and
    reading it without rotation.  The in-kernel recast to the arena
    dtype after rotation is what makes this exact for bf16 arenas."""
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    nb, hkv, bs, d, hq, tq, b = 5, 2, 8, 16, 4, 8, 2
    k = jax.random.normal(ks[0], (nb, hkv, bs, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[1], (nb, hkv, bs, d), jnp.float32).astype(dtype)
    kpos = jnp.arange(nb * bs).reshape(nb, bs) % (3 * bs)
    kpos = jnp.where(jnp.arange(nb)[:, None] == 0, -1, kpos)
    # suffix: one live block per row so the cascade has both legs
    sk = jax.random.normal(ks[2], (3, hkv, bs, d), jnp.float32).astype(dtype)
    sv = jax.random.normal(ks[3], (3, hkv, bs, d), jnp.float32).astype(dtype)
    delta = 40
    skpos = 3 * bs + delta + jnp.arange(3 * bs).reshape(3, bs) % bs
    skpos = jnp.where(jnp.arange(3)[:, None] == 0, -1, skpos)
    ppt = jnp.array([[1, 2, 3], [4, 1, 0]], jnp.int32)
    spt = jnp.array([[1], [2]], jnp.int32)
    p_off = jnp.full(ppt.shape, delta, jnp.int32)
    p_skip = jnp.zeros(ppt.shape, jnp.int32)
    q = jax.random.normal(ks[4], (b, hq, tq, d), jnp.float32).astype(dtype)
    q_pos = 3 * bs + delta + jnp.broadcast_to(jnp.arange(tq)[None], (b, tq))

    got = ops.fused_paged_attention(
        q, k, v, sk, sv, q_pos, kpos, skpos, ppt, spt,
        rope_theta=THETA, p_off=p_off, p_skip=p_skip, prefix_causal=True,
        block_q=8)

    # Gate A (same executable, bitwise at BOTH dtypes): the tile cached
    # at base 0 and offset by delta must equal the tile whose STORED
    # positions already sit at the target (offset 0) — both arms run
    # the identical compiled kernel with identical effective positions,
    # so this is a true bitwise position-independence gate.
    eff = jnp.where(kpos >= 0, kpos + delta, -1)
    shifted = ops.fused_paged_attention(
        q, k, v, sk, sv, q_pos, eff, skpos, ppt, spt,
        rope_theta=THETA, p_off=jnp.zeros_like(p_off), p_skip=p_skip,
        prefix_causal=True, block_q=8)
    assert jnp.array_equal(got, shifted), \
        "fused kernel rotation is not position-independent"

    # Gate B (vs write-time rotation): rotate the cached tile at
    # stored+delta outside the kernel (apply_rope returns the arena
    # dtype), re-base the positions, pre-rotate the suffix at its raw
    # stored positions, and read with rotation OFF.
    k_rot, _ = _rot_arena(k, kpos, delta)
    sk_rot = apply_rope(sk, jnp.where(skpos >= 0, skpos, -1)[:, None, :],
                        THETA)
    want = ops.fused_paged_attention(
        q, k_rot.astype(dtype), v, sk_rot.astype(dtype), sv, q_pos, eff,
        skpos, ppt, spt, prefix_causal=True, block_q=8)
    assert got.dtype == want.dtype
    if dtype == jnp.bfloat16:
        # The arena dtype the Pallas path serves with: the in-kernel
        # recast of the rotated f32 tile to bf16 lands on the same bits
        # as apply_rope's bf16 cast — BITWISE.
        assert jnp.array_equal(got, want), \
            "fused kernel read-rotation not bitwise vs write-time (bf16)"
    else:
        # f32: XLA's FMA contraction differs between the in-kernel
        # fusion and the standalone apply_rope graph (one ulp in
        # k1*cos - k2*sin), so bitwise is not compiler-guaranteed here;
        # gate at a few-ulp tolerance instead.  (Eagerly, _rot_tile and
        # apply_rope ARE bitwise identical — see the oracle test.)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-6, atol=2e-6)


# ----------------------------------------------------------------------
# gates 2-4: end-to-end drain serving
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def tok():
    return Tokenizer.train(["the quick brown fox jumps over the lazy dog "
                            "a graph of nodes and edges answers questions"])


def _cfg(vocab, dtype="float32", impl="xla"):
    return ModelConfig(name="compose-test", family="dense", num_layers=3,
                       d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
                       d_ff=160, vocab_size=vocab, dtype=dtype,
                       attention_impl=impl)


def _engine(tok, key=1, dtype="float32", impl="xla", **kw):
    cfg = _cfg(tok.vocab_size, dtype, impl)
    params = M.init_params(jax.random.PRNGKey(key), cfg)
    kw.setdefault("max_cache_len", 512)
    kw.setdefault("max_new_tokens", 5)
    return ServingEngine(params, cfg, tok, **kw)


def _chain(eng, seg_tokens):
    """Prefill a chain, one state per segment; returns the leaf."""
    st = None
    for toks in seg_tokens:
        if st is None:
            st, _ = eng.prefill_prefix(toks, _record=False)
        else:
            st, _ = eng.prefill_prefix_extension(st, toks, _record=False)
    return st


def _release_chain(leaf):
    for st in leaf.chain():
        st.release()


def _chain_composition(leaf, seg_tokens, frac=0.0):
    """The degenerate composition: the chain's own segments at their
    original offsets, no gaps."""
    segs, off = [], 0
    for st, toks in zip(leaf.chain(), seg_tokens):
        segs.append(ComposedSegment(state=st, target_offset=off,
                                    tokens=tuple(toks)))
        off += len(toks)
    return SegmentComposition(segments=segs, gaps=[], recompute_frac=frac)


@pytest.mark.parametrize("dtype,impl", [("float32", "xla"),
                                        ("bfloat16", "pallas")])
@pytest.mark.parametrize("frac", [0.0, 1.0])
def test_composition_token_identical_to_chain_drain(tok, dtype, impl, frac):
    """Exact-offset compositions (frac=0: pure splice; frac=1: full
    boundary recompute, cached copies masked) serve token-identically
    to the chain path on the drain serve — f32/XLA and bf16/Pallas."""
    eng = _engine(tok, dtype=dtype, impl=impl)
    segs = [tok.encode("a graph of nodes and edges", bos=True),
            tok.encode("the quick brown fox jumps over the lazy dog"),
            tok.encode("answers questions the lazy dog")]
    leaf = _chain(eng, segs)
    sfx = [tok.encode("answers questions"), tok.encode("and edges"),
           tok.encode("the quick"), tok.encode("lazy dog jumps")]
    try:
        want, t = eng.serve([Request(s, leaf) for s in sfx], _record=False)
        assert t["paged"] and "composed" not in t
        comp = _chain_composition(leaf, segs, frac=frac)
        got, t2 = eng.serve([Request(s, composition=comp) for s in sfx],
                            _record=False)
        assert t2["composed"]
        assert got == want, (frac, dtype, impl)
    finally:
        _release_chain(leaf)
    # every pin unwound: the chain's own refcounts are the only
    # remaining references, dropped by release() above
    assert eng.block_pool.blocks_in_use == 0


def test_composition_mixed_batch_and_stats(tok):
    """One batch mixing a composed row, a chain row, and a prefixless
    row; compose stats count the spliced vs recomputed tokens."""
    eng = _engine(tok)
    segs = [tok.encode("a graph of nodes and edges", bos=True),
            tok.encode("the quick brown fox jumps over the lazy dog")]
    leaf = _chain(eng, segs)
    sfx = tok.encode("answers questions")
    comp = _chain_composition(leaf, segs, frac=0.25)
    try:
        outs, t = eng.serve([
            Request(sfx, composition=comp),
            Request(sfx, leaf),
            Request(sfx),
        ])
        assert t["composed"] and len(outs) == 3
        # composed row == chain row: same context, exact offsets
        assert outs[0] == outs[1]
        st = eng.cache_mgr.stats
        assert st.compose_requests == 1
        assert st.compose_segments == 2
        wins = [recompute_window(len(s), 0.25) for s in segs]
        assert st.compose_recomputed_tokens == sum(wins)
        assert st.compose_spliced_tokens == \
            sum(len(s) for s in segs) - sum(wins)
    finally:
        _release_chain(leaf)
    assert eng.block_pool.blocks_in_use == 0


def test_cross_cluster_splice_reuses_foreign_segment(tok):
    """The headline capability: a segment prefilled under cluster A's
    chain (at base != 0) composes into a DIFFERENT prompt at a new
    offset — a reuse the dendrogram chain layout never expressed.  With
    recompute_frac=1.0 the result must equal the chain serve of the
    equivalent fresh chain (full recompute = position-independent by
    construction); with a partial frac the serve must run and the
    savings counters must show the splice."""
    eng = _engine(tok)
    a_root = tok.encode("a graph of nodes and edges", bos=True)
    a_ext = tok.encode("the quick brown fox jumps over the lazy dog")
    leaf_a = _chain(eng, [a_root, a_ext])           # a_ext base = len(a_root)
    seg_a = leaf_a                                   # leaf owns a_ext
    b_root = tok.encode("answers questions the lazy dog", bos=True)
    sfx = tok.encode("answers questions")
    # prompt B: b_root ++ a_ext, with a_ext spliced from cluster A
    comp = SegmentComposition(
        segments=[ComposedSegment(state=seg_a,
                                  target_offset=len(b_root),
                                  tokens=tuple(a_ext))],
        gaps=[(0, list(b_root))], recompute_frac=1.0)
    try:
        got, t = eng.serve([Request(sfx, composition=comp)], _record=False)
        assert t["composed"]
        # oracle: the same prompt served as a fresh chain
        oracle_leaf = _chain(eng, [b_root, a_ext])
        want, _ = eng.serve([Request(sfx, oracle_leaf)], _record=False)
        _release_chain(oracle_leaf)
        assert got == want
        # partial recompute: runs, and the splice saves prefill tokens
        comp2 = SegmentComposition(
            segments=[ComposedSegment(state=seg_a,
                                      target_offset=len(b_root),
                                      tokens=tuple(a_ext))],
            gaps=[(0, list(b_root))], recompute_frac=0.25)
        outs, _ = eng.serve([Request(sfx, composition=comp2)])
        assert len(outs) == 1
        st = eng.cache_mgr.stats
        w = recompute_window(len(a_ext), 0.25)
        assert st.compose_spliced_tokens == len(a_ext) - w > 0
        assert st.compose_recomputed_tokens == w > 0
    finally:
        _release_chain(leaf_a)
    assert eng.block_pool.blocks_in_use == 0


@pytest.mark.parametrize("dtype,impl", [("float32", "xla"),
                                        ("bfloat16", "pallas")])
@pytest.mark.parametrize("frac", [0.0, 1.0])
def test_composition_token_identical_to_chain_continuous(tok, dtype, impl,
                                                         frac):
    """The same identity on the CONTINUOUS path: composed rows admitted
    mid-flight (across two admissions, with chunked decode between)
    emit exactly the chain drain-serve's tokens."""
    from repro.serving.continuous import ContinuousEngine
    eng = _engine(tok, dtype=dtype, impl=impl)
    segs = [tok.encode("a graph of nodes and edges", bos=True),
            tok.encode("the quick brown fox jumps over the lazy dog"),
            tok.encode("answers questions the lazy dog")]
    leaf = _chain(eng, segs)
    sfx = [tok.encode("answers questions"), tok.encode("and edges"),
           tok.encode("the quick"), tok.encode("lazy dog jumps")]
    try:
        want, _ = eng.serve([Request(s, leaf) for s in sfx], _record=False)
        comp = _chain_composition(leaf, segs, frac=frac)
        cont = ContinuousEngine(eng, max_slots=4, chunk=2,
                                max_suffix_len=64)
        cont.admit([Request(s, composition=comp) for s in sfx[:2]],
                   payloads=[0, 1])
        cont.step()
        cont.admit([Request(s, composition=comp) for s in sfx[2:]],
                   payloads=[2, 3])
        cont.flush()
        got = [None] * 4
        for r in cont.pop_retired():
            got[r.payload] = r.tokens
        assert got == want, (frac, dtype, impl)
    finally:
        _release_chain(leaf)
    assert eng.block_pool.blocks_in_use == 0


def test_continuous_mixed_composed_and_chain_rows(tok):
    """One continuous admission mixing a composed row with a plain
    chain row: both must match their drain-serve oracles, and chain
    rows decode with zero offset tables (the degenerate plan)."""
    from repro.serving.continuous import ContinuousEngine
    eng = _engine(tok)
    segs = [tok.encode("a graph of nodes and edges", bos=True),
            tok.encode("the quick brown fox jumps over the lazy dog")]
    leaf = _chain(eng, segs)
    sfx = tok.encode("answers questions")
    try:
        want, _ = eng.serve([Request(sfx, leaf), Request(sfx)],
                            _record=False)
        comp = _chain_composition(leaf, segs, frac=0.25)
        cont = ContinuousEngine(eng, max_slots=4, chunk=2,
                                max_suffix_len=64)
        cont.admit([Request(sfx, composition=comp),
                    Request(sfx, leaf), Request(sfx)],
                   payloads=["comp", "chain", "flat"])
        cont.flush()
        got = {r.payload: r.tokens for r in cont.pop_retired()}
        # composed row == chain row == drain chain serve
        assert got["comp"] == got["chain"] == want[0]
        assert got["flat"] == want[1]
        assert eng.cache_mgr.stats.compose_requests == 1
    finally:
        _release_chain(leaf)
    assert eng.block_pool.blocks_in_use == 0


# ----------------------------------------------------------------------
# quantized pools: composed serving + the dead-row reclaim regression
# ----------------------------------------------------------------------
def test_composed_serve_quantized_pool_accounting(tok):
    """Composition over an int8 prefix arena: the fused read-time
    rotation rides the in-register dequant (no store-dtype recast of a
    dequantized tile), frac=1.0 equals the all-fresh oracle, and — the
    satellite regression — every compute-dtype row the composed serve
    stages through returns to the suffix free list, so resident bytes
    stay exactly the priced layout (no dead full-precision rows)."""
    from repro.serving.continuous import ContinuousEngine
    eng = _engine(tok, quantize_prefix=True)
    pool = eng.block_pool
    a_root = tok.encode("a graph of nodes and edges", bos=True)
    shared = tok.encode("the quick brown fox jumps over the lazy dog")
    b_root = tok.encode("answers questions", bos=True)
    sfx = tok.encode("lazy dog jumps")
    leaf = _chain(eng, [a_root, shared])
    comp = SegmentComposition(
        segments=[ComposedSegment(state=leaf, target_offset=len(b_root),
                                  tokens=tuple(shared))],
        gaps=[(0, list(b_root))], recompute_frac=1.0)
    try:
        got, t = eng.serve([Request(sfx, composition=comp)], _record=False)
        assert t["composed"]
        # frac=1.0 recomputes every spliced token at compute dtype, so
        # the composed row must equal the all-fresh (prefixless) serve
        # of the same token stream — int8 never enters the attended KV
        want, _ = eng.serve([Request(b_root + shared + sfx)],
                            _record=False)
        assert got == want
        # partial frac reads the int8 splice through dequant+rotate
        comp2 = SegmentComposition(
            segments=[ComposedSegment(state=leaf,
                                      target_offset=len(b_root),
                                      tokens=tuple(shared))],
            gaps=[(0, list(b_root))], recompute_frac=0.25)
        outs, _ = eng.serve([Request(sfx, composition=comp2)],
                            _record=False)
        assert len(outs[0]) > 0
        cont = ContinuousEngine(eng, max_slots=2, chunk=2,
                                max_suffix_len=64)
        cont.admit([Request(sfx, composition=comp)], payloads=[0])
        cont.flush()
        assert [r.tokens for r in cont.pop_retired()] == [want[0]]
        # the reclaim regression, on every composed path above: all
        # staging/suffix rows are back, residency is prefix-space only
        assert pool.free_suffix_blocks == pool.suffix_allocator.num_usable
        held = sum(np.asarray(x).nbytes for x in
                   jax.tree_util.tree_leaves(pool.arena)) + \
            sum(np.asarray(x).nbytes for x in
                jax.tree_util.tree_leaves(pool.qarena))
        assert pool.device_bytes == held
        assert pool.prefix_blocks_in_use * pool.prefix_block_bytes == \
            sum(len(st.page.blocks) for st in leaf.chain()) * \
            pool.prefix_block_bytes
    finally:
        _release_chain(leaf)
    assert pool.blocks_in_use == 0


# ----------------------------------------------------------------------
# scheduler + pipeline wiring (content-addressed segment registry)
# ----------------------------------------------------------------------
def _stub_scheduler(eng, chains):
    """An ``OnlineScheduler`` over a stub assigner whose cluster ``i``
    carries chain ``chains[i]`` — each a list of token-id segments.
    ``segment_tokens_fn`` just passes the tokens through, so the test
    controls segment content (and thus registry keys) exactly."""
    from repro.core.planner import ChainSpec
    from repro.core.prefix_pool import PrefixPool
    from repro.serving.scheduler import OnlineCluster, OnlineScheduler

    class _Assigner:
        clusters: list = []

        def representative(self, cid):
            return self.clusters[cid].representative

    asg = _Assigner()
    asg.clusters = [
        OnlineCluster(cluster_id=i, centroid=np.zeros(4, np.float32),
                      representative=None,
                      chain=ChainSpec(
                          keys=[f"c{i}s{j}" for j in range(len(segs))],
                          contents=[list(s) for s in segs]))
        for i, segs in enumerate(chains)]
    return OnlineScheduler(eng, asg, PrefixPool(1 << 28),
                           prefix_tokens_fn=lambda rep: list(rep),
                           segment_tokens_fn=lambda c, b: list(c))


def test_scheduler_composes_cross_cluster_segment(tok):
    """`try_compose` through the content registry: a segment prefilled
    under cluster A's chain is spliced into cluster B's prompt at a
    DIFFERENT offset; with recompute_frac=1.0 the served tokens equal
    the fresh-chain oracle.  Exact-offset-only residency (cluster A
    again) must NOT engage composition — the chain path serves it."""
    from repro.serving.scheduler import Assignment
    eng = _engine(tok)
    a_root = tok.encode("a graph of nodes and edges", bos=True)
    shared = tok.encode("the quick brown fox jumps over the lazy dog")
    b_root = tok.encode("answers questions", bos=True)
    assert len(a_root) != len(b_root)       # the splice is re-based
    sched = _stub_scheduler(eng, [[a_root, shared], [b_root, shared]])
    sched.compose_frac = 1.0
    emb, sgs = [np.zeros(4, np.float32)], [None]
    sfx = [tok.encode("lazy dog jumps")]
    stats = eng.cache_mgr.stats

    # cluster 0 cold: chain path, registry learns both segments
    out_a = sched.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=0, is_new=True, distance=0.0)])
    assert stats.compose_requests == 0
    assert tuple(shared) in sched._seg_registry

    # cluster 0 again: fully resident at exact offsets -> still chain
    out_a2 = sched.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=0, is_new=False, distance=0.0)])
    assert stats.compose_requests == 0
    assert out_a2[0].tokens == out_a[0].tokens

    # cluster 1: b_root is cold (gap) but `shared` is resident at base
    # len(a_root) != len(b_root) -> re-based splice -> composition
    out_b = sched.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=1, is_new=False, distance=0.0)])
    assert stats.compose_requests == 1
    assert out_b[0].prefix_len == len(b_root) + len(shared)
    assert out_b[0].pool_hit

    # oracle: the same prompt served as a fresh chain on a twin engine
    eng2 = _engine(tok)
    leaf = _chain(eng2, [b_root, shared])
    want, _ = eng2.serve([Request(sfx[0], leaf)], _record=False)
    _release_chain(leaf)
    assert out_b[0].tokens == want[0]


def test_scheduler_serve_continuous_composes(tok):
    """The same cross-cluster splice through `serve_continuous`: the
    composed row admits into the in-flight batch, decodes the oracle's
    tokens, and its pins unwind at retirement."""
    from repro.serving.continuous import ContinuousEngine
    from repro.serving.scheduler import Assignment
    eng = _engine(tok)
    a_root = tok.encode("a graph of nodes and edges", bos=True)
    shared = tok.encode("the quick brown fox jumps over the lazy dog")
    b_root = tok.encode("answers questions", bos=True)
    sched = _stub_scheduler(eng, [[a_root, shared], [b_root, shared]])
    sched.compose_frac = 1.0
    emb, sgs = [np.zeros(4, np.float32)], [None]
    sfx = [tok.encode("lazy dog jumps")]
    sched.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=0, is_new=True, distance=0.0)])

    cont = ContinuousEngine(eng, max_slots=4, chunk=2, max_suffix_len=64)
    admitted, _ = sched.serve_continuous(
        cont, emb, sgs, sfx, payloads=["b"], now=0.0, assignments=[
            Assignment(cluster_id=1, is_new=False, distance=0.0)])
    assert eng.cache_mgr.stats.compose_requests == 1
    assert admitted[0].prefix_len == len(b_root) + len(shared)
    cont.flush()
    got = {r.payload.payload: r.tokens for r in cont.pop_retired()}
    eng2 = _engine(tok)
    leaf = _chain(eng2, [b_root, shared])
    want, _ = eng2.serve([Request(sfx[0], leaf)], _record=False)
    _release_chain(leaf)
    assert got["b"] == want[0]


def test_tier_round_trip_composes_identically(tok):
    """Satellite 2: demote → promote carries the per-segment
    base-position metadata (prefix_len/seg_len → base_pos), so a
    promoted segment splices into a composition exactly like the
    never-evicted original — same tokens, same base_pos."""
    from repro.core.prefix_pool import PrefixPool
    from repro.core.tiered import HostTier
    eng = _engine(tok)
    pp = PrefixPool(1 << 28)
    pp.stats = eng.cache_mgr.stats
    pp.attach_block_pool(eng.block_pool)
    pp.attach_host_tier(HostTier(1 << 28))
    a_root = tok.encode("a graph of nodes and edges", bos=True)
    shared = tok.encode("the quick brown fox jumps over the lazy dog")
    b_root = tok.encode("answers questions", bos=True)
    sfx = tok.encode("lazy dog jumps")
    root, _ = eng.prefill_prefix(a_root, _record=False)
    leaf, _ = eng.prefill_prefix_extension(root, shared, _record=False)
    pp.put("root", root)
    pp.put(("seg", "x"), leaf)

    def splice(st):
        return SegmentComposition(
            segments=[ComposedSegment(state=st,
                                      target_offset=len(b_root),
                                      tokens=tuple(shared))],
            gaps=[(0, list(b_root))], recompute_frac=0.25)

    want, _ = eng.serve([Request(sfx, composition=splice(leaf))],
                        _record=False)
    base0, slen0 = leaf.base_pos, leaf.segment_len
    assert pp.demote_to_host(("seg", "x"))
    assert pp.get(("seg", "x")) is None          # device-evicted
    promoted = pp.promote(("seg", "x"), parent=root, pin=True)
    assert promoted is not None
    assert promoted.base_pos == base0
    assert promoted.segment_len == slen0
    got, _ = eng.serve([Request(sfx, composition=splice(promoted))],
                       _record=False)
    assert got == want                           # bitwise host round trip
    pp.release(("seg", "x"))


def test_scheduler_composes_through_promoted_segment(tok):
    """The scheduler's registry lookup promotes a demoted segment back
    for composition: after cluster A's shared segment is demoted to the
    host tier (parent still resident), cluster B's composed serve still
    splices it — and serves the same tokens as before the demote."""
    from repro.core.tiered import HostTier
    from repro.serving.scheduler import Assignment
    eng = _engine(tok)
    a_root = tok.encode("a graph of nodes and edges", bos=True)
    shared = tok.encode("the quick brown fox jumps over the lazy dog")
    b_root = tok.encode("answers questions", bos=True)
    sched = _stub_scheduler(eng, [[a_root, shared], [b_root, shared]])
    sched.compose_frac = 1.0
    sched.pool.attach_host_tier(HostTier(1 << 28))
    emb, sgs = [np.zeros(4, np.float32)], [None]
    sfx = [tok.encode("lazy dog jumps")]
    sched.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=0, is_new=True, distance=0.0)])
    out_b = sched.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=1, is_new=False, distance=0.0)])
    stats = eng.cache_mgr.stats
    assert stats.compose_requests == 1
    # demote the shared segment (the chain leaf) to the host tier
    assert sched.pool.demote_to_host(("seg", "c0s1"))
    out_b2 = sched.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=1, is_new=False, distance=0.0)])
    assert stats.compose_requests == 2           # composed again
    assert stats.tier_promotions >= 1            # via the tier
    assert out_b2[0].tokens == out_b[0].tokens


@pytest.fixture(scope="module")
def scene_pipe():
    from repro.data.scenegraph import generate_scene_graph
    from repro.rag.pipeline import GraphRAGPipeline
    from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
    from repro.rag.text_encoder import TextEncoder
    graph, queries = generate_scene_graph()
    tok2 = Tokenizer.train([q.question + " " + q.answer for q in queries]
                           + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="compose-pipe", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=tok2.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(32))
    pipe = GraphRAGPipeline(
        index=index, retriever=GRetrieverRetriever(index),
        engine=ServingEngine(params, cfg, tok2, max_cache_len=768,
                             max_new_tokens=4),
        tokenizer=tok2, use_soft_prompt=False)
    return pipe, queries[:8]


def test_run_subgcache_compose_frac_one_matches_chain(scene_pipe):
    """Offline compose mode at recompute_frac=1.0 is token-identical to
    the chain tree runner, and the arena returns to its baseline."""
    pipe, items = scene_pipe
    base = pipe.engine.block_pool.blocks_in_use
    recs_chain, _, _, _ = pipe.run_subgcache(items, num_clusters=3,
                                             tree_levels=3)
    recs_comp, summary, _, stats = pipe.run_subgcache(
        items, num_clusters=3, tree_levels=3, compose=True,
        recompute_frac=1.0)
    assert [r.generated for r in recs_comp] == \
        [r.generated for r in recs_chain]
    assert "compose" in summary.name
    assert pipe.engine.block_pool.blocks_in_use == base
    # partial recompute runs end to end and reports splice savings
    recs_p, _, _, stats_p = pipe.run_subgcache(
        items, num_clusters=3, tree_levels=3, compose=True,
        recompute_frac=0.25)
    assert all(r is not None and r.generated is not None for r in recs_p)
    assert pipe.engine.block_pool.blocks_in_use == base


def test_serve_stream_compose_frac_one_matches_plain(scene_pipe):
    """`serve_stream(compose_frac=1.0)` keeps token streams identical to
    the chains-only scheduler on both serving loops (composition only
    reschedules prefill work; at frac=1.0 it recomputes every spliced
    token, so even engaged splices are exact)."""
    pipe, items = scene_pipe
    arr = np.cumsum(np.full(len(items), 0.01))
    kw = dict(max_batch=4, tree_levels=2, tree_clusters=3)
    r0, _, _ = pipe.serve_stream(items, arr, mode="drain", **kw)
    r1, _, s1 = pipe.serve_stream(items, arr, mode="drain",
                                  compose_frac=1.0, **kw)
    assert [r.generated for r in r0] == [r.generated for r in r1]
    assert s1.compose_frac == 1.0
    rc, _, _ = pipe.serve_stream(items, arr, mode="continuous", chunk=2,
                                 compose_frac=1.0, **kw)
    assert [r.generated for r in rc] == [r.generated for r in r0]


def test_composition_validation():
    """Span tiling is enforced: overlaps, holes, and empty spans are
    construction errors, not serving surprises."""
    with pytest.raises(AssertionError):
        SegmentComposition(segments=[], gaps=[(1, [5, 6])])
    with pytest.raises(AssertionError):
        SegmentComposition(segments=[], gaps=[(0, [])])
    c = SegmentComposition(segments=[], gaps=[(0, [1, 2, 3])])
    assert c.total_len == 3
    assert c.fresh_spans() == [(0, [1, 2, 3])]
    assert c.spliced_tokens() == 0


# ----------------------------------------------------------------------
# drift-scored selective recomputation (DESIGN.md §15)
# ----------------------------------------------------------------------
def _drift_composition(eng, leaf, seg_tokens, budget, probe):
    """The chain's own segments at exact offsets, masks from the
    engine's layer-0 drift probe at ``budget`` tokens per segment."""
    segs, off = [], 0
    for st, toks in zip(leaf.chain(), seg_tokens):
        segs.append(ComposedSegment(state=st, target_offset=off,
                                    tokens=tuple(toks)))
        off += len(toks)
    comp = SegmentComposition(segments=segs, gaps=[],
                              block_size=eng.block_size)
    comp.apply_drift(eng.drift_scores(comp, probe), budget)
    return comp


@pytest.mark.parametrize("dtype,impl", [("float32", "xla"),
                                        ("bfloat16", "pallas")])
def test_drift_budget_seg_len_identical_to_chain(tok, dtype, impl):
    """Property (a): ``recompute_budget >= seg_len`` selects every
    block, making the drift path the same executable plan as
    ``recompute_frac=1.0`` — and both token-identical to the chain
    serve — on the drain AND continuous paths, f32/XLA and bf16/Pallas."""
    from repro.serving.continuous import ContinuousEngine
    eng = _engine(tok, dtype=dtype, impl=impl, block_size=4)
    segs = [tok.encode("a graph of nodes and edges", bos=True),
            tok.encode("the quick brown fox jumps over the lazy dog"),
            tok.encode("answers questions the lazy dog")]
    leaf = _chain(eng, segs)
    sfx = [tok.encode("answers questions"), tok.encode("lazy dog jumps")]
    budget = max(len(s) for s in segs)
    try:
        want, _ = eng.serve([Request(s, leaf) for s in sfx], _record=False)
        comp = _drift_composition(eng, leaf, segs, budget, sfx[0])
        for s in comp.segments:     # budget >= seg_len: every block
            nb = (len(s.tokens) + 3) // 4
            assert s.recompute_blocks == tuple(range(nb))
        got, t = eng.serve([Request(s, composition=comp) for s in sfx],
                           _record=False)
        assert t["composed"] and got == want, (dtype, impl)
        frac1 = _chain_composition(leaf, segs, frac=1.0)
        got1, _ = eng.serve([Request(s, composition=frac1) for s in sfx],
                            _record=False)
        assert got1 == want
        cont = ContinuousEngine(eng, max_slots=4, chunk=2,
                                max_suffix_len=64)
        cont.admit([Request(s, composition=comp) for s in sfx],
                   payloads=[0, 1])
        cont.flush()
        gotc = [None, None]
        for r in cont.pop_retired():
            gotc[r.payload] = r.tokens
        assert gotc == want, (dtype, impl)
    finally:
        _release_chain(leaf)
    assert eng.block_pool.blocks_in_use == 0


@pytest.mark.parametrize("dtype,impl", [("float32", "xla"),
                                        ("bfloat16", "pallas")])
def test_drift_partial_budget_stats_reconcile(tok, dtype, impl):
    """Property (c): with a partial budget the serve runs on both
    paths and the drift gauges reconcile exactly against the masks —
    ``compose_drift_tokens`` (block accounting incl. the ragged tail)
    equals ``compose_recomputed_tokens``, one drift splice per spliced
    segment, positive covered score."""
    from repro.core.cache import masked_block_tokens
    from repro.serving.continuous import ContinuousEngine
    eng = _engine(tok, dtype=dtype, impl=impl, block_size=4)
    segs = [tok.encode("a graph of nodes and edges", bos=True),
            tok.encode("the quick brown fox jumps over the lazy dog")]
    leaf = _chain(eng, segs)
    sfx = tok.encode("answers questions")
    comp = _drift_composition(eng, leaf, segs, 4, sfx)   # 1 block/segment
    expect = sum(masked_block_tokens(len(s.tokens), s.recompute_blocks, 4)
                 for s in comp.segments)
    assert 0 < expect < sum(len(s) for s in segs)        # a real subset
    try:
        outs, t = eng.serve([Request(sfx, composition=comp)])
        assert t["composed"] and len(outs) == 1
        st = eng.cache_mgr.stats
        assert st.compose_drift_splices == len(segs)
        assert st.compose_drift_tokens == expect
        assert st.compose_recomputed_tokens == expect
        assert st.compose_spliced_tokens == \
            sum(len(s) for s in segs) - expect
        assert st.compose_drift_score > 0.0
        cont = ContinuousEngine(eng, max_slots=2, chunk=2,
                                max_suffix_len=64)
        cont.admit([Request(sfx, composition=comp)], payloads=[0])
        cont.flush()
        assert len(cont.pop_retired()) == 1
        assert st.compose_drift_tokens == 2 * expect     # both paths
        assert st.compose_recomputed_tokens == 2 * expect
    finally:
        _release_chain(leaf)
    assert eng.block_pool.blocks_in_use == 0


def test_drift_quantized_pool_budget_identity(tok):
    """The frac=1.0 anchor holds over an int8 prefix arena too:
    budget >= seg_len masks every cached (quantized) block, so the
    composed serve equals the all-fresh serve bitwise."""
    eng = _engine(tok, quantize_prefix=True, block_size=4)
    a_root = tok.encode("a graph of nodes and edges", bos=True)
    shared = tok.encode("the quick brown fox jumps over the lazy dog")
    sfx = tok.encode("lazy dog jumps")
    leaf = _chain(eng, [a_root, shared])
    try:
        comp = _drift_composition(eng, leaf, [a_root, shared],
                                  len(shared), sfx)
        got, t = eng.serve([Request(sfx, composition=comp)], _record=False)
        assert t["composed"]
        want, _ = eng.serve([Request(a_root + shared + sfx)],
                            _record=False)
        assert got == want
    finally:
        _release_chain(leaf)
    assert eng.block_pool.blocks_in_use == 0


# ----------------------------------------------------------------------
# gap-span caching + registry invalidation + admission (DESIGN.md §15)
# ----------------------------------------------------------------------
def test_gap_span_cached_and_respliced(tok):
    """Satellite 1: the cold gap a composed serve prefills is captured
    into content-addressed blocks; the SAME cluster's next arrival
    splices the gap instead of recomputing it — token-identically —
    and a duplicate capture is declined."""
    from repro.serving.scheduler import Assignment
    eng = _engine(tok, block_size=4)
    a_root = tok.encode("a graph of nodes and edges", bos=True)
    shared = tok.encode("the quick brown fox jumps over the lazy dog")
    b_root = tok.encode("answers questions over the dog", bos=True)
    assert len(b_root) >= 4                  # above gap_min_tokens
    sched = _stub_scheduler(eng, [[a_root, shared], [b_root, shared]])
    sched.compose_frac = 1.0
    emb, sgs = [np.zeros(4, np.float32)], [None]
    sfx = [tok.encode("lazy dog jumps")]
    stats = eng.cache_mgr.stats
    sched.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=0, is_new=True, distance=0.0)])
    assert stats.gap_spans_cached == 0       # chain path: no gaps
    out1 = sched.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=1, is_new=False, distance=0.0)])
    assert stats.compose_requests == 1
    assert stats.compose_segments == 1       # only `shared` spliced
    assert stats.gap_spans_cached == 1       # b_root captured
    assert stats.gap_tokens_cached == len(b_root)
    assert tuple(b_root) in sched._seg_registry
    out2 = sched.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=1, is_new=False, distance=0.0)])
    assert stats.compose_requests == 2
    assert stats.compose_segments == 3       # b_root AND shared spliced
    assert out2[0].tokens == out1[0].tokens  # gap splice is exact
    assert stats.gap_spans_cached == 1       # no duplicate capture
    sched.pool.clear()                       # hard-evict everything …
    assert sched._seg_registry == {}         # … registry fully retracts
    assert eng.block_pool.blocks_in_use == 0


def test_hard_evicted_registry_entry_never_splices(tok):
    """Satellite 3 regression: a segment hard-evicted from the pool (no
    host tier) must drop out of the content registry via the
    ``on_hard_evict`` hook — a later compose plan treats the content as
    cold instead of dereferencing recycled blocks."""
    from repro.serving.scheduler import Assignment
    eng = _engine(tok, block_size=4)
    a_root = tok.encode("a graph of nodes and edges", bos=True)
    shared = tok.encode("the quick brown fox jumps over the lazy dog")
    b_root = tok.encode("answers questions", bos=True)
    sched = _stub_scheduler(eng, [[a_root, shared], [b_root, shared]])
    sched.compose_frac = 1.0
    emb, sgs = [np.zeros(4, np.float32)], [None]
    sfx = [tok.encode("lazy dog jumps")]
    sched.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=0, is_new=True, distance=0.0)])
    assert tuple(shared) in sched._seg_registry
    # hard-evict the shared segment (leaf first: it is unanchored)
    assert sched.pool._evict_entry(sched.pool.entry(("seg", "c0s1")))
    assert tuple(shared) not in sched._seg_registry
    # composition finds nothing spliceable -> chain path, correct serve
    assert sched.try_compose(1) is None
    out_b = sched.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=1, is_new=False, distance=0.0)])
    assert eng.cache_mgr.stats.compose_requests == 0
    eng2 = _engine(tok)
    leaf = _chain(eng2, [b_root, shared])
    want, _ = eng2.serve([Request(sfx[0], leaf)], _record=False)
    _release_chain(leaf)
    assert out_b[0].tokens == want[0]


def test_admission_cost_model_declines_repeat_heavy(tok):
    """Satellite: composition-aware admission.  On a repeat-heavy trace
    the "cost" policy declines the engage (chain prefills once, repeats
    are pool hits) and ends with FEWER total prefill tokens than the
    greedy policy, which pays gap + recompute on every arrival."""
    from repro.serving.scheduler import Assignment
    a_root, shared, b_root, sfx = None, None, None, None

    def run(policy):
        nonlocal a_root, shared, b_root, sfx
        eng = _engine(tok, block_size=4)
        a_root = tok.encode("a graph of nodes and edges", bos=True)
        shared = tok.encode("the quick brown fox jumps over the lazy dog")
        b_root = tok.encode("answers questions", bos=True)
        sfx = [tok.encode("lazy dog")]
        sched = _stub_scheduler(eng, [[a_root, shared], [b_root, shared]])
        sched.compose_frac = 1.0          # every engage recomputes all
        sched.compose_admission = policy
        # gap capture off: isolate the admission decision itself
        eng.gap_admit = None
        emb, sgs = [np.zeros(4, np.float32)], [None]
        st = eng.cache_mgr.stats
        total = 0

        def serve(cid, is_new):
            # bench-style accounting: chain prefills land in
            # prefix_tokens_computed; a composed row computes its
            # prefix_len minus whatever it spliced from cache
            nonlocal total
            p0, s0, c0 = (st.prefix_tokens_computed,
                          st.compose_spliced_tokens, st.compose_requests)
            q = sched.serve_batch(emb, sgs, sfx, assignments=[
                Assignment(cluster_id=cid, is_new=is_new,
                           distance=0.0)])[0]
            t = (st.prefix_tokens_computed - p0) + len(sfx[0])
            if st.compose_requests > c0:
                t += q.prefix_len - (st.compose_spliced_tokens - s0)
            total += t
            return q.tokens

        serve(0, True)
        outs = [serve(1, False)
                for _ in range(4)]        # repeat-heavy: B over and over
        return outs, st, total

    outs_g, st_g, toks_greedy = run("greedy")
    outs_c, st_c, toks_cost = run("cost")
    assert outs_g == outs_c               # policy changes cost, not tokens
    assert st_g.compose_declines == 0 and st_g.compose_requests == 4
    assert st_c.compose_declines >= 1     # at least one refused engage
    assert st_c.compose_requests < 4
    assert toks_cost < toks_greedy        # the decline paid off


def test_shared_index_cross_replica_splice(tok):
    """Satellite 2: a registry miss on one replica fetches the segment
    another replica holds through the shared content index + host-tier
    transport, promotes it locally, and the composed serve is
    token-identical to a fresh local chain."""
    from repro.core.tiered import HostTier
    from repro.serving.router import SharedSegmentIndex
    from repro.serving.scheduler import Assignment
    shared = tok.encode("the quick brown fox jumps over the lazy dog",
                        bos=True)
    b_root = tok.encode("answers questions", bos=True)
    sfx = [tok.encode("lazy dog jumps")]
    emb, sgs = [np.zeros(4, np.float32)], [None]
    eng0, eng1 = _engine(tok, block_size=4), _engine(tok, block_size=4)
    s0 = _stub_scheduler(eng0, [[shared]])
    s1 = _stub_scheduler(eng1, [[shared], [b_root, shared]])
    for s in (s0, s1):
        s.compose_frac = 1.0
        s.pool.attach_host_tier(HostTier(1 << 28))
    idx = SharedSegmentIndex()
    s0.shared_index = idx
    s1.shared_index = idx
    # replica 0 prefills `shared` (a root segment) and publishes it
    s0.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=0, is_new=True, distance=0.0)])
    assert tuple(shared) not in s1._seg_registry
    # replica 1 composes cluster 1: local miss -> cross-replica fetch
    out = s1.serve_batch(emb, sgs, sfx, assignments=[
        Assignment(cluster_id=1, is_new=False, distance=0.0)])
    assert idx.fetches == 1
    assert eng1.cache_mgr.stats.compose_requests == 1
    assert eng1.cache_mgr.stats.tier_promotions == 1
    # move semantics: the source no longer resolves the content
    assert tuple(shared) not in s0._seg_registry
    assert s1._seg_registry[tuple(shared)] == ("seg", "c0s0")
    # token-identical to a fresh local chain of the same prompt
    eng2 = _engine(tok)
    leaf = _chain(eng2, [b_root, shared])
    want, _ = eng2.serve([Request(sfx[0], leaf)], _record=False)
    _release_chain(leaf)
    assert out[0].tokens == want[0]
