"""Serving engine + pipeline integration: the SubGCache exactness
invariants and metric accounting."""
import jax
import numpy as np
import pytest

from repro.data.scenegraph import generate_scene_graph
from repro.data.tokenizer import Tokenizer
from repro.gnn.graph_transformer import (apply_graph_transformer,
                                         init_graph_transformer)
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.rag.pipeline import GraphRAGPipeline
from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
from repro.rag.text_encoder import TextEncoder
from repro.core.cache import PrefixState
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def setup():
    graph, queries = generate_scene_graph()
    tok = Tokenizer.train(
        [q.question + " " + q.answer for q in queries] + graph.node_text,
        max_vocab=2048)
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    idx = RetrieverIndex.build(graph, TextEncoder(48))
    gnn = init_graph_transformer(jax.random.PRNGKey(1), 48, 48, 2, 4)
    eng = ServingEngine(params, cfg, tok, max_cache_len=512,
                        max_new_tokens=6)
    pipe = GraphRAGPipeline(index=idx, retriever=GRetrieverRetriever(idx),
                            engine=eng, tokenizer=tok, gnn_params=gnn,
                            gnn_apply=apply_graph_transformer,
                            use_soft_prompt=False)
    return graph, queries, pipe


def test_broadcast_copies_only_when_aliased(monkeypatch):
    """Satellite regression: ``PrefixState.broadcast`` used to
    ``jnp.copy`` EVERY leaf even when ``broadcast_to``/``astype``
    already materialized a fresh buffer — doubling the write traffic of
    every stateful-fallback broadcast.  Now the copy happens only when
    the no-op broadcast would alias the (donated) source buffers."""
    import jax.numpy as jnp
    import repro.core.cache as cache_mod

    copies = []
    real_copy = jnp.copy

    class _JnpProxy:
        def __getattr__(self, name):
            if name == "copy":
                def counted(x):
                    copies.append(x.shape)
                    return real_copy(x)
                return counted
            return getattr(jnp, name)

    monkeypatch.setattr(cache_mod, "jnp", _JnpProxy())
    src = {"state": jnp.arange(8, dtype=jnp.float32).reshape(1, 8),
           "conv": jnp.ones((1, 4), jnp.float32)}
    st = PrefixState(cache=src, prefix_len=3, capacity=8)

    # expansion to a member batch: broadcast_to materializes, NO copy
    template = jax.eval_shape(
        lambda: {"state": jnp.zeros((4, 8), jnp.float32),
                 "conv": jnp.zeros((4, 4), jnp.float32)})
    out = st.broadcast(template)
    assert copies == [], "expanding broadcast must not add a second copy"
    np.testing.assert_array_equal(np.asarray(out["state"]),
                                  np.tile(np.asarray(src["state"]), (4, 1)))

    # same-shape template: broadcast_to would ALIAS -> must copy
    template1 = jax.eval_shape(
        lambda: {"state": jnp.zeros((1, 8), jnp.float32),
                 "conv": jnp.zeros((1, 4), jnp.float32)})
    out1 = st.broadcast(template1)
    assert len(copies) == 2              # one per leaf
    assert out1["state"].unsafe_buffer_pointer() \
        != src["state"].unsafe_buffer_pointer()


def test_singleton_subgcache_equals_baseline(setup):
    """c = m reduces SubGCache to vanilla RAG — generations must match."""
    _, queries, pipe = setup
    items = queries[:6]
    rb, _ = pipe.run_baseline(items)
    rs, _, plan, _ = pipe.run_subgcache(items, num_clusters=len(items))
    assert len(plan.clusters) == len(items)
    for a, b in zip(rb, rs):
        assert a.generated == b.generated


def test_prefix_reuse_is_exact_across_batch_sizes(setup):
    """Members served via broadcast prefix must equal 1-by-1 serving."""
    _, queries, pipe = setup
    items = queries[10:14]
    # all four share one cluster
    rs, _, plan, _ = pipe.run_subgcache(items, num_clusters=1)
    assert len(plan.clusters) == 1
    # serve each against the same representative individually
    rep = plan.clusters[0].representative
    prefix = pipe.tokenizer.encode(pipe.prefix_text(rep), bos=True)
    state, _ = pipe.engine.prefill_prefix(prefix)
    for k, it in enumerate(items):
        suffix = pipe.tokenizer.encode(pipe.suffix_text(it.question))
        outs, _ = pipe.engine.generate_with_prefix(state, [suffix])
        got = pipe.tokenizer.decode(outs[0])
        assert got == rs[k].generated, (k, got, rs[k].generated)


def test_metrics_ordering(setup):
    _, queries, pipe = setup
    items = queries[:5]
    recs, summary, _, stats = pipe.run_subgcache(items, num_clusters=2)
    for r in recs:
        assert r.pftt <= r.ttft <= r.rt + 1e-12
    assert stats.prefill_savings >= 1.0
    assert stats.num_queries == len(items)
    assert summary.num_queries == len(items)


def test_cluster_wise_release(setup):
    """After a batch, no prefix state may stay live (paper's release)."""
    _, queries, pipe = setup
    pipe.run_subgcache(queries[:6], num_clusters=2)
    assert pipe.engine.cache_mgr.live_state is None


def test_generate_stops_at_eos():
    tok = Tokenizer.train(["a b c"])
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, tok, max_cache_len=64, max_new_tokens=5)
    out, _ = eng.generate(tok.encode("a b", bos=True))
    assert len(out) <= 5
