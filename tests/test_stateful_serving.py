"""Recurrent-state architectures in the serving engine: prefix-state
reuse must be exact (no pad token may enter the scan state)."""
import jax
import pytest

from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def tok():
    return Tokenizer.train(["the quick brown fox jumps over the lazy dog "
                            "a b c d e f g shared prefix question answer"])


def _engine(cfg, tok):
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return ServingEngine(params, cfg, tok, max_cache_len=512,
                         max_new_tokens=6)


@pytest.mark.parametrize("family_kw", [
    dict(family="ssm", num_layers=2, d_model=64, num_heads=0,
         num_kv_heads=0, d_ff=0, ssm_state=8),
    dict(family="hybrid", num_layers=3, d_model=64, num_heads=4,
         num_kv_heads=1, d_ff=128,
         block_pattern=("rglru", "rglru", "attn_local"), local_window=16),
])
def test_stateful_prefix_reuse_exact(family_kw, tok):
    cfg = ModelConfig(name="t", vocab_size=tok.vocab_size, dtype="float32",
                      **family_kw)
    eng = _engine(cfg, tok)
    assert eng._stateful
    prefix = tok.encode("shared prefix a b c d e f g", bos=True)
    suffixes = [tok.encode("question the quick answer"),
                tok.encode("question lazy answer"),          # ragged length
                tok.encode("question brown fox jumps answer")]
    state, _ = eng.prefill_prefix(prefix)
    outs, _ = eng.generate_with_prefix(state, suffixes)
    for sfx, got in zip(suffixes, outs):
        ref, _ = eng.generate(prefix + sfx)
        assert ref == got, (tok.decode(sfx), tok.decode(ref), tok.decode(got))


def test_attention_arch_not_stateful(tok):
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32")
    eng = _engine(cfg, tok)
    assert not eng._stateful
