"""MoE sort-dispatch vs dense oracle; capacity semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis; "
                           "pip install -e '.[test]'")
from hypothesis import given, settings, strategies as st

from repro.models import moe as moe_lib

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,s,d,e,k,f", [
    (1, 8, 16, 4, 1, 32), (2, 17, 32, 4, 2, 64), (3, 5, 16, 8, 2, 32),
    (2, 1, 16, 4, 2, 32),          # decode shape S=1
])
def test_moe_matches_dense_oracle(b, s, d, e, k, f):
    p = moe_lib.init_moe(KEY, d, f, e, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(b * 7 + s), (b, s, d))
    out, aux = moe_lib.apply_moe(p, x, top_k=k, capacity_factor=float(e))
    ref = moe_lib.apply_moe_dense_oracle(p, x, top_k=k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)
    assert np.isfinite(float(aux))


def test_moe_dense_residual():
    p = moe_lib.init_moe(KEY, 16, 32, 4, jnp.float32, dense_residual_d_ff=24)
    x = jax.random.normal(KEY, (2, 6, 16))
    out, _ = moe_lib.apply_moe(p, x, top_k=2, capacity_factor=4.0)
    ref = moe_lib.apply_moe_dense_oracle(p, x, top_k=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_moe_capacity_drops_reduce_output():
    """With capacity 0-ish factor, overflow tokens are dropped (output 0
    contribution), never corrupted."""
    d, e, f = 16, 4, 32
    p = moe_lib.init_moe(KEY, d, f, e, jnp.float32)
    x = jax.random.normal(KEY, (1, 64, d))
    tight, _ = moe_lib.apply_moe(p, x, top_k=2, capacity_factor=0.05)
    loose, _ = moe_lib.apply_moe(p, x, top_k=2, capacity_factor=8.0)
    assert np.all(np.isfinite(np.asarray(tight)))
    # tight capacity must zero-out some tokens' expert contributions
    diff = np.abs(np.asarray(tight) - np.asarray(loose)).max()
    assert diff > 0


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 12))
def test_moe_aux_loss_bounds(b, s):
    p = moe_lib.init_moe(KEY, 8, 16, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(s), (b, s, 8))
    _, aux = moe_lib.apply_moe(p, x, top_k=2)
    # Switch aux loss: >= 1 at perfect balance... actually >= it is ~1 when
    # uniform; bounded by E when fully collapsed
    assert 0.0 < float(aux) <= 4.0 + 1e-6
