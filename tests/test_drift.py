"""Drift-probe kernel vs oracle (DESIGN.md §15).

``drift_probe`` (kernels/fused_cascade.py) accumulates per-key causal
attention mass in two grid phases (online-softmax normalizer scan, then
a revisit pass that emits normalized mass per key block).  The oracle
``drift_mass_ref`` computes the same quantity densely.  The two round
differently — the kernel applies the normalizer per revisited block
with the FINAL (m, l), the oracle normalizes a dense row — so the gate
is allclose, not bitwise (same contract as the fused serving kernels).

Also pins the pure-python selection semantics the scores feed
(``select_drift_blocks``): budget quantization UP to whole blocks,
budget >= seg_len selecting everything (the frac=1.0 identity anchor),
and the tie-break that keeps the fixed leading window a subset of the
drift mask when scores tie.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import masked_block_tokens, select_drift_blocks
from repro.kernels.fused_cascade import drift_probe
from repro.kernels.ref import drift_mass_ref

jax.config.update("jax_platform_name", "cpu")


def _mk(key, hq, hkv, tq, s, d, dtype=jnp.float32):
    kq, kk = jax.random.split(jax.random.PRNGKey(key))
    q = (jax.random.normal(kq, (hq, tq, d)) * 0.7).astype(dtype)
    k = (jax.random.normal(kk, (hkv, s, d)) * 0.7).astype(dtype)
    return q, k


@pytest.mark.parametrize("hq,hkv,tq,s,d,block_k", [
    (8, 2, 6, 48, 16, 16),      # GQA g=4, S a multiple of block_k
    (4, 4, 3, 37, 8, 16),       # MHA, ragged S (kernel pads the tail)
    (6, 2, 1, 9, 32, 4),        # single probe query, tiny blocks
    (8, 1, 11, 130, 8, 128),    # MQA, S just past one block
])
def test_drift_probe_matches_oracle(hq, hkv, tq, s, d, block_k):
    q, k = _mk(3 + s, hq, hkv, tq, s, d)
    # fresh tokens sit AFTER most keys: probe positions interleave with
    # the key tail so the causal mask actually cuts (not all-visible)
    k_pos = jnp.arange(s, dtype=jnp.int32)
    q_pos = jnp.linspace(s // 3, s + 4, tq).astype(jnp.int32)
    got = drift_probe(q, k, q_pos, k_pos, block_k=block_k)
    want = drift_mass_ref(q, k, q_pos, k_pos)
    assert got.shape == (s,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_drift_probe_padding_and_masked_rows():
    """Padding keys (k_pos == -1), padding queries (q_pos == -1), and a
    query older than every key all contribute exactly zero mass."""
    q, k = _mk(11, 4, 2, 5, 40, 16)
    k_pos = jnp.where(jnp.arange(40) < 33, jnp.arange(40), -1)
    k_pos = k_pos.astype(jnp.int32)
    # rows: two padding probes, three real ones
    q_pos = jnp.asarray([-1, -1, 10, 20, 40], jnp.int32)
    got = np.asarray(drift_probe(q, k, q_pos, k_pos, block_k=16))
    want = np.asarray(drift_mass_ref(q, k, q_pos, k_pos))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert np.all(got[33:] == 0.0)    # padding keys: exactly zero
    # each live probe row distributes exactly 1.0 per query head over
    # its visible keys; 3 live rows x 4 query heads
    np.testing.assert_allclose(got.sum(), 3 * 4, rtol=1e-5)


def test_drift_probe_bf16_inputs():
    q, k = _mk(7, 8, 2, 4, 64, 16, dtype=jnp.bfloat16)
    k_pos = jnp.arange(64, dtype=jnp.int32)
    q_pos = jnp.asarray([30, 45, 60, 63], jnp.int32)
    got = drift_probe(q, k, q_pos, k_pos, block_k=32)
    want = drift_mass_ref(q, k, q_pos, k_pos)
    # bf16 scores, f32 accumulation in both paths
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ----------------------------------------------------------------------
# selection semantics (pure python, no kernel)
# ----------------------------------------------------------------------
def test_select_drift_blocks_budget_quantization():
    scores = [0.1, 5.0, 0.2, 3.0]          # 4 blocks, bs=8, seg_len=29
    # 1 token of budget still buys a whole block (the top scorer)
    assert select_drift_blocks(scores, 1, 29, 8) == (1,)
    # 9 tokens -> ceil to 2 blocks: the two top scorers, index-sorted
    assert select_drift_blocks(scores, 9, 29, 8) == (1, 3)
    # budget >= seg_len selects everything (frac=1.0 identity anchor)
    assert select_drift_blocks(scores, 29, 29, 8) == (0, 1, 2, 3)
    assert select_drift_blocks(scores, 10_000, 29, 8) == (0, 1, 2, 3)
    # zero budget recomputes nothing
    assert select_drift_blocks(scores, 0, 29, 8) == ()


def test_select_drift_blocks_tie_break_is_leading():
    """Equal scores select LEADING blocks first, so at equal budget the
    drift mask always CONTAINS the fixed leading window's blocks — the
    containment property the issue's test checklist names."""
    scores = [1.0, 1.0, 1.0, 1.0, 1.0]
    assert select_drift_blocks(scores, 16, 40, 8) == (0, 1)
    assert select_drift_blocks(scores, 17, 40, 8) == (0, 1, 2)
    # a genuinely hotter tail block still wins over a cold leading one
    assert select_drift_blocks([0.0, 1.0, 1.0, 2.0, 1.0], 8, 40, 8) \
        == (3,)


def test_masked_block_tokens_counts_tail_block():
    # full blocks count block_size, the tail block only its live tokens
    assert masked_block_tokens(29, (0, 3), 8) == 8 + 5
    assert masked_block_tokens(32, (0, 3), 8) == 16
    assert masked_block_tokens(29, (), 8) == 0
