"""Host-memory tier for the prefix cache (DESIGN.md §12): demote →
promote block round trips are bitwise (f32 and int8+scales), serving a
promoted segment is token-identical to the never-evicted and recomputed
arms (flat and chain, drain and continuous, f32/XLA and bf16/Pallas),
speculative prefetch is counted honestly, injected faults
(``device_put`` failure, ``OutOfBlocks``, a demote-vs-pin race) unwind
without phantom references, and quantized prefix staging rows return to
the suffix free list (the dead-row reclaim regression)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import CacheStats
from repro.core.paged import KVBlockPool, OutOfBlocks
from repro.core.prefix_pool import PrefixPool, state_bytes
from repro.core.tiered import HostSegment, HostTier
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.engine import Request, ServingEngine


def _gqa_cfg(vocab=64, dtype="float32", impl="xla"):
    return ModelConfig(name="tier-test", family="dense", num_layers=3,
                       d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
                       d_ff=160, vocab_size=vocab, dtype=dtype,
                       attention_impl=impl)


@pytest.fixture(scope="module")
def tok():
    return Tokenizer.train(["the quick brown fox jumps over the lazy dog "
                            "a graph of nodes and edges answers questions"])


def _engine(tok, key=0, dtype="float32", impl="xla", **kw):
    cfg = _gqa_cfg(tok.vocab_size, dtype, impl)
    params = M.init_params(jax.random.PRNGKey(key), cfg)
    kw.setdefault("max_cache_len", 512)
    kw.setdefault("max_new_tokens", 5)
    return ServingEngine(params, cfg, tok, **kw)


def _filled_dense(cfg, P, C=32):
    dense = M.init_cache(cfg, 1, C)

    def fill(path, x):
        if path[-1].key == "pos":
            seq = jnp.arange(x.shape[-1])
            return jnp.broadcast_to(jnp.where(seq < P, seq, -1), x.shape)
        return jnp.arange(x.size, dtype=jnp.float32).reshape(
            x.shape).astype(x.dtype) / x.size
    return jax.tree_util.tree_map_with_path(fill, dense)


def _tiered_pool(eng, pool_budget=1 << 30, tier_budget=1 << 30):
    pool = PrefixPool(pool_budget, eng.cache_mgr.stats)
    pool.attach_block_pool(eng.block_pool)
    pool.attach_host_tier(HostTier(tier_budget))
    return pool


# ----------------------------------------------------------------------
# block-level round trip: demote → promote is bitwise
# ----------------------------------------------------------------------
@pytest.mark.parametrize("dtype,quantize", [("float32", False),
                                            ("bfloat16", False),
                                            ("float32", True)])
def test_demote_promote_round_trip_bitwise(dtype, quantize):
    """The host copy and the re-promoted arena rows must be BITWISE the
    rows that were demoted — K/V, positions, and (when quantized) the
    int8 codes plus their f32 scales.  Token identity downstream rests
    entirely on this."""
    cfg = _gqa_cfg(dtype=dtype)
    pool = KVBlockPool(cfg, num_blocks=16, block_size=8,
                       quantize_prefix=quantize)
    P = 19
    pt = pool.write_prefix(_filled_dense(cfg, P), P)
    host, nbytes, toks = pool.demote_blocks(pt.blocks)
    assert nbytes == sum(x.nbytes for x in jax.tree_util.tree_leaves(host))
    assert sum(toks) == P
    if quantize:    # scales travel with the segment
        assert any("scale" in jax.tree_util.keystr(p) for p, _ in
                   jax.tree_util.tree_leaves_with_path(host))
    pool.decref(pt.blocks)
    bids, transfer = pool.promote_blocks(host, toks)
    jax.block_until_ready(transfer)
    host2, nbytes2, toks2 = pool.demote_blocks(bids)
    assert nbytes2 == nbytes and toks2 == toks
    for a, b in zip(jax.tree_util.tree_leaves(host),
                    jax.tree_util.tree_leaves(host2)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a.view(np.uint8), b.view(np.uint8))
    pool.decref(bids)
    assert pool.blocks_in_use == 0


# ----------------------------------------------------------------------
# engine-level: tokens identical across never-evicted / promoted /
# recomputed, flat
# ----------------------------------------------------------------------
@pytest.mark.parametrize("dtype,impl", [("float32", "xla"),
                                        ("bfloat16", "pallas")])
def test_flat_promote_and_recompute_tokens_identical(tok, dtype, impl):
    eng = _engine(tok, dtype=dtype, impl=impl)
    stats = eng.cache_mgr.stats
    pool = _tiered_pool(eng)
    tier = pool.tier
    prefix = tok.encode("the quick brown fox jumps over the lazy dog "
                        + "a graph of nodes " * 30, bos=True)
    sfx = [tok.encode("answers questions"), tok.encode("and edges")]
    st, dt = eng.prefill_prefix(prefix, _record=False)
    pool.put("c", st, prefill_s=dt)
    oracle, t = eng.serve([Request(s, st) for s in sfx], _record=False)
    assert t["paged"]

    # arm 2: evict (→ demote), promote back, serve
    pool.budget_bytes = 1
    pool._evict_to_budget()
    assert "c" not in pool and "c" in tier
    assert stats.tier_demotions == 1
    pool.budget_bytes = 1 << 30
    st2 = pool.promote("c")
    assert st2 is not None and "c" not in tier
    assert stats.tier_promotions == 1 and stats.pool_reprefills == 0
    assert stats.tier_promoted_bytes == stats.tier_demoted_bytes > 0
    out2, _ = eng.serve([Request(s, st2) for s in sfx], _record=False)
    assert out2 == oracle
    assert tier.drain_pending() >= 0.0 and not tier._inflight

    # arm 3: evict again, DISCARD the host copy, recompute
    pool.budget_bytes = 1
    pool._evict_to_budget()
    tier.clear()
    pool.budget_bytes = 1 << 30
    assert pool.promote("c") is None          # double miss
    st3, dt3 = eng.prefill_prefix(prefix, _record=False)
    pool.put("c", st3, prefill_s=dt3)
    assert stats.pool_reprefills == 1         # recompute counted honestly
    out3, _ = eng.serve([Request(s, st3) for s in sfx], _record=False)
    assert out3 == oracle
    assert stats.tier_promotion_rate == 0.5   # 1 promotion vs 1 reprefill
    pool.clear()
    assert eng.block_pool.blocks_in_use == 0


# ----------------------------------------------------------------------
# chain-aware promotion (tree levels >= 2)
# ----------------------------------------------------------------------
def _chain_sched(tok, eng, pool):
    from repro.core.planner import ChainSpec
    from repro.core.subgraph import Subgraph
    from repro.serving.scheduler import (OnlineCluster,
                                         OnlineClusterAssigner,
                                         OnlineScheduler)
    anc_sg = Subgraph.from_lists([0, 1, 2], [])
    leaf_sg = Subgraph.from_lists([0, 1, 2, 3, 4], [])
    assigner = OnlineClusterAssigner()
    assigner.clusters.append(OnlineCluster(
        cluster_id=0, centroid=np.zeros(2), representative=leaf_sg,
        chain=ChainSpec(keys=[10, 11], contents=[anc_sg, leaf_sg])))

    def seg_tokens(content, base):
        if base is None:
            return tok.encode("the quick brown fox jumps over the lazy "
                              "dog " * 6, bos=True)
        return tok.encode("a graph of nodes and edges")
    return OnlineScheduler(eng, assigner, pool, lambda sg: [],
                           segment_tokens_fn=seg_tokens)


def test_chain_promote_relinks_through_resident_parent(tok):
    """Evict the LEAF only (tree order protects the root): the next
    materialization must PROMOTE it — not re-prefill — and the promoted
    leaf must chain to the still-resident root's blocks.  Tokens match
    the pre-eviction serve exactly."""
    eng = _engine(tok)
    stats = eng.cache_mgr.stats
    pool = _tiered_pool(eng)
    sched = _chain_sched(tok, eng, pool)
    sfx = [tok.encode("answers questions"), tok.encode("lazy dog")]
    st, hit, _, _ = sched.ensure_chain(0)
    oracle, _ = eng.serve([Request(s, st) for s in sfx], _record=False)
    root = pool.entry(("seg", 10)).state
    root_blocks = list(root.page.blocks)

    pool.budget_bytes = state_bytes(root)
    pool._evict_to_budget()
    assert ("seg", 10) in pool and ("seg", 11) not in pool
    assert ("seg", 11) in pool.tier
    assert pool.tier.peek(("seg", 11)).parent_key == ("seg", 10)

    pool.budget_bytes = 1 << 30
    st2, hit2, dt2, _ = sched.ensure_chain(0)
    assert hit2 and dt2 == 0.0            # promoted, not recomputed
    assert stats.tier_promotions == 1 and stats.pool_reprefills == 0
    assert st2.parent is root
    assert st2.ancestor_blocks == root_blocks
    out, _ = eng.serve([Request(s, st2) for s in sfx], _record=False)
    assert out == oracle
    pool.clear()
    assert eng.block_pool.blocks_in_use == 0


def test_chain_promote_whole_path_root_then_leaf(tok):
    """Evict the whole chain (leaf first, then root — both demoted).
    The next walk promotes root→leaf, re-linking the leaf under the
    freshly promoted root; tokens are unchanged."""
    eng = _engine(tok)
    stats = eng.cache_mgr.stats
    pool = _tiered_pool(eng)
    sched = _chain_sched(tok, eng, pool)
    sfx = [tok.encode("answers questions")]
    st, _, _, _ = sched.ensure_chain(0)
    oracle, _ = eng.serve([Request(s, st) for s in sfx], _record=False)

    pool.budget_bytes = 1
    pool._evict_to_budget()
    assert len(pool) == 0 and len(pool.tier) == 2
    assert stats.tier_demotions == 2

    pool.budget_bytes = 1 << 30
    st2, hit2, dt2, _ = sched.ensure_chain(0)
    assert hit2 and dt2 == 0.0
    assert stats.tier_promotions == 2 and stats.pool_reprefills == 0
    assert st2.parent is pool.entry(("seg", 10)).state
    out, _ = eng.serve([Request(s, st2) for s in sfx], _record=False)
    assert out == oracle
    pool.clear()
    assert eng.block_pool.blocks_in_use == 0


def test_host_discard_is_leaf_first():
    """The host tier's second-level eviction never victimizes a segment
    that is the recorded parent of another hosted segment, even when
    the parent's score is colder — chains peel leaf-first one tier
    down, mirroring the pool's ancestor anchoring."""
    tier = HostTier(budget_bytes=300)
    stats = CacheStats()
    tier.stats = stats

    def seg(key, parent_key, nbytes, page_length):
        return HostSegment(
            key=key, host={}, block_tokens=[page_length], nbytes=nbytes,
            prefix_len=page_length, page_length=page_length,
            seg_len=page_length, capacity=64, enc_len=0, n_soft=0,
            parent_key=parent_key, quantized=False, prefill_s=0.0)

    assert tier.admit(seg("root", None, 100, 64))     # cold + big
    assert tier.admit(seg("leaf", "root", 100, 4))    # hot + small
    tier.get("leaf")
    # pressure: only one can stay — the ROOT must, it anchors the leaf's
    # linkage even though its discard score is far worse
    assert tier.admit(seg("other", None, 200, 8))
    assert "root" in tier and "leaf" not in tier
    assert stats.host_discards == 1
    assert stats.host_bytes_in_use == tier.bytes_in_use == 300
    assert stats.host_bytes_peak == 300


# ----------------------------------------------------------------------
# speculative prefetch
# ----------------------------------------------------------------------
def test_prefetch_promotes_and_accounts(tok):
    from repro.core.subgraph import Subgraph
    from repro.serving.scheduler import (OnlineCluster,
                                         OnlineClusterAssigner,
                                         OnlineScheduler)
    eng = _engine(tok)
    stats = eng.cache_mgr.stats
    pool = _tiered_pool(eng)
    assigner = OnlineClusterAssigner()
    assigner.clusters.append(OnlineCluster(
        cluster_id=0, centroid=np.zeros(2),
        representative=Subgraph.from_lists([0], [])))
    sched = OnlineScheduler(
        eng, assigner, pool,
        lambda sg: tok.encode("a graph of nodes and edges " * 10,
                              bos=True))
    st, hit, _ = sched.ensure_state(0)
    assert not hit
    sfx = [tok.encode("answers questions")]
    oracle, _ = eng.serve([Request(s, st) for s in sfx], _record=False)

    pool.budget_bytes = 1
    pool._evict_to_budget()
    pool.budget_bytes = 1 << 30
    assert 0 in pool.tier

    # a queued query is tagged to cluster 0 → its segment promotes NOW
    assert sched.prefetch([np.zeros(2)]) == 1
    e = pool.entry(0)
    assert e is not None and e.refs == 0 and e.prefetched
    assert stats.tier_prefetch_promotions == 1
    assert stats.tier_prefetch_hits == 0      # not consumed yet
    # a second probe for the same cluster finds it resident: no-op
    assert sched.prefetch([np.zeros(2)]) == 0
    assert stats.pool_hits == 0               # probes are not traffic

    # the query reaches the front: ensure_state is a pool HIT that
    # consumes the prefetch flag — and serves the same tokens
    st2, hit2, dt2 = sched.ensure_state(0)
    assert hit2 and dt2 == 0.0
    assert stats.tier_prefetch_hits == 1
    assert stats.prefetch_hit_rate == 1.0
    assert not pool.entry(0).prefetched       # consumed exactly once
    out, _ = eng.serve([Request(s, st2) for s in sfx], _record=False)
    assert out == oracle
    sched._drain_tier()
    pool.clear()
    assert eng.block_pool.blocks_in_use == 0


def test_prefetch_never_computes(tok):
    """A probe whose cluster has no host copy (true double miss) must
    not prefill anything — prefetch is promotion-only."""
    from repro.core.subgraph import Subgraph
    from repro.serving.scheduler import (OnlineCluster,
                                         OnlineClusterAssigner,
                                         OnlineScheduler)
    eng = _engine(tok)
    pool = _tiered_pool(eng)
    assigner = OnlineClusterAssigner()
    assigner.clusters.append(OnlineCluster(
        cluster_id=0, centroid=np.zeros(2),
        representative=Subgraph.from_lists([0], [])))
    sched = OnlineScheduler(eng, assigner, pool,
                            lambda sg: tok.encode("a graph", bos=True))
    # tier attached but empty → fast path, nothing promoted or computed
    assert sched.prefetch([np.zeros(2)]) == 0
    assert len(pool) == 0 and eng.block_pool.blocks_in_use == 0


# ----------------------------------------------------------------------
# fault injection
# ----------------------------------------------------------------------
def _demoted_flat(tok, eng, pool):
    """One flat segment admitted, served once, then demoted to host."""
    prefix = tok.encode("the quick brown fox jumps over the lazy dog "
                        * 4, bos=True)
    st, dt = eng.prefill_prefix(prefix, _record=False)
    pool.put("c", st, prefill_s=dt)
    sfx = [tok.encode("answers questions")]
    oracle, _ = eng.serve([Request(s, st) for s in sfx], _record=False)
    pool.budget_bytes = 1
    pool._evict_to_budget()
    pool.budget_bytes = 1 << 30
    assert "c" in pool.tier and "c" not in pool
    return prefix, sfx, oracle


def test_device_put_failure_unwinds_and_recomputes(tok, monkeypatch):
    """An injected ``device_put`` fault mid-promotion must leave no
    phantom references (allocator free count restored), keep the host
    copy, count a promotion failure — and the serving path falls back
    to recompute with identical tokens."""
    eng = _engine(tok)
    stats = eng.cache_mgr.stats
    pool = _tiered_pool(eng)
    prefix, sfx, oracle = _demoted_flat(tok, eng, pool)
    free0 = eng.block_pool.allocator.free_blocks

    with monkeypatch.context() as mp:
        def boom(*a, **kw):
            raise RuntimeError("injected transfer fault")
        mp.setattr(jax, "device_put", boom)
        assert pool.promote("c") is None
    assert eng.block_pool.allocator.free_blocks == free0
    assert "c" in pool.tier and "c" not in pool   # host copy survives
    assert stats.tier_promotion_failures == 1
    assert stats.tier_promotions == 0

    # fall back to recompute (the scheduler's double-miss branch)
    st2, dt2 = eng.prefill_prefix(prefix, _record=False)
    pool.put("c", st2, prefill_s=dt2)
    out, _ = eng.serve([Request(s, st2) for s in sfx], _record=False)
    assert out == oracle
    # ...and the intact host copy still promotes once the fault clears
    pool.budget_bytes = 1
    pool._evict_to_budget()
    pool.budget_bytes = 1 << 30
    assert pool.promote("c") is not None
    pool.clear()
    assert eng.block_pool.blocks_in_use == 0


def test_out_of_blocks_mid_promotion_unwinds(tok):
    """Promotion under arena exhaustion (every block pinned elsewhere)
    must raise-and-unwind inside the attempt: no allocation survives,
    the host copy is intact, and the promotion succeeds verbatim once
    pressure clears."""
    eng = _engine(tok)
    stats = eng.cache_mgr.stats
    pool = _tiered_pool(eng)
    _, sfx, oracle = _demoted_flat(tok, eng, pool)
    bp = eng.block_pool
    hold = bp.alloc(bp.allocator.free_blocks)     # exhaust prefix space
    assert bp.allocator.free_blocks == 0
    assert pool.promote("c") is None
    assert bp.allocator.free_blocks == 0          # nothing leaked back
    assert "c" in pool.tier
    assert stats.tier_promotion_failures == 1
    bp.decref(hold)
    free0 = bp.allocator.free_blocks
    st = pool.promote("c")
    assert st is not None
    out, _ = eng.serve([Request(s, st) for s in sfx], _record=False)
    assert out == oracle
    assert bp.allocator.free_blocks == free0 - len(st.page.blocks)
    pool.clear()
    assert bp.blocks_in_use == 0


def test_demote_loses_race_with_pin(tok, monkeypatch):
    """A ``get(pin=True)`` that lands while the demotion gather is in
    flight must WIN: the demote aborts (nothing stored host-side), the
    entry stays resident and pinned, and the eviction pass moves on."""
    eng = _engine(tok)
    stats = eng.cache_mgr.stats
    pool = _tiered_pool(eng)
    prefix = tok.encode("a graph of nodes and edges " * 4, bos=True)
    st, dt = eng.prefill_prefix(prefix, _record=False)
    pool.put("c", st, prefill_s=dt)
    bp = eng.block_pool
    orig = bp.demote_blocks

    def racing_demote(bids):
        out = orig(bids)
        pool.pin("c")        # the racing reader lands mid-gather
        return out
    monkeypatch.setattr(bp, "demote_blocks", racing_demote)
    free0 = bp.allocator.free_blocks
    pool.budget_bytes = 1
    pool._evict_to_budget()                       # gives up: pin wins
    assert "c" in pool and pool.entry("c").refs == 1
    assert len(pool.tier) == 0 and pool.tier.bytes_in_use == 0
    assert stats.tier_demotions == 0 and stats.pool_evictions == 0
    assert bp.allocator.free_blocks == free0      # state untouched
    pool.release("c")
    pool.clear()
    assert bp.blocks_in_use == 0


# ----------------------------------------------------------------------
# satellite regression: quantized prefixes strand no compute-dtype rows
# ----------------------------------------------------------------------
def test_quantized_staging_rows_return_to_suffix_free_list():
    """``write_prefix`` on a quantized pool must return its compute-
    dtype staging rows to the suffix free list once the int8 copy
    commits; the resident prefix then prices EXACTLY as ``from_budget``
    sized it (bytes ↔ block counts agree)."""
    cfg = _gqa_cfg()
    pool = KVBlockPool(cfg, num_blocks=16, block_size=8,
                       quantize_prefix=True)
    P = 19
    pt = pool.write_prefix(_filled_dense(cfg, P), P)
    # every staging row is back: the suffix space is fully free again
    assert pool.free_suffix_blocks == pool.suffix_allocator.num_usable
    assert pool.prefix_blocks_in_use == len(pt.blocks) == 3
    assert pool.blocks_in_use == 3

    stats = CacheStats()
    stats.record_blocks(pool)
    resident_bytes = stats.block_bytes_in_use
    assert resident_bytes == len(pt.blocks) * pool.prefix_block_bytes
    # from_budget agreement: a pool sized to exactly the resident bytes
    # holds exactly that many usable prefix blocks
    sized = KVBlockPool.from_budget(cfg, resident_bytes, 8,
                                    quantize_prefix=True)
    assert sized.allocator.num_usable == pool.prefix_blocks_in_use
    # both id spaces are reported in the capacity gauge
    assert stats.blocks_total == 2 * pool.allocator.num_usable
    pool.decref(pt.blocks)
    assert pool.blocks_in_use == 0


# ----------------------------------------------------------------------
# pipeline end to end: drain + continuous, thrash budget, tokens fixed
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_pipe():
    from repro.data.scenegraph import generate_scene_graph
    from repro.rag.pipeline import GraphRAGPipeline
    from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
    from repro.rag.text_encoder import TextEncoder
    graph, queries = generate_scene_graph()
    tok2 = Tokenizer.train([q.question + " " + q.answer for q in queries]
                           + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="tier-pipe", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=tok2.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(32))
    pipe = GraphRAGPipeline(
        index=index, retriever=GRetrieverRetriever(index),
        engine=ServingEngine(params, cfg, tok2, max_cache_len=768,
                             max_new_tokens=4),
        tokenizer=tok2, use_soft_prompt=False)
    return pipe, queries[:10]


@pytest.mark.parametrize("mode", ["drain", "continuous"])
@pytest.mark.parametrize("tree_levels", [1, 2])
def test_serve_stream_tiered_tokens_identical(small_pipe, mode,
                                              tree_levels):
    """End to end under a THRASH budget (pool holds ~one prefix, so
    every cluster switch evicts): serving with the host tier attached
    produces token-identical answers to the roomy no-tier reference, in
    both loop modes and both layouts — and actually exercises the tier
    (demotions and promotions observed)."""
    pipe, items = small_pipe
    arr = np.cumsum(np.full(len(items), 0.01))
    # a near-zero spawn threshold splits the trace into several flat
    # clusters (the tree path seeds its own leaves); one-entry budget +
    # several clusters = guaranteed thrash
    kw = dict(max_batch=4, mode=mode, chunk=2, tree_levels=tree_levels,
              tree_clusters=3, threshold=1e-6, max_clusters=3)
    ref, _, rs = pipe.serve_stream(items, arr, **kw)
    # thrash budget: just one resident entry's bytes
    thrash = max(e.nbytes for e in rs.pool._entries.values()) \
        if len(rs.pool) else 1 << 20
    rec, _, sch = pipe.serve_stream(items, arr, pool_budget_bytes=thrash,
                                    host_tier_bytes=1 << 30, **kw)
    assert [r.generated for r in rec] == [r.generated for r in ref]
    st = sch.pool.stats
    assert st.tier_demotions > 0
    assert st.tier_promotions > 0
    assert st.tier_promotion_failures == 0
    # the serving report exposes the tier section
    from repro.rag.workbench import serving_report
    rep = serving_report(pipe)
    assert rep["tier"]["promotions"] == st.tier_promotions
    assert 0.0 <= rep["tier"]["prefetch_hit_rate"] <= 1.0
