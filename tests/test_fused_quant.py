"""Fused single-pass cascade kernel + int8 prefix blocks (DESIGN.md §11).

Correctness gates, in order of strength:

1. The fused ORACLE is bitwise the multi-launch composition (prefix
   partial + suffix partial + LSE merge) — asserted with exact
   equality, f32/XLA at matched block widths.
2. The fused Pallas kernels (interpret mode) match the oracle allclose
   — decode and prefill shapes, shared/per-row tables, windows, int8.
3. End to end, an engine with ``fused=True`` is token-identical to
   ``fused=False`` across flat, tree (levels >= 2), drain, and
   continuous serving, on f32/XLA (where it is bitwise by construction)
   AND bf16/Pallas (where the single-pass accumulator rounds
   differently and identity is the gate).
4. int8 prefix mode: per-block write->dequant round-trip error bounds,
   byte-accounting regression (same budget => ~2x the blocks/tokens),
   and the serving quality gate (greedy-token match rate + max logit
   MSE under the tolerance knobs recorded in EXPERIMENTS.md).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.paged import KVBlockPool
from repro.core.prefix_pool import state_bytes
from repro.data.tokenizer import Tokenizer
from repro.kernels import ops
from repro.kernels import ref as R
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request, ServingEngine

KEY = jax.random.PRNGKey(0)

# --- int8 serving quality gate (tolerance knobs; EXPERIMENTS.md) ------
QUALITY_TOKEN_MATCH_MIN = 0.90   # greedy tokens identical to bf16-pool
QUALITY_LOGIT_MSE_MAX = 5e-3     # max per-row first-token logit MSE


# ----------------------------------------------------------------------
# kernel-level: oracle composition + fused Pallas vs oracle
# ----------------------------------------------------------------------
def _paged_case(seed=0, b=3, hq=8, hkv=2, d=32, bs=8, nbp=16, nbs=12):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    pk = jax.random.normal(ks[0], (nbp, hkv, bs, d))
    pv = jax.random.normal(ks[1], (nbp, hkv, bs, d))
    sk = jax.random.normal(ks[2], (nbs, hkv, bs, d))
    sv = jax.random.normal(ks[3], (nbs, hkv, bs, d))
    npp, nps = 4, 3
    p_kpos = jnp.arange(nbp * bs).reshape(nbp, bs) % (npp * bs)
    p_kpos = jnp.where(jnp.arange(nbp)[:, None] == 0, -1, p_kpos)
    s_kpos = npp * bs + jnp.arange(nbs * bs).reshape(nbs, bs) % (nps * bs)
    s_kpos = jnp.where(jnp.arange(nbs)[:, None] == 0, -1, s_kpos)
    ppt = jnp.array([[1, 2, 3, 0], [4, 5, 0, 0], [6, 7, 8, 9]], jnp.int32)
    spt = jnp.array([[1, 2, 0], [3, 4, 5], [6, 0, 0]], jnp.int32)
    return dict(pk=pk, pv=pv, sk=sk, sv=sv, p_kpos=p_kpos, s_kpos=s_kpos,
                ppt=ppt[:b], spt=spt[:b], npp=npp, nps=nps,
                b=b, hq=hq, hkv=hkv, d=d, bs=bs, keys=ks)


def _quantize(x):
    amax = jnp.max(jnp.abs(x), axis=(2, 3))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, :, None, None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def test_fused_oracle_is_bitwise_multilaunch_composition():
    """Gate 1: exact (==) equality between the fused oracle and the
    explicit multi-launch cascade at matched widths, f32/XLA — the
    contract that makes the XLA fused serving path bitwise-identical
    to multi-launch by construction."""
    c = _paged_case()
    tq = 13
    q = jax.random.normal(c["keys"][4], (c["b"], c["hq"], tq, c["d"]))
    q_pos = c["npp"] * c["bs"] + jnp.broadcast_to(
        jnp.arange(tq)[None], (c["b"], tq))
    fused = R.fused_paged_attention_ref(
        q, c["pk"], c["pv"], c["sk"], c["sv"], q_pos, c["p_kpos"],
        c["s_kpos"], c["ppt"], c["spt"])
    o1 = R.paged_attention_partial_ref(q, c["pk"], c["pv"], q_pos,
                                       c["p_kpos"], c["ppt"], causal=False)
    o2 = R.paged_attention_partial_ref(q, c["sk"], c["sv"], q_pos,
                                       c["s_kpos"], c["spt"], causal=True)
    multi, _, _ = R.merge_partials_ref(*o1, *o2)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(multi))

    qd = jax.random.normal(c["keys"][5], (c["b"], c["hq"], c["d"]))
    qd_pos = jnp.full((c["b"],), (c["npp"] + c["nps"]) * c["bs"], jnp.int32)
    fused_d = R.fused_paged_decode_gqa_ref(
        qd, c["pk"], c["pv"], c["sk"], c["sv"], qd_pos, c["p_kpos"],
        c["s_kpos"], c["ppt"], c["spt"])
    d1 = R.paged_decode_gqa_partial_ref(qd, c["pk"], c["pv"], qd_pos,
                                        c["p_kpos"], c["ppt"])
    d2 = R.paged_decode_gqa_partial_ref(qd, c["sk"], c["sv"], qd_pos,
                                        c["s_kpos"], c["spt"])
    multi_d, _, _ = R.merge_partials_ref(*d1, *d2)
    np.testing.assert_array_equal(np.asarray(fused_d), np.asarray(multi_d))


@pytest.mark.parametrize("shared,window,quant", [
    (False, 0, False), (True, 0, False), (False, 20, False),
    (False, 0, True), (True, 0, True),
])
def test_fused_decode_kernel_matches_oracle(shared, window, quant):
    c = _paged_case()
    q = jax.random.normal(c["keys"][4], (c["b"], c["hq"], c["d"]))
    q_pos = jnp.full((c["b"],), (c["npp"] + c["nps"]) * c["bs"], jnp.int32)
    ppt = jnp.array([[1, 2, 3, 4]], jnp.int32) if shared else c["ppt"]
    pk, pv, ks, vs = c["pk"], c["pv"], None, None
    if quant:
        pk, ks = _quantize(pk)
        pv, vs = _quantize(pv)
    got = ops.fused_paged_decode_gqa(
        q, pk, pv, c["sk"], c["sv"], q_pos, c["p_kpos"], c["s_kpos"],
        ppt, c["spt"], ks, vs, window=window)
    want = R.fused_paged_decode_gqa_ref(
        q, pk, pv, c["sk"], c["sv"], q_pos, c["p_kpos"], c["s_kpos"],
        ppt, c["spt"], ks, vs, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shared,window,quant", [
    (False, 0, False), (True, 0, False), (False, 20, False),
    (False, 0, True),
])
def test_fused_prefill_kernel_matches_oracle(shared, window, quant):
    c = _paged_case()
    tq = 13          # deliberately not a block_q multiple (padding path)
    q = jax.random.normal(c["keys"][4], (c["b"], c["hq"], tq, c["d"]))
    q_pos = c["npp"] * c["bs"] + jnp.broadcast_to(
        jnp.arange(tq)[None], (c["b"], tq))
    ppt = jnp.array([[1, 2, 3, 4]], jnp.int32) if shared else c["ppt"]
    pk, pv, ks, vs = c["pk"], c["pv"], None, None
    if quant:
        pk, ks = _quantize(pk)
        pv, vs = _quantize(pv)
    got = ops.fused_paged_attention(
        q, pk, pv, c["sk"], c["sv"], q_pos, c["p_kpos"], c["s_kpos"],
        ppt, c["spt"], ks, vs, window=window, block_q=8)
    want = R.fused_paged_attention_ref(
        q, pk, pv, c["sk"], c["sv"], q_pos, c["p_kpos"], c["s_kpos"],
        ppt, c["spt"], ks, vs, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_fused_bf16_kernel_matches_multilaunch_tokens():
    """bf16/Pallas gate at the kernel level: fused single-pass and
    multi-launch rank the same argmax almost everywhere (full identity
    is asserted end-to-end on served tokens below)."""
    c = _paged_case()
    q = jax.random.normal(c["keys"][4],
                          (c["b"], c["hq"], c["d"])).astype(jnp.bfloat16)
    q_pos = jnp.full((c["b"],), (c["npp"] + c["nps"]) * c["bs"], jnp.int32)
    pk, pv = (x.astype(jnp.bfloat16) for x in (c["pk"], c["pv"]))
    sk, sv = (x.astype(jnp.bfloat16) for x in (c["sk"], c["sv"]))
    got = ops.fused_paged_decode_gqa(q, pk, pv, sk, sv, q_pos, c["p_kpos"],
                                     c["s_kpos"], c["ppt"], c["spt"])
    o1 = ops.paged_decode_gqa_partial(q, pk, pv, q_pos, c["p_kpos"],
                                      c["ppt"])
    o2 = ops.paged_decode_gqa_partial(q, sk, sv, q_pos, c["s_kpos"],
                                      c["spt"])
    multi, _, _ = R.merge_partials_ref(*o1, *o2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(multi),
                               atol=2e-2, rtol=2e-2)


# ----------------------------------------------------------------------
# int8 arena: round trip + byte accounting
# ----------------------------------------------------------------------
def _gqa_cfg(vocab=64, dtype="float32", impl="xla", window=0):
    return ModelConfig(name="fused-test", family="dense", num_layers=3,
                       d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
                       d_ff=160, vocab_size=vocab, dtype=dtype,
                       attention_impl=impl, sliding_window=window)


def test_int8_write_dequant_round_trip_error_bounds():
    """Per-block symmetric int8: every dequantized element must sit
    within half a quantization step (scale/2 = amax/254) of the source,
    per (block, kv-head) tile; empty blocks keep pos = -1."""
    cfg = _gqa_cfg()
    pool = KVBlockPool(cfg, num_blocks=16, block_size=8,
                       quantize_prefix=True)
    P, C = 19, 32
    dense = M.init_cache(cfg, 1, C)
    k1 = jax.random.fold_in(KEY, 7)

    def fill(path, x):
        key = path[-1].key
        if key == "pos":
            pos = jnp.arange(C)
            row = jnp.where(pos < P, pos, -1).astype(x.dtype)
            return jnp.broadcast_to(row, x.shape)
        salt = abs(hash(jax.tree_util.keystr(path))) % (2 ** 31)
        return jax.random.normal(jax.random.fold_in(k1, salt),
                                 x.shape, jnp.float32).astype(x.dtype)
    dense = jax.tree_util.tree_map_with_path(fill, dense)
    page = pool.write_prefix(dense, P)
    # the compute-dtype staging rows went BACK to the suffix free list
    # once the int8 copy committed (the dead-row reclaim fix), so the
    # full-precision source comes from an unquantized reference pool
    # given the same dense cache — not from the quantized pool's arena
    ref = KVBlockPool(cfg, num_blocks=16, block_size=8)
    ref_page = ref.write_prefix(dense, P)
    assert pool.free_suffix_blocks == ref.num_blocks - 1  # staging freed

    arena_leaves = jax.tree_util.tree_leaves_with_path(ref.arena)
    q_by_path = {jax.tree_util.keystr(p): x for p, x in
                 jax.tree_util.tree_leaves_with_path(pool.qarena)}
    bids = jnp.asarray(page.blocks)
    rbids = jnp.asarray(ref_page.blocks)
    checked = 0
    for path, leaf in arena_leaves:
        key = path[-1].key
        ps = jax.tree_util.keystr(path)
        if key == "pos":
            np.testing.assert_array_equal(
                np.asarray(jnp.moveaxis(q_by_path[ps], -2, 0)[bids]),
                np.asarray(jnp.moveaxis(leaf, -2, 0)[rbids]))
            continue
        qv = q_by_path[ps]
        scale = q_by_path[ps.replace(f"'{key}'", f"'{key}_scale'")]
        src = jnp.moveaxis(leaf, -4, 0)[rbids].astype(jnp.float32)
        deq = (jnp.moveaxis(qv, -4, 0)[bids].astype(jnp.float32)
               * jnp.moveaxis(scale, -2, 0)[bids][:, ..., None, :, None])
        step = jnp.moveaxis(scale, -2, 0)[bids][:, ..., None, :, None]
        err = jnp.abs(deq - src)
        assert float(jnp.max(err - step * 0.5)) <= 1e-6, ps
        # and the bound is tight-ish: errors are not all zero
        checked += 1
    assert checked >= 2      # at least k and v checked


def test_int8_pool_doubles_blocks_at_equal_budget():
    """Satellite regression: the SAME byte budget must admit ~2x the
    blocks (and so ~2x the path tokens) when prefix blocks are int8 —
    i.e. accounting prices the arena dtype, not the compute dtype."""
    cfg = _gqa_cfg(dtype="bfloat16")
    budget = 512 * 1024
    pool16 = KVBlockPool.from_budget(cfg, budget, 64)
    pool8 = KVBlockPool.from_budget(cfg, budget, 64, quantize_prefix=True)
    ratio = pool8.num_blocks / pool16.num_blocks
    assert 1.7 <= ratio <= 2.2, ratio
    # per-block accounting: int8 layout is K/V bytes halved + scales
    assert pool8.prefix_block_bytes < pool16.prefix_block_bytes
    assert pool16.prefix_block_bytes == pool16.block_bytes


def _leaf_bytes(tree):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


@pytest.mark.parametrize("quant", [False, True])
def test_resident_bytes_match_priced_layout(quant):
    """Satellite regression (quantize_prefix dead-arena bug): the bytes
    the pool PRICES (``device_bytes``) equal the bytes the arenas
    actually HOLD on device — summed jax leaf nbytes.  Before the fix,
    a quantized pool also allocated ``num_blocks`` compute-dtype arena
    rows it never addressed, so residency silently exceeded the priced
    layout ~3x."""
    cfg = _gqa_cfg(dtype="bfloat16")
    pool = KVBlockPool(cfg, num_blocks=16, block_size=8,
                       quantize_prefix=quant)
    held = _leaf_bytes(pool.arena)
    if quant:
        held += _leaf_bytes(pool.qarena)
    assert pool.device_bytes == held


def test_from_budget_sizes_suffix_and_prefix_spaces_separately():
    """Under quantize_prefix the two address spaces get their OWN
    counts from the same budget: compute-dtype suffix rows at the
    compute block price, int8 prefix rows at the int8 price (~2x as
    many) — not one count priced twice."""
    cfg = _gqa_cfg(dtype="bfloat16")
    budget = 512 * 1024
    pool = KVBlockPool.from_budget(cfg, budget, 64, quantize_prefix=True)
    bb = KVBlockPool.block_bytes_for(cfg, 64)
    pb = KVBlockPool.prefix_block_bytes_for(cfg, 64, quantize_prefix=True)
    assert pool.suffix_blocks == max(2, budget // bb + 1)
    assert pool.num_blocks == max(2, budget // pb + 1)
    assert pool.num_blocks > pool.suffix_blocks
    # explicit suffix_blocks wins over the derived count
    pool2 = KVBlockPool.from_budget(cfg, budget, 64,
                                    quantize_prefix=True,
                                    suffix_blocks=5)
    assert pool2.suffix_blocks == 5
    # the shrunk suffix space still serves: write a prefix, allocate a
    # suffix path on the separate allocator
    dense = M.init_cache(cfg, 1, 64)
    page = pool.write_prefix(dense, 19)
    assert pool.prefix_blocks_in_use == len(page.blocks)
    assert pool.free_suffix_blocks == pool.suffix_allocator.num_usable


def test_state_bytes_and_gauges_reflect_arena_dtype():
    """PrefixPool/CacheStats byte accounting prices paged states at the
    layout their blocks occupy: the quantized pool reports int8+scales
    bytes, the plain pool the compute dtype."""
    from repro.core.cache import CacheStats, PrefixState
    cfg = _gqa_cfg(dtype="bfloat16")
    dense = M.init_cache(cfg, 1, 32)
    states = {}
    for quant in (False, True):
        pool = KVBlockPool(cfg, num_blocks=16, block_size=8,
                           quantize_prefix=quant)
        page = pool.write_prefix(dense, 19)
        states[quant] = PrefixState(
            cache=None, prefix_len=19, capacity=32, page=page,
            block_pool=pool)
        stats = CacheStats()
        stats.record_blocks(pool)
        assert stats.block_bytes == pool.prefix_block_bytes
        assert stats.block_bytes_in_use == \
            pool.blocks_in_use * pool.prefix_block_bytes
    assert state_bytes(states[True]) < state_bytes(states[False])
    # 3 blocks x per-block bytes exactly
    assert state_bytes(states[True]) == \
        3 * states[True].block_pool.prefix_block_bytes


# ----------------------------------------------------------------------
# end-to-end: fused == multi-launch tokens (flat / tree / continuous)
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def tok():
    return Tokenizer.train(["the quick brown fox jumps over the lazy dog "
                            "a graph of nodes and edges answers questions"])


def _engine(tok, key=1, dtype="float32", impl="xla", **kw):
    cfg = _gqa_cfg(tok.vocab_size, dtype, impl)
    params = M.init_params(jax.random.PRNGKey(key), cfg)
    kw.setdefault("max_cache_len", 512)
    kw.setdefault("max_new_tokens", 5)
    return ServingEngine(params, cfg, tok, **kw)


@pytest.mark.parametrize("dtype,impl", [("float32", "xla"),
                                        ("bfloat16", "pallas")])
def test_fused_token_identical_across_serving_paths(tok, dtype, impl):
    """THE acceptance gate: fused=True serves token-identically to
    fused=False on flat prefixes, a depth-3 chain (levels >= 2), the
    drain path, and continuous in-flight batching — f32/XLA (bitwise by
    construction) and bf16/Pallas (single-pass accumulator)."""
    fused = _engine(tok, dtype=dtype, impl=impl, fused=True)
    multi = _engine(tok, dtype=dtype, impl=impl, fused=False)
    assert fused.fused and not multi.fused
    t0 = tok.encode("a graph of nodes and edges", bos=True)
    t1 = tok.encode("the quick brown fox jumps over the lazy dog " * 2)
    t2 = tok.encode("answers questions the lazy dog")
    sfx = [tok.encode("answers questions"), tok.encode("and edges"),
           tok.encode("the quick"), tok.encode("lazy dog jumps")]

    outs = {}
    for name, eng in (("fused", fused), ("multi", multi)):
        flat, _ = eng.prefill_prefix(t0 + t1 + t2, _record=False)
        root, _ = eng.prefill_prefix(t0, _record=False)
        mid, _ = eng.prefill_prefix_extension(root, t1, _record=False)
        leaf, _ = eng.prefill_prefix_extension(mid, t2, _record=False)
        drain_flat, t = eng.serve([Request(s, flat) for s in sfx],
                                  _record=False)
        assert t["paged"]
        drain_tree, _ = eng.serve([Request(s, leaf) for s in sfx],
                                  _record=False)
        cont = ContinuousEngine(eng, max_slots=4, chunk=2,
                                max_suffix_len=8)
        cont.admit([Request(sfx[0], leaf), Request(sfx[1], leaf)],
                   payloads=[0, 1])
        cont.step()
        cont.admit([Request(sfx[2], leaf), Request(sfx[3], flat)],
                   payloads=[2, 3])
        cont.flush()
        res = {r.payload: r for r in cont.pop_retired()}
        outs[name] = (drain_flat, drain_tree,
                      [res[i].tokens for i in range(4)])
        for st in (leaf, mid, root, flat):
            st.release()
    assert outs["fused"] == outs["multi"]


def test_quantized_serving_quality_gate(tok):
    """int8 prefix mode quality gate (knobs at module top, recorded in
    EXPERIMENTS.md): greedy served tokens match the full-precision pool
    at >= QUALITY_TOKEN_MATCH_MIN rate, and per-row first-token logit
    MSE stays under QUALITY_LOGIT_MSE_MAX, on a fixed eval batch over
    flat and chained prefixes."""
    base = _engine(tok, dtype="float32", impl="xla")
    q8 = _engine(tok, dtype="float32", impl="xla", quantize_prefix=True)
    assert q8.quantize_prefix and q8.block_pool.qarena is not None
    t0 = tok.encode("a graph of nodes and edges "
                    "the quick brown fox jumps over the lazy dog",
                    bos=True)
    t1 = tok.encode("answers questions the lazy dog " * 3)
    sfx = [tok.encode("answers questions"), tok.encode("and edges"),
           tok.encode("the quick brown fox"), tok.encode("lazy dog")]

    toks, logits = {}, {}
    for name, eng in (("base", base), ("q8", q8)):
        root, _ = eng.prefill_prefix(t0, _record=False)
        leaf, _ = eng.prefill_prefix_extension(root, t1, _record=False)
        out, _ = eng.serve([Request(s, st) for s in sfx
                            for st in (root, leaf)], _record=False)
        toks[name] = out
        # logit drift probe: one extra greedy step's distribution
        lg = []
        for st in (root, leaf):
            emb, pos, valid, _ = eng._embed_padded([list(sfx[0])], None,
                                                   st.prefix_len)
            nbp = len(st.chain_blocks())
            prow = np.zeros((1, max(1, nbp)), np.int32)
            prow[0, :nbp] = st.chain_blocks()
            bids = eng.block_pool.alloc_suffix(
                eng.block_pool.blocks_needed(emb.shape[1]))
            srow = np.asarray(bids, np.int32).reshape(1, -1)
            prefill = eng._prefill_jit(1, emb.shape[1])
            _, lgt, _ = eng._with_arena(lambda a: prefill(
                eng.params, emb, pos, valid, a, eng.block_pool.qarena,
                jnp.int32(st.prefix_len), jnp.asarray(prow),
                jnp.asarray(srow)))
            lg.append(np.asarray(lgt[0], np.float32))
            eng.block_pool.decref(bids, suffix=True)
        logits[name] = lg
        leaf.release()
        root.release()

    flat_b = [t for row in toks["base"] for t in row]
    flat_q = [t for row in toks["q8"] for t in row]
    match = np.mean([a == b for a, b in zip(flat_b, flat_q)])
    assert match >= QUALITY_TOKEN_MATCH_MIN, (match, toks)
    mse = max(float(np.mean((a - b) ** 2))
              for a, b in zip(logits["base"], logits["q8"]))
    assert mse <= QUALITY_LOGIT_MSE_MAX, mse


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_quantized_serving_all_paths_run(tok, impl):
    """int8 mode exercises every serving path (drain, extension chain,
    continuous) on both backends without error, and frees its blocks."""
    eng = _engine(tok, dtype="float32", impl=impl, quantize_prefix=True)
    root, _ = eng.prefill_prefix(tok.encode("a graph of nodes", bos=True),
                                 _record=False)
    leaf, _ = eng.prefill_prefix_extension(
        root, tok.encode("the quick brown fox"), _record=False)
    sfx = [tok.encode("answers questions"), tok.encode("and edges")]
    out, _ = eng.serve([Request(sfx[0], leaf), Request(sfx[1], root)],
                       _record=False)
    assert all(len(o) > 0 for o in out)
    cont = ContinuousEngine(eng, max_slots=2, chunk=2, max_suffix_len=8)
    cont.admit([Request(sfx[0], leaf)], payloads=[0])
    cont.flush()
    res = cont.pop_retired()
    assert res[0].tokens == out[0]
    base = eng.block_pool.blocks_in_use
    leaf.release()
    root.release()
    assert eng.block_pool.blocks_in_use == 0 < base + 1
