"""Per-architecture smoke tests: reduced variant of each assigned family
runs one forward/train step on CPU; output shapes + finiteness asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.models import model as M


def _batch(cfg, b=2, t=16, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {
        "tokens": jax.random.randint(key, (b, t), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, t), 0, cfg.vocab_size),
        "mask": jnp.ones((b, t), jnp.float32),
    }
    if cfg.is_encdec:
        batch["enc_frames"] = 0.1 * jnp.ones(
            (b, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)
    elif cfg.num_image_tokens:
        batch["img_embeds"] = 0.1 * jnp.ones(
            (b, cfg.num_image_tokens, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", R.ASSIGNED_ARCHS + ("llama32-3b",))
def test_smoke_train_step(arch):
    cfg = R.get_reduced(arch)
    cfg.validate()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: M.train_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss)), (arch, loss)
    gsum = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0, arch


@pytest.mark.parametrize("arch", R.ASSIGNED_ARCHS)
def test_smoke_forward_shapes(arch):
    cfg = R.get_reduced(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, t = 2, 16
    batch = _batch(cfg, b, t)
    x = M.embed_tokens(params, batch["tokens"])
    enc = None
    if cfg.is_encdec:
        enc = M.run_encoder(params, cfg, batch["enc_frames"])
        assert enc.shape == (b, cfg.encoder_seq, cfg.d_model)
    elif cfg.num_image_tokens:
        enc = M.project_frontend(params, batch["img_embeds"])
        assert enc.shape == (b, cfg.num_image_tokens, cfg.d_model)
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    hidden, _, _ = M.forward(params, cfg, x, pos, enc=enc)
    assert hidden.shape == (b, t, cfg.d_model)
    logits = M.unembed(params, cfg, hidden)
    assert logits.shape == (b, t, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", R.ASSIGNED_ARCHS)
def test_smoke_prefill_then_decode(arch):
    """serve path: prefill T tokens then one decode step == full forward."""
    cfg = R.get_reduced(arch)
    if cfg.num_experts:
        # drop-free routing for exactness (serving-time MoE semantics);
        # capacity drops are train-time load-shedding, not inference math.
        cfg = cfg.replace(moe_capacity_factor=8.0)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, t = 2, 12
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (b, t + 1), 0, cfg.vocab_size)
    enc = None
    enc_len = 0
    if cfg.is_encdec:
        enc = M.run_encoder(params, cfg, 0.1 * jnp.ones(
            (b, cfg.encoder_seq, cfg.frontend_dim), jnp.float32))
        enc_len = cfg.encoder_seq
    elif cfg.num_image_tokens:
        enc = M.project_frontend(params, 0.1 * jnp.ones(
            (b, cfg.num_image_tokens, cfg.frontend_dim), jnp.float32))
        enc_len = cfg.num_image_tokens

    x = M.embed_tokens(params, tokens)
    pos = jnp.broadcast_to(jnp.arange(t + 1, dtype=jnp.int32)[None],
                           (b, t + 1))
    h_full, _, _ = M.forward(params, cfg, x, pos, enc=enc)
    want = M.unembed(params, cfg, h_full)[:, -1]

    cache = M.init_cache(cfg, b, capacity=32, enc_len=enc_len)
    _, cache, _ = M.forward(params, cfg, x[:, :t], pos[:, :t], cache=cache,
                            enc=enc)
    h1, cache, _ = M.forward(params, cfg, x[:, t:], pos[:, t:], cache=cache)
    got = M.unembed(params, cfg, h1)[:, -1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_assigned_arch_configs_exact():
    """The full configs must match the assignment table exactly."""
    spec = {
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
    }
    for arch, (l, d, h, kv, ff, v) in spec.items():
        cfg = R.get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (l, d, h, kv, ff, v), arch
    assert R.get_config("mixtral-8x22b").num_experts == 8
    assert R.get_config("mixtral-8x22b").num_experts_per_tok == 2
    assert R.get_config("arctic-480b").num_experts == 128
    assert R.get_config("falcon-mamba-7b").ssm_state == 16
    assert R.get_config("recurrentgemma-2b").block_pattern == \
        ("rglru", "rglru", "attn_local")


def test_reduced_variants_are_small():
    for arch in R.ASSIGNED_ARCHS:
        r = R.get_reduced(arch)
        assert r.num_layers <= 5
        assert r.d_model <= 512
        assert (r.num_experts or 0) <= 4
