"""RAG substrate: datasets, tokenizer, retrievers, GNN encoders."""
import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis; "
                           "pip install -e '.[test]'")
from hypothesis import given, settings, strategies as st

from repro.data.oag import generate_oag
from repro.data.scenegraph import generate_scene_graph
from repro.data.tokenizer import EOS, PAD, Tokenizer
from repro.gnn.gat import apply_gat, init_gat
from repro.gnn.graph_transformer import (apply_graph_transformer,
                                         init_graph_transformer)
from repro.rag.retriever import (GRAGRetriever, GRetrieverRetriever,
                                 RetrieverIndex)
from repro.rag.text_encoder import TextEncoder


def test_scene_graph_matches_paper_stats():
    g, qs = generate_scene_graph()
    assert g.num_nodes == 22
    assert g.num_edges == 147
    assert len(qs) == 426


def test_datasets_deterministic():
    g1, q1 = generate_scene_graph(seed=3)
    g2, q2 = generate_scene_graph(seed=3)
    assert g1.node_text == g2.node_text and g1.edges == g2.edges
    assert [q.question for q in q1] == [q.question for q in q2]


def test_scene_answers_grounded():
    g, qs = generate_scene_graph()
    for q in qs[:50]:
        if q.question.startswith("What is the color"):
            anchor = q.anchor_nodes[0]
            assert f"attribute: {q.answer}" in g.node_text[anchor]


def test_oag_answers_are_relations():
    g, qs = generate_oag(num_papers=50, num_authors=30, num_queries=100)
    rels = {"written by", "focuses on", "cites", "has member"}
    assert all(q.answer in rels for q in qs)


def test_tokenizer_roundtrip():
    tok = Tokenizer.train(["the quick brown fox", "jumps over the dog"])
    ids = tok.encode("the quick dog", bos=True, eos=True)
    assert ids[0] == 1 and ids[-1] == EOS
    assert tok.decode(ids) == "the quick dog"


def test_tokenizer_unk_and_pad():
    tok = Tokenizer.train(["hello world"])
    ids = tok.encode("hello zzzunknown")
    assert ids[1] == 3                 # UNK
    assert tok.decode([PAD, ids[0]]) == "hello"


def test_text_encoder_similarity_ordering():
    enc = TextEncoder(64)
    v = enc.encode(["red sweater color", "red sweater", "quantum physics"])
    sim_close = float(v[0] @ v[1])
    sim_far = float(v[0] @ v[2])
    assert sim_close > sim_far


@pytest.mark.parametrize("retr_cls", [GRetrieverRetriever, GRAGRetriever])
def test_retrieved_subgraphs_are_valid(retr_cls):
    g, qs = generate_scene_graph()
    idx = RetrieverIndex.build(g, TextEncoder(32))
    r = retr_cls(idx)
    all_edges = set(g.edges)
    for q in qs[:20]:
        sg = r.retrieve(q.question)
        assert sg.num_nodes > 0
        assert sg.edges <= all_edges
        for s, _, d in sg.edges:
            assert s in sg.nodes and d in sg.nodes


def test_retriever_anchor_recall_reasonable():
    g, qs = generate_scene_graph()
    idx = RetrieverIndex.build(g, TextEncoder(64))
    r = GRetrieverRetriever(idx)
    rec = np.mean([
        len(set(q.anchor_nodes) & r.retrieve(q.question).nodes)
        / len(q.anchor_nodes) for q in qs[:60]])
    assert rec > 0.4, rec


@pytest.mark.parametrize("init,apply", [
    (init_graph_transformer, apply_graph_transformer),
    (init_gat, apply_gat),
])
def test_gnn_encoders_shapes_and_grads(init, apply):
    import jax.numpy as jnp
    key = jax.random.PRNGKey(0)
    p = init(key, 16, 32, 2, 4)
    x = jax.random.normal(key, (5, 16))
    snd = jnp.array([0, 1, 2, 3, 4, 0], jnp.int32)
    rcv = jnp.array([1, 2, 3, 4, 0, 0], jnp.int32)
    ef = jax.random.normal(key, (6, 16))
    h = apply(p, x, snd, rcv, ef)
    assert h.shape == (5, 32)
    # grad w.r.t. the float weight subtree only ("num_heads" is an int leaf)
    g = jax.grad(lambda layers: jnp.sum(apply(
        {**p, "layers": layers}, x, snd, rcv, ef) ** 2))(p["layers"])
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_gnn_isolated_nodes_no_nan():
    import jax.numpy as jnp
    key = jax.random.PRNGKey(0)
    p = init_graph_transformer(key, 8, 16, 2, 2)
    x = jax.random.normal(key, (3, 8))
    # only self-loops
    snd = jnp.array([0, 1, 2], jnp.int32)
    rcv = jnp.array([0, 1, 2], jnp.int32)
    ef = jnp.zeros((3, 8))
    h = apply_graph_transformer(p, x, snd, rcv, ef)
    assert bool(jnp.all(jnp.isfinite(h)))
