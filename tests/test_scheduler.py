"""Online cluster serving: incremental assignment vs the offline planner,
pooled eviction under a byte budget, and multi-prefix batched serving
exactness vs per-cluster cascade serving (DESIGN.md §7)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import CacheStats, PrefixState
from repro.core.planner import plan_batch
from repro.core.prefix_pool import PrefixPool, state_bytes
from repro.core.subgraph import Subgraph
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import (ArrivalQueue, OnlineClusterAssigner,
                                     OnlineScheduler)


def _blobs(rng, centers, per, spread=0.05):
    """Well-separated gaussian blobs -> (embeddings [m,d], labels [m])."""
    emb, labels = [], []
    for c, ctr in enumerate(centers):
        emb.append(ctr + spread * rng.standard_normal((per, len(ctr))))
        labels += [c] * per
    return np.concatenate(emb), np.array(labels)


def _sg(i):
    return Subgraph.from_lists([i], [])


# ----------------------------------------------------------------------
# online assignment
# ----------------------------------------------------------------------
def test_online_assignment_matches_offline_plan():
    """Seeded from an offline plan_batch cut with threshold=inf, online
    nearest-representative assignment reproduces the offline labels on
    the same batch, and the cluster count stays respected (no spawn)."""
    rng = np.random.default_rng(0)
    centers = [np.array([0.0, 0.0]), np.array([10.0, 0.0]),
               np.array([0.0, 10.0])]
    emb, _ = _blobs(rng, centers, per=5)
    subs = [_sg(i) for i in range(len(emb))]
    plan = plan_batch(subs, emb, num_clusters=3)

    a = OnlineClusterAssigner.from_plan(plan, emb, threshold=math.inf)
    assert len(a.clusters) == 3
    offline_label = {}
    for j, cp in enumerate(plan.clusters):
        for i in cp.member_indices:
            offline_label[i] = j
    for i in range(len(emb)):
        asg = a.assign(emb[i])
        assert not asg.is_new
        assert asg.cluster_id == offline_label[i], i
    assert len(a.clusters) == 3          # threshold=inf never spawns


def test_online_spawn_threshold_and_cap():
    rng = np.random.default_rng(1)
    centers = [np.array([0.0, 0.0]), np.array([10.0, 0.0]),
               np.array([0.0, 10.0])]
    emb, labels = _blobs(rng, centers, per=4)
    order = rng.permutation(len(emb))

    a = OnlineClusterAssigner(threshold=1.0)
    spawned = {}
    for i in order:
        asg = a.assign(emb[i], _sg(int(i)))
        if asg.is_new:
            spawned[labels[i]] = asg.cluster_id
        else:                      # joined the cluster its blob spawned
            assert asg.cluster_id == spawned[labels[i]]
            assert asg.distance <= 1.0
    assert len(a.clusters) == 3    # exactly one spawn per blob

    # capped: the third blob cannot spawn and joins its nearest cluster
    b = OnlineClusterAssigner(threshold=1.0, max_clusters=2)
    for i in order:
        b.assign(emb[i], _sg(int(i)))
    assert len(b.clusters) == 2

    # spawning without a subgraph is an error (nothing to represent)
    c = OnlineClusterAssigner(threshold=1.0)
    with pytest.raises(ValueError):
        c.assign(np.zeros(2))


# ----------------------------------------------------------------------
# arrival queue
# ----------------------------------------------------------------------
def test_arrival_queue_drains_by_time_and_slots():
    q = ArrivalQueue()
    for t, name in [(0.3, "c"), (0.1, "a"), (0.2, "b"), (0.9, "d")]:
        q.push(t, name)
    assert q.next_arrival() == pytest.approx(0.1)
    got = q.drain(now=0.35, max_slots=2)
    assert [a.payload for a in got] == ["a", "b"]       # oldest first
    got = q.drain(now=0.35, max_slots=8)
    assert [a.payload for a in got] == ["c"]            # d not arrived yet
    assert len(q) == 1
    assert q.drain(now=1.0, max_slots=8)[0].payload == "d"
    assert q.next_arrival() is None


# ----------------------------------------------------------------------
# prefix pool
# ----------------------------------------------------------------------
def _state(prefix_len, n_floats=1024):
    cache = {"k": jnp.zeros((n_floats,), jnp.float32)}
    return PrefixState(cache=cache, prefix_len=prefix_len,
                       capacity=prefix_len)


def test_pool_respects_byte_budget_and_counts():
    one = state_bytes(_state(8))
    stats = CacheStats()
    pool = PrefixPool(budget_bytes=2 * one, stats=stats)
    assert pool.get("a") is None                        # cold miss
    pool.put("a", _state(8))
    pool.put("b", _state(8))
    assert pool.get("a") is not None                    # hit bumps 'a'
    pool.put("c", _state(8))                            # over budget
    assert pool.bytes_in_use <= pool.budget_bytes
    assert len(pool) == 2
    # 'b' was the coldest (no hits, oldest touch) -> evicted
    assert "b" not in pool and "a" in pool and "c" in pool
    assert stats.pool_evictions == 1
    assert stats.pool_hits == 1 and stats.pool_misses == 1
    # readmission after eviction counts as a re-prefill
    pool.put("b", _state(8))
    assert stats.pool_reprefills == 1


def test_pool_eviction_is_cost_aware():
    """A long stale prefix outranks a short equally-stale one for
    eviction (score ~ age * prefix_len / hits), and hits protect."""
    one = state_bytes(_state(8, 1024))
    pool = PrefixPool(budget_bytes=3 * one)
    pool.put("long", _state(64, 1024))
    pool.put("short", _state(8, 1024))
    pool.get("long")                   # equal recency, then both idle
    pool.get("short")
    pool.put("x", _state(8, 1024))
    pool.put("y", _state(8, 1024))     # forces one eviction
    assert "long" not in pool          # big and no hotter -> first out
    assert "short" in pool


def test_pool_admission_survives_its_own_eviction_pass():
    """Regression: a long fresh prefix out-scores every resident entry
    (score ~ prefix_len), but an admission must never evict ITSELF —
    the caller prefilled it because a query needs it right now."""
    one = state_bytes(_state(8, 1024))
    pool = PrefixPool(budget_bytes=3 * one)
    for k in ("a", "b", "c"):
        pool.put(k, _state(8, 1024))
    pool.put("big", _state(512, 1024))      # highest eviction score
    assert "big" in pool
    assert pool.bytes_in_use <= pool.budget_bytes
    assert len(pool) == 3                   # one short resident evicted


def test_pool_never_evicts_in_flight():
    one = state_bytes(_state(8))
    pool = PrefixPool(budget_bytes=one)     # room for a single state
    pool.put("a", _state(8))
    with pool.using(["a"]):
        pool.put("b", _state(8))            # over budget while 'a' pinned
        assert "a" in pool                  # pinned survives ...
        assert "b" not in pool or pool.bytes_in_use > pool.budget_bytes
    # after release the budget is enforced again
    pool.put("c", _state(8))
    assert pool.bytes_in_use <= pool.budget_bytes
    assert "a" not in pool                  # released -> evictable


# ----------------------------------------------------------------------
# multi-prefix batched serving: exact vs per-cluster cascade
# ----------------------------------------------------------------------
def _gqa_cfg(vocab, dtype="float32", impl="xla"):
    return ModelConfig(name="sched-test", family="dense", num_layers=3,
                       d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
                       d_ff=160, vocab_size=vocab, dtype=dtype,
                       attention_impl=impl)


@pytest.fixture(scope="module")
def tok():
    return Tokenizer.train(["the quick brown fox jumps over the lazy dog "
                            "a graph of nodes and edges answers questions"])


@pytest.mark.parametrize("dtype,impl", [("float32", "xla"),
                                        ("bfloat16", "pallas")])
def test_generate_multi_prefix_exact_vs_per_cluster(tok, dtype, impl):
    """One mixed PAGED batch over TWO pooled prefixes (different
    lengths, so different block counts — members share their cluster's
    prefix blocks physically) must reproduce per-cluster DENSE cascade
    serving token for token — GQA, and the bf16 Pallas kernel path
    (the paged kernels walk the page tables via scalar prefetch)."""
    cfg = _gqa_cfg(tok.vocab_size, dtype, impl)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, tok, max_cache_len=512,
                        max_new_tokens=5)
    dense = ServingEngine(params, cfg, tok, max_cache_len=512,
                          max_new_tokens=5, paged=False)
    assert eng.use_paged and not dense.use_paged
    p_short = tok.encode("a graph of nodes", bos=True)
    p_long = tok.encode("the quick brown fox jumps over the lazy dog "
                        + "a graph of nodes and edges " * 24, bos=True)
    st0, _ = eng.prefill_prefix(p_short)
    st1, _ = eng.prefill_prefix(p_long)
    assert st0.is_paged and st1.is_paged
    assert len(st0.page.blocks) < len(st1.page.blocks)
    # members of one cluster share the SAME physical blocks; only the
    # two prefixes' own blocks are resident — no padded stacked copy
    assert eng.block_pool.blocks_in_use == (len(st0.page.blocks)
                                            + len(st1.page.blocks))

    sfx = [tok.encode("answers questions"), tok.encode("and edges"),
           tok.encode("lazy dog jumps"), tok.encode("the quick")]
    pids = [0, 1, 1, 0]
    multi, t = eng.generate_multi_prefix([st0, st1], pids, sfx)
    assert t["split_prefix"] and t["paged"] and t["num_prefixes"] == 2

    d0, _ = dense.prefill_prefix(p_short)
    d1, _ = dense.prefill_prefix(p_long)
    ref = [None] * 4
    o0, _ = dense.generate_with_prefix(d0, [sfx[0], sfx[3]])
    o1, _ = dense.generate_with_prefix(d1, [sfx[1], sfx[2]])
    ref[0], ref[3] = o0
    ref[1], ref[2] = o1
    assert multi == ref
    # suffix blocks freed after the batch; prefix blocks still resident
    assert eng.block_pool.blocks_in_use == (len(st0.page.blocks)
                                            + len(st1.page.blocks))
    st0.release()
    st1.release()
    assert eng.block_pool.blocks_in_use == 0


def test_generate_multi_prefix_stateful_fallback(tok):
    """Recurrent stacks cannot split a positional prefix: the pooled
    call must group per cluster and still match single-cluster serving."""
    cfg = ModelConfig(name="ssm-t", family="ssm", num_layers=2, d_model=64,
                      num_heads=0, num_kv_heads=0, d_ff=0, ssm_state=8,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    eng = ServingEngine(params, cfg, tok, max_cache_len=512,
                        max_new_tokens=4)
    assert eng._stateful
    st0, _ = eng.prefill_prefix(tok.encode("a graph of nodes", bos=True))
    st1, _ = eng.prefill_prefix(tok.encode("the lazy dog", bos=True))
    sfx = [tok.encode("answers questions"), tok.encode("and edges go"),
           tok.encode("the quick")]
    pids = [0, 1, 0]
    multi, t = eng.generate_multi_prefix([st0, st1], pids, sfx)
    assert not t["split_prefix"]
    ref = [None] * 3
    o0, _ = eng.generate_with_prefix(st0, [sfx[0], sfx[2]])
    o1, _ = eng.generate_with_prefix(st1, [sfx[1]])
    ref[0], ref[2] = o0
    ref[1] = o1[0]
    assert multi == ref


def test_stateful_subbatch_timing_attribution(tok):
    """Bugfix regression: ragged suffix lengths on a stateful arch are
    served as equal-length sub-batches; each member's share must come
    from its OWN sub-batch and the shares must add up to the totals."""
    cfg = ModelConfig(name="ssm-t2", family="ssm", num_layers=2, d_model=64,
                      num_heads=0, num_kv_heads=0, d_ff=0, ssm_state=8,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    eng = ServingEngine(params, cfg, tok, max_cache_len=512,
                        max_new_tokens=4)
    sfx = [tok.encode("answers questions a graph of nodes and edges"),
           tok.encode("dog"),
           tok.encode("dog")]                 # two length groups
    state, _ = eng.prefill_prefix(tok.encode("the quick brown", bos=True))
    _, t = eng.generate_with_prefix(state, sfx)
    assert len(t["prefill_share"]) == 3 and len(t["decode_share"]) == 3
    assert sum(t["prefill_share"]) == pytest.approx(t["prefill_s"])
    assert sum(t["decode_share"]) == pytest.approx(t["decode_s"])
    # the two short members sat in the same sub-batch -> equal shares
    assert t["prefill_share"][1] == pytest.approx(t["prefill_share"][2])
    # members of different sub-batches are NOT billed a global average
    assert t["prefill_share"][0] != pytest.approx(t["prefill_share"][1])


# ----------------------------------------------------------------------
# PrefixPool under the paged backend (satellite coverage)
# ----------------------------------------------------------------------
def _paged_engine(tok, key=7, **kw):
    cfg = _gqa_cfg(tok.vocab_size)
    params = M.init_params(jax.random.PRNGKey(key), cfg)
    return ServingEngine(params, cfg, tok, max_cache_len=512,
                         max_new_tokens=3, **kw)


def test_pool_paged_refcount_pins_across_inflight_batches(tok):
    """An entry evicted while an in-flight batch still walks its blocks
    must not free them: the batch holds its own block references, and
    the blocks return to the free list only when it releases."""
    eng = _paged_engine(tok)
    bp = eng.block_pool
    st, _ = eng.prefill_prefix(tok.encode("a graph of nodes", bos=True),
                               _record=False)
    pool = PrefixPool(budget_bytes=state_bytes(st))
    pool.attach_block_pool(bp)
    pool.put("a", st)
    blocks = list(st.page.blocks)

    # batch A takes in-flight references (what _serve_paged does)
    bp.incref(blocks)
    # overlapping admission evicts "a" (budget fits one state)
    st_b, _ = eng.prefill_prefix(
        tok.encode("the quick brown fox jumps over", bos=True),
        _record=False)
    pool.put("b", st_b)
    assert "a" not in pool
    # evicted, but batch A still holds the blocks -> not freed
    assert all(bp.allocator.refcount(b) == 1 for b in blocks)
    in_use = bp.blocks_in_use
    bp.decref(blocks)                   # batch A completes
    assert bp.blocks_in_use == in_use - len(blocks)


def test_pool_paged_cow_after_shared_block_evicted(tok):
    """Copy-on-write: after an entry whose blocks an in-flight reader
    shares is evicted, a writer must get a COPY — the reader's KV is
    bit-identical before and after, and the original block frees when
    the reader releases."""
    eng = _paged_engine(tok)
    bp = eng.block_pool
    st, _ = eng.prefill_prefix(tok.encode("a graph of nodes and edges",
                                          bos=True), _record=False)
    pool = PrefixPool(budget_bytes=state_bytes(st))
    pool.attach_block_pool(bp)
    pool.put("a", st)
    shared = st.page.blocks[0]
    row = np.asarray([[shared]])
    before = np.asarray(bp.gather(row)["groups"]["0"]["k"])

    bp.incref([shared])                 # in-flight batch A
    bp.incref([shared])                 # overlapping in-flight batch B
    st_b, _ = eng.prefill_prefix(tok.encode("the quick brown fox jumps "
                                            "over the lazy dog", bos=True),
                                 _record=False)
    pool.put("b", st_b)                 # evicts "a"; A and B's refs remain
    assert bp.allocator.refcount(shared) == 2

    # batch A wants to WRITE (e.g. extend its prefix in place): B still
    # reads the block, so A must get a copy
    new = bp.cow(shared)
    assert new != shared
    assert bp.allocator.refcount(shared) == 1   # A's ref moved to the copy
    np.testing.assert_array_equal(
        np.asarray(bp.gather(np.asarray([[new]]))["groups"]["0"]["k"]),
        before)
    # B's view untouched by whatever A writes next
    np.testing.assert_array_equal(
        np.asarray(bp.gather(row)["groups"]["0"]["k"]), before)
    free_before = bp.free_blocks
    bp.decref([shared])                 # batch B completes -> block frees
    assert bp.free_blocks == free_before + 1
    # a uniquely-referenced block needs no copy
    assert bp.cow(new) == new


def test_pool_paged_reprefill_counter(tok):
    """Miss -> prefill -> admit -> evict -> miss -> re-prefill: the
    readmission is counted as a re-prefill and the freed blocks are
    recycled for the new state."""
    eng = _paged_engine(tok)
    stats = eng.cache_mgr.reset_stats()
    one, _ = eng.prefill_prefix(tok.encode("a graph of nodes", bos=True),
                                _record=False)
    pool = PrefixPool(budget_bytes=state_bytes(one), stats=stats)
    pool.attach_block_pool(eng.block_pool)
    one.release()

    def materialize(key, text):
        st = pool.get(key)
        if st is None:
            st, _ = eng.prefill_prefix(tok.encode(text, bos=True),
                                       _record=False)
            pool.put(key, st)
        return st

    materialize("a", "a graph of nodes")
    materialize("b", "the quick brown")          # evicts "a"
    assert "a" not in pool and stats.pool_evictions == 1
    materialize("a", "a graph of nodes")         # readmission
    assert stats.pool_reprefills == 1
    assert stats.pool_misses == 3 and stats.pool_hits == 0
    assert pool.get("a") is not None
    assert stats.pool_hits == 1
    # only the resident state's blocks are held
    resident = pool.entry("a").state
    assert eng.block_pool.blocks_in_use == len(resident.page.blocks)


def test_block_allocator_reclaims_from_pool_on_pressure(tok):
    """Arena exhaustion evicts cold pooled prefixes instead of failing:
    admission pressure and HBM pressure are one page-table operation."""
    eng = _paged_engine(tok, arena_blocks=2)     # tiny arena
    bp = eng.block_pool
    pool = PrefixPool(budget_bytes=1 << 30)      # byte budget never binds
    pool.attach_block_pool(bp)
    texts = ["a graph of nodes", "the quick brown", "lazy dog jumps"]
    for i, txt in enumerate(texts):
        st, _ = eng.prefill_prefix(tok.encode(txt, bos=True),
                                   _record=False)
        pool.put(i, st)
    # every prefix is 1 block and only 2 fit: the third prefill's block
    # allocation reclaimed one resident entry instead of raising
    assert len(pool) == 2 and 2 in pool
    assert sum(k in pool for k in (0, 1)) == 1
    assert pool.stats.pool_evictions == 1


def test_replacing_a_pool_releases_the_old_pools_blocks(tok):
    """Regression: a fresh serving window (new PrefixPool attached to
    the same engine arena) must release the abandoned pool's resident
    blocks — nothing else ever would, and each replaced pool would
    otherwise shrink the arena by one working set."""
    eng = _paged_engine(tok)
    bp = eng.block_pool
    pool1 = PrefixPool(budget_bytes=1 << 30)
    pool1.attach_block_pool(bp)
    st, _ = eng.prefill_prefix(tok.encode("a graph of nodes", bos=True),
                               _record=False)
    pool1.put("a", st)
    assert bp.blocks_in_use > 0
    pool2 = PrefixPool(budget_bytes=1 << 30)
    pool2.attach_block_pool(bp)          # replaces pool1
    assert bp.blocks_in_use == 0         # pool1's residents released
    assert len(pool1) == 0
    assert bp.allocator.reclaim_hook == pool2._reclaim_blocks


def test_failed_paged_serve_drops_inflight_pins(tok):
    """Regression: a serve that fails AFTER pinning its prefix blocks
    (here: suffix overflows max_cache_len) must drop the pins and leave
    the arena servable — phantom references would make the blocks
    unfreeable forever."""
    eng = _paged_engine(tok)
    st, _ = eng.prefill_prefix(tok.encode("a graph of nodes", bos=True),
                               _record=False)
    base = [eng.block_pool.allocator.refcount(b) for b in st.page.blocks]
    with pytest.raises(ValueError, match="max_cache_len"):
        eng.generate_with_prefix(st, [[5] * 600], _record=False)
    assert [eng.block_pool.allocator.refcount(b)
            for b in st.page.blocks] == base
    outs, _ = eng.generate_with_prefix(st, [tok.encode("answers")],
                                       _record=False)
    assert len(outs) == 1                # arena still serves
    st.release()
    assert eng.block_pool.blocks_in_use == 0


# ----------------------------------------------------------------------
# scheduler end-to-end (assign + pool + engine)
# ----------------------------------------------------------------------
def test_scheduler_serves_mixed_batches_with_pool_hits(tok):
    cfg = _gqa_cfg(tok.vocab_size)
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    eng = ServingEngine(params, cfg, tok, max_cache_len=512,
                        max_new_tokens=4)
    stats = eng.cache_mgr.reset_stats()
    reps = {0: tok.encode("a graph of nodes and edges", bos=True),
            1: tok.encode("the quick brown fox", bos=True)}
    sched = OnlineScheduler(
        eng, OnlineClusterAssigner(threshold=1.0),
        PrefixPool(budget_bytes=1 << 30),
        lambda sg: reps[min(sg.nodes)])
    emb = {0: np.array([0.0, 0.0]), 1: np.array([10.0, 0.0])}

    # batch 1: both clusters spawn (2 misses), members mix in one batch
    served = sched.serve_batch(
        [emb[0], emb[1], emb[0]], [_sg(0), _sg(1), _sg(0)],
        [tok.encode("answers"), tok.encode("lazy dog"), tok.encode("jumps")])
    assert [s.cluster_id for s in served] == [0, 1, 0]
    assert [s.spawned for s in served] == [True, True, False]
    assert not any(s.pool_hit for s in served)
    assert stats.pool_misses == 2 and stats.pool_hits == 0

    # batch 2: same clusters -> pure pool hits, no prefix prefill cost
    served = sched.serve_batch(
        [emb[1], emb[0]], [_sg(1), _sg(0)],
        [tok.encode("the quick"), tok.encode("and edges")])
    assert all(s.pool_hit for s in served)
    assert all(s.prefix_share_s == 0.0 for s in served)
    assert stats.pool_hits == 2
    # outputs match direct single-cluster serving against pooled states
    o_direct, _ = eng.generate_with_prefix(
        sched.pool.get(1), [tok.encode("the quick")], _record=False)
    assert served[0].tokens == o_direct[0]


def test_scheduler_survives_budget_smaller_than_batch(tok):
    """Regression: a batch touching more prefix bytes than the pool
    budget must still serve — states are pinned the moment they are
    acquired (materialize-and-pin), so a later admission in the same
    batch can neither evict them nor crash the pin; the budget is
    enforced again once the batch releases."""
    cfg = _gqa_cfg(tok.vocab_size)
    params = M.init_params(jax.random.PRNGKey(5), cfg)
    eng = ServingEngine(params, cfg, tok, max_cache_len=512,
                        max_new_tokens=3)
    reps = {0: tok.encode("a graph of nodes and edges", bos=True),
            1: tok.encode("the quick brown fox", bos=True)}
    pool = PrefixPool(budget_bytes=1)          # nothing fits unpinned
    sched = OnlineScheduler(
        eng, OnlineClusterAssigner(threshold=1.0), pool,
        lambda sg: reps[min(sg.nodes)])
    served = sched.serve_batch(
        [np.array([0.0, 0.0]), np.array([10.0, 0.0])],
        [_sg(0), _sg(1)],
        [tok.encode("answers"), tok.encode("lazy dog")])
    assert [s.cluster_id for s in served] == [0, 1]
    assert all(s.tokens for s in served)
    assert len(pool) == 0                      # released -> evicted


def test_pipeline_serve_stream_end_to_end():
    """Streaming trace through the full RAG pipeline: every query is
    answered, queue waits are non-negative and feed TTFT, pool
    accounting is consistent, and a warm scheduler keeps its clusters."""
    from repro.data.scenegraph import generate_scene_graph
    from repro.rag.pipeline import GraphRAGPipeline
    from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
    from repro.rag.text_encoder import TextEncoder

    graph, queries = generate_scene_graph()
    tok2 = Tokenizer.train([q.question + " " + q.answer
                            for q in queries] + graph.node_text,
                           max_vocab=2048)
    cfg = ModelConfig(name="stream-t", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=tok2.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(32))
    pipe = GraphRAGPipeline(
        index=index, retriever=GRetrieverRetriever(index),
        engine=ServingEngine(params, cfg, tok2, max_cache_len=512,
                             max_new_tokens=3),
        tokenizer=tok2, use_soft_prompt=False)

    items = queries[:6]
    arrivals = [0.0, 0.0, 0.1, 0.1, 5.0, 5.0]     # two bursts
    recs, summary, sched = pipe.serve_stream(items, arrivals, max_batch=4,
                                             threshold=0.25,
                                             pool_budget_bytes=1 << 26)
    assert all(r is not None and r.generated is not None for r in recs)
    assert all(r.queue_wait_s >= 0 for r in recs)
    assert summary.num_queries == 6
    stats = sched.pool.stats
    assert stats.pool_hits + stats.pool_misses >= len(
        sched.assigner.clusters)
    assert stats.num_queries == 6                  # engine-side accounting
    # ttft includes the queue wait
    r = recs[0]
    assert r.ttft == pytest.approx(
        r.queue_wait_s + r.retrieval_s + r.cluster_share_s
        + r.prompt_build_s + r.prefix_share_s + r.prefill_s
        + r.first_token_s)

    # a warm scheduler is reusable: clusters persist, pool hits accrue
    n_clusters = len(sched.assigner.clusters)
    _, _, sched2 = pipe.serve_stream(items[:2], [0.0, 0.0],
                                     max_batch=4, scheduler=sched)
    assert sched2 is sched
    assert len(sched.assigner.clusters) >= n_clusters
    assert sched.pool.stats.pool_hits >= 1        # fresh window, warm pool
