"""Pallas kernel validation: shape/dtype sweeps vs the ref.py jnp oracles
(interpret mode on CPU; the kernels target TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _mk_qkv(b, hq, hkv, tq, s, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, tq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("b,hq,hkv,tq,s,d", [
    (1, 4, 4, 8, 32, 32),      # MHA
    (2, 8, 2, 16, 64, 64),     # GQA
    (2, 4, 1, 7, 40, 32),      # MQA, unaligned lengths
    (1, 2, 2, 33, 129, 16),    # prime-ish padding path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefix_attention_sweep(b, hq, hkv, tq, s, d, dtype):
    q, k, v = _mk_qkv(b, hq, hkv, tq, s, d, dtype)
    prefix = s // 2
    k_pos = jnp.where(jnp.arange(s)[None] < prefix + tq,
                      jnp.arange(s)[None], -1)
    k_pos = jnp.broadcast_to(k_pos, (b, s))
    q_pos = jnp.broadcast_to(prefix + jnp.arange(tq)[None], (b, tq))
    out = ops.prefix_attention(q, k, v, q_pos, k_pos, block_q=8, block_k=16)
    want = ref.prefix_attention_ref(q, k, v, q_pos, k_pos)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("window", [4, 16, 0])
def test_prefix_attention_window(window):
    q, k, v = _mk_qkv(2, 4, 2, 12, 48, 32, jnp.float32)
    k_pos = jnp.broadcast_to(jnp.arange(48)[None], (2, 48))
    q_pos = jnp.broadcast_to(36 + jnp.arange(12)[None], (2, 12))
    out = ops.prefix_attention(q, k, v, q_pos, k_pos, window=window,
                               block_q=8, block_k=16)
    want = ref.prefix_attention_ref(q, k, v, q_pos, k_pos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_prefix_attention_fully_masked_rows_zero():
    """Padded queries whose every key is masked must output 0, not NaN."""
    q, k, v = _mk_qkv(1, 2, 2, 4, 16, 16, jnp.float32)
    k_pos = jnp.full((1, 16), -1, jnp.int32)         # nothing valid
    q_pos = jnp.broadcast_to(jnp.arange(4)[None], (1, 4))
    out = ops.prefix_attention(q, k, v, q_pos, k_pos, block_q=4, block_k=8)
    assert bool(jnp.all(out == 0.0))


# ----------------------------------------------------------------------
# shared-prefix cascade: partial attention + LSE merge
# ----------------------------------------------------------------------
@pytest.mark.parametrize("kv_batch", ["shared", "member"])
@pytest.mark.parametrize("b,hq,hkv,tq,s,d", [
    (2, 4, 4, 8, 32, 32),      # MHA
    (3, 8, 2, 7, 40, 32),      # GQA, unaligned lengths
    (2, 4, 1, 33, 129, 16),    # MQA, prime-ish padding path
])
def test_attention_partial_sweep(kv_batch, b, hq, hkv, tq, s, d):
    bk = 1 if kv_batch == "shared" else b
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, tq, d))
    k = jax.random.normal(ks[1], (bk, hkv, s, d))
    v = jax.random.normal(ks[2], (bk, hkv, s, d))
    k_pos = jnp.where(jnp.arange(s)[None] < s - 3, jnp.arange(s)[None], -1)
    k_pos = jnp.broadcast_to(k_pos, (bk, s))
    q_pos = jnp.broadcast_to(s + jnp.arange(tq)[None], (b, tq))
    out, m, l = ops.attention_partial(q, k, v, q_pos, k_pos, causal=False,
                                      block_q=8, block_k=16)
    out_r, m_r, l_r = ref.attention_partial_ref(q, k, v, q_pos, k_pos,
                                                causal=False)
    for got, want in ((out, out_r), (m, m_r), (l, l_r)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("kv_batch", ["shared", "member"])
@pytest.mark.parametrize("window", [0, 6])
def test_decode_gqa_partial_cascade(kv_batch, window):
    """Decode-shaped partials (prefix + suffix) merged must equal decode
    over the concatenated KV."""
    b, hq, hkv, p_len, s_len, d = 2, 8, 2, 24, 10, 32
    bk = 1 if kv_batch == "shared" else b
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, hq, d))
    pk = jax.random.normal(ks[1], (bk, hkv, p_len, d))
    pv = jax.random.normal(ks[2], (bk, hkv, p_len, d))
    sk = jax.random.normal(ks[3], (b, hkv, s_len, d))
    sv = jax.random.normal(ks[4], (b, hkv, s_len, d))
    p_pos = jnp.broadcast_to(jnp.arange(p_len)[None], (bk, p_len))
    s_pos = jnp.broadcast_to(p_len + jnp.arange(s_len)[None], (b, s_len))
    q_pos = jnp.full((b,), p_len + s_len - 1, jnp.int32)

    o1 = ops.decode_gqa_partial(q, pk, pv, q_pos, p_pos, window=window,
                                block_k=16)
    o2 = ops.decode_gqa_partial(q, sk, sv, q_pos, s_pos, window=window,
                                block_k=8)
    got, _, _ = ref.merge_partials_ref(*o1, *o2)

    k_all = jnp.concatenate([jnp.broadcast_to(pk, (b,) + pk.shape[1:]), sk], 2)
    v_all = jnp.concatenate([jnp.broadcast_to(pv, (b,) + pv.shape[1:]), sv], 2)
    pos_all = jnp.concatenate(
        [jnp.broadcast_to(p_pos, (b, p_len)), s_pos], 1)
    want = ref.decode_gqa_ref(q, k_all, v_all, q_pos, pos_all, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_lse_merge_matches_ref():
    """N-way fold entry point vs the jnp oracle on synthetic partials.
    (The pairwise Pallas merge kernel is gone — the paged cascade folds
    in-kernel now — so ``ops.fold_partials`` is the merge surface.)"""
    b, hq, tq, d = 2, 4, 13, 16
    ks = jax.random.split(KEY, 6)
    o1 = jax.random.normal(ks[0], (b, hq, tq, d))
    o2 = jax.random.normal(ks[1], (b, hq, tq, d))
    m1 = jax.random.normal(ks[2], (b, hq, tq)) * 3
    m2 = jax.random.normal(ks[3], (b, hq, tq)) * 3
    l1 = jax.nn.softplus(jax.random.normal(ks[4], (b, hq, tq)))
    l2 = jax.nn.softplus(jax.random.normal(ks[5], (b, hq, tq)))
    # include empty partials (fully-masked rows): l = 0, m = NEG_INF
    l1 = l1.at[0, 0, :3].set(0.0)
    m1 = m1.at[0, 0, :3].set(ref.NEG_INF)
    got, gm, gl = ops.fold_partials([(o1, m1, l1), (o2, m2, l2)])
    want, wm, wl = ref.merge_partials_ref(o1, m1, l1, o2, m2, l2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(gm), np.asarray(wm), atol=2e-5)
    np.testing.assert_allclose(np.asarray(gl), np.asarray(wl), atol=2e-5,
                               rtol=2e-5)


def test_partial_merge_equals_full_attention():
    """Cascade invariant: merge(prefix partial, suffix partial) must equal
    one softmax over the concatenated KV — the exactness the split
    serving path rests on."""
    b, hq, hkv, tq, p_len, s_len, d = 2, 8, 2, 9, 37, 11, 32
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, hq, tq, d))
    pk = jax.random.normal(ks[1], (1, hkv, p_len, d))
    pv = jax.random.normal(ks[2], (1, hkv, p_len, d))
    sk = jax.random.normal(ks[3], (b, hkv, s_len, d))
    sv = jax.random.normal(ks[4], (b, hkv, s_len, d))
    p_pos = jnp.arange(p_len)[None]
    q_pos = jnp.broadcast_to(p_len + jnp.arange(tq)[None], (b, tq))
    s_pos = jnp.broadcast_to(p_len + jnp.arange(s_len)[None], (b, s_len))

    o1 = ops.attention_partial(q, pk, pv, q_pos, p_pos, causal=False,
                               block_q=8, block_k=16)
    o2 = ops.attention_partial(q, sk, sv, q_pos, s_pos, causal=True,
                               block_q=8, block_k=8)
    got, _, _ = ops.fold_partials([o1, o2])

    k_all = jnp.concatenate([jnp.broadcast_to(pk, (b,) + pk.shape[1:]), sk], 2)
    v_all = jnp.concatenate([jnp.broadcast_to(pv, (b,) + pv.shape[1:]), sv], 2)
    pos_all = jnp.concatenate([jnp.broadcast_to(p_pos, (b, p_len)), s_pos], 1)
    want = ref.prefix_attention_ref(q, k_all, v_all, q_pos, pos_all,
                                    causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 4, 4, 32, 32), (2, 8, 2, 64, 64), (3, 6, 1, 100, 32),
])
def test_decode_gqa_sweep(b, hq, hkv, s, d):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    q_pos = jnp.arange(b) * 3 + s // 2
    k_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out = ops.decode_gqa(q, k, v, q_pos, k_pos, block_k=16)
    want = ref.decode_gqa_ref(q, k, v, q_pos, k_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_decode_gqa_ring_buffer_order_invariance():
    """Slot order must not matter — only stored positions."""
    b, hq, hkv, s, d = 1, 4, 2, 16, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    k_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q_pos = jnp.array([s])
    base = ops.decode_gqa(q, k, v, q_pos, k_pos, block_k=8)
    perm = jax.random.permutation(KEY, s)
    out = ops.decode_gqa(q, k[:, :, perm], v[:, :, perm], q_pos,
                         k_pos[:, perm], block_k=8)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("bt,t,di,n,bd,btk", [
    (1, 16, 32, 8, 16, 8), (2, 37, 64, 16, 32, 16), (2, 64, 128, 8, 64, 64),
])
def test_ssm_scan_sweep(bt, t, di, n, bd, btk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (bt, t, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, t, di))) * 0.1
    B = jax.random.normal(ks[2], (bt, t, n))
    C = jax.random.normal(ks[3], (bt, t, n))
    A = -jnp.exp(jax.random.normal(ks[4], (di, n)))
    h0 = jax.random.normal(KEY, (bt, di, n))
    y, hT = ops.ssm_scan(x, dt, B, C, A, h0, block_d=bd, block_t=btk)
    yr, hTr = ref.ssm_scan_ref(x, dt, B, C, A, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTr), atol=1e-4,
                               rtol=1e-4)


def test_ssm_scan_chunked_equals_onechunk():
    """State carry across time-chunk grid steps must be exact."""
    bt, t, di, n = 1, 64, 32, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (bt, t, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, t, di))) * 0.1
    B = jax.random.normal(ks[2], (bt, t, n))
    C = jax.random.normal(ks[3], (bt, t, n))
    A = -jnp.exp(jax.random.normal(ks[4], (di, n)))
    y1, h1 = ops.ssm_scan(x, dt, B, C, A, block_d=32, block_t=64)
    y2, h2 = ops.ssm_scan(x, dt, B, C, A, block_d=32, block_t=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5,
                               rtol=1e-5)


@pytest.mark.parametrize("b,t,w,bw,btk", [
    (1, 16, 32, 16, 8), (2, 37, 48, 16, 16), (2, 64, 128, 64, 32),
])
def test_rglru_scan_sweep(b, t, w, bw, btk):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (b, t, w))
    a_log = -jax.nn.softplus(jax.random.normal(ks[1], (b, t, w)))
    h0 = jax.random.normal(KEY, (b, w))
    y, hT = ops.rglru_scan(x, a_log, h0, block_w=bw, block_t=btk)
    yr, hTr = ref.rglru_scan_ref(x, a_log, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTr), atol=1e-5,
                               rtol=1e-5)
