"""Pallas kernel validation: shape/dtype sweeps vs the ref.py jnp oracles
(interpret mode on CPU; the kernels target TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _mk_qkv(b, hq, hkv, tq, s, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, tq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("b,hq,hkv,tq,s,d", [
    (1, 4, 4, 8, 32, 32),      # MHA
    (2, 8, 2, 16, 64, 64),     # GQA
    (2, 4, 1, 7, 40, 32),      # MQA, unaligned lengths
    (1, 2, 2, 33, 129, 16),    # prime-ish padding path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefix_attention_sweep(b, hq, hkv, tq, s, d, dtype):
    q, k, v = _mk_qkv(b, hq, hkv, tq, s, d, dtype)
    prefix = s // 2
    k_pos = jnp.where(jnp.arange(s)[None] < prefix + tq,
                      jnp.arange(s)[None], -1)
    k_pos = jnp.broadcast_to(k_pos, (b, s))
    q_pos = jnp.broadcast_to(prefix + jnp.arange(tq)[None], (b, tq))
    out = ops.prefix_attention(q, k, v, q_pos, k_pos, block_q=8, block_k=16)
    want = ref.prefix_attention_ref(q, k, v, q_pos, k_pos)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("window", [4, 16, 0])
def test_prefix_attention_window(window):
    q, k, v = _mk_qkv(2, 4, 2, 12, 48, 32, jnp.float32)
    k_pos = jnp.broadcast_to(jnp.arange(48)[None], (2, 48))
    q_pos = jnp.broadcast_to(36 + jnp.arange(12)[None], (2, 12))
    out = ops.prefix_attention(q, k, v, q_pos, k_pos, window=window,
                               block_q=8, block_k=16)
    want = ref.prefix_attention_ref(q, k, v, q_pos, k_pos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_prefix_attention_fully_masked_rows_zero():
    """Padded queries whose every key is masked must output 0, not NaN."""
    q, k, v = _mk_qkv(1, 2, 2, 4, 16, 16, jnp.float32)
    k_pos = jnp.full((1, 16), -1, jnp.int32)         # nothing valid
    q_pos = jnp.broadcast_to(jnp.arange(4)[None], (1, 4))
    out = ops.prefix_attention(q, k, v, q_pos, k_pos, block_q=4, block_k=8)
    assert bool(jnp.all(out == 0.0))


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 4, 4, 32, 32), (2, 8, 2, 64, 64), (3, 6, 1, 100, 32),
])
def test_decode_gqa_sweep(b, hq, hkv, s, d):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    q_pos = jnp.arange(b) * 3 + s // 2
    k_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out = ops.decode_gqa(q, k, v, q_pos, k_pos, block_k=16)
    want = ref.decode_gqa_ref(q, k, v, q_pos, k_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_decode_gqa_ring_buffer_order_invariance():
    """Slot order must not matter — only stored positions."""
    b, hq, hkv, s, d = 1, 4, 2, 16, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    k_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q_pos = jnp.array([s])
    base = ops.decode_gqa(q, k, v, q_pos, k_pos, block_k=8)
    perm = jax.random.permutation(KEY, s)
    out = ops.decode_gqa(q, k[:, :, perm], v[:, :, perm], q_pos,
                         k_pos[:, perm], block_k=8)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("bt,t,di,n,bd,btk", [
    (1, 16, 32, 8, 16, 8), (2, 37, 64, 16, 32, 16), (2, 64, 128, 8, 64, 64),
])
def test_ssm_scan_sweep(bt, t, di, n, bd, btk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (bt, t, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, t, di))) * 0.1
    B = jax.random.normal(ks[2], (bt, t, n))
    C = jax.random.normal(ks[3], (bt, t, n))
    A = -jnp.exp(jax.random.normal(ks[4], (di, n)))
    h0 = jax.random.normal(KEY, (bt, di, n))
    y, hT = ops.ssm_scan(x, dt, B, C, A, h0, block_d=bd, block_t=btk)
    yr, hTr = ref.ssm_scan_ref(x, dt, B, C, A, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTr), atol=1e-4,
                               rtol=1e-4)


def test_ssm_scan_chunked_equals_onechunk():
    """State carry across time-chunk grid steps must be exact."""
    bt, t, di, n = 1, 64, 32, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (bt, t, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, t, di))) * 0.1
    B = jax.random.normal(ks[2], (bt, t, n))
    C = jax.random.normal(ks[3], (bt, t, n))
    A = -jnp.exp(jax.random.normal(ks[4], (di, n)))
    y1, h1 = ops.ssm_scan(x, dt, B, C, A, block_d=32, block_t=64)
    y2, h2 = ops.ssm_scan(x, dt, B, C, A, block_d=32, block_t=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5,
                               rtol=1e-5)


@pytest.mark.parametrize("b,t,w,bw,btk", [
    (1, 16, 32, 16, 8), (2, 37, 48, 16, 16), (2, 64, 128, 64, 32),
])
def test_rglru_scan_sweep(b, t, w, bw, btk):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (b, t, w))
    a_log = -jax.nn.softplus(jax.random.normal(ks[1], (b, t, w)))
    h0 = jax.random.normal(KEY, (b, w))
    y, hT = ops.rglru_scan(x, a_log, h0, block_w=bw, block_t=btk)
    yr, hTr = ref.rglru_scan_ref(x, a_log, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTr), atol=1e-5,
                               rtol=1e-5)
