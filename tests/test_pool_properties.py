"""Property-based invariant suite for the block/pool/tier layer
(DESIGN.md §8/§10/§12).

A seeded op-sequence machine drives random interleavings of
put / put-child / get(pin) / release / budget shocks / demote (via
eviction) / promote / cow / suffix-allocation churn against a REAL
``KVBlockPool`` + ``PrefixPool`` + ``HostTier`` stack, re-deriving the
ground truth from scratch after every operation:

* every block is refcounted exactly once per owner (resident page,
  ancestor snapshot, harness reader, suffix hold);
* free list ∪ owned blocks PARTITIONS each arena id space;
* a pinned entry is never evicted (hence never demoted);
* byte gauges (pool, tier, CacheStats) reconcile with totals recomputed
  from first principles;
* eviction order: a resident segment's parent is resident, and the host
  tier never picks a discard victim that anchors a hosted descendant.

The driver mirrors production pin discipline where the stack requires
it: a chain parent is pinned while a child is built against it, and an
entry is pinned across its own copy-on-write (the scheduler holds both
pins inside a batch) — otherwise the allocator's reclaim hook could
evict the state mid-operation, which no caller permits.

The driver is stdlib-only (``random.Random``) so it runs everywhere;
CI executes 100 seeds × {f32, int8} = 200 sequences.  When
``hypothesis`` is installed (CI kernels job), a shrinking variant runs
the same machine under generated op programs."""
import collections
import random

import pytest

from repro.core.cache import CacheStats, PrefixState
from repro.core.paged import KVBlockPool, OutOfBlocks
from repro.core.prefix_pool import PrefixPool, state_bytes
from repro.core.tiered import HostTier
from repro.models.config import ModelConfig

try:
    import hypothesis
    from hypothesis import strategies as hyp_st
except ImportError:          # CI installs hypothesis; local runs skip
    hypothesis = None


def _tiny_cfg():
    return ModelConfig(name="prop-test", family="dense", num_layers=1,
                       d_model=16, num_heads=2, num_kv_heads=1, head_dim=8,
                       d_ff=32, vocab_size=64, dtype="float32")


def _filled_dense(cfg, P, C=16):
    import jax
    import jax.numpy as jnp
    from repro.models import model as M
    dense = M.init_cache(cfg, 1, C)

    def fill(path, x):
        if path[-1].key == "pos":
            seq = jnp.arange(x.shape[-1])
            return jnp.broadcast_to(jnp.where(seq < P, seq, -1), x.shape)
        return jnp.arange(x.size, dtype=jnp.float32).reshape(
            x.shape).astype(x.dtype) / x.size
    return jax.tree_util.tree_map_with_path(fill, dense)


# segment token lengths come from a tiny set so the jitted write/copy
# signatures (static block counts) stay hot across all seeds
SEG_LENS = (3, 6, 11)
BLOCK_SIZE = 4
NUM_BLOCKS = 24


class PoolMachine:
    """One randomized episode against the real pool stack."""

    OPS = ("put_flat", "put_flat", "put_child", "get", "get", "release",
           "shrink_pool", "grow_pool", "shrink_tier", "promote", "cow",
           "drop_reader", "suffix_alloc", "suffix_free")

    def __init__(self, seed: int, quantize: bool) -> None:
        self.rng = random.Random(seed)
        self.cfg = _tiny_cfg()
        self.bp = KVBlockPool(self.cfg, NUM_BLOCKS, BLOCK_SIZE,
                              quantize_prefix=quantize)
        self.stats = CacheStats()
        self.pool = PrefixPool(1 << 30, self.stats)
        self.pool.attach_block_pool(self.bp)
        self.pool.attach_host_tier(HostTier(1 << 30))
        self.next_key = 0
        self.pins = collections.Counter()     # key -> pins the driver holds
        self.readers = []                     # increfed block-id lists
        self.suffix_holds = []                # suffix-space allocations
        per = self.bp.prefix_block_bytes
        self.pool_budgets = [1, 2 * per, 5 * per]
        host_per = per if quantize else self.bp.block_bytes
        self.tier_budgets = [1, 3 * host_per, 1 << 30]
        self._dense = {P: _filled_dense(self.cfg, P) for P in SEG_LENS}

    # -- state fabrication (the pool stores states, it never computes
    # them — content is irrelevant to every invariant checked here) ----
    def _mk_state(self, parent=None):
        seg = self.rng.choice(SEG_LENS)
        pt = self.bp.write_prefix(self._dense[seg], seg)
        anc = []
        if parent is not None:
            anc = list(parent.chain_blocks())
            self.bp.incref(anc)
        base = parent.prefix_len if parent is not None else 0
        return PrefixState(cache=None, prefix_len=base + seg, capacity=64,
                           page=pt, block_pool=self.bp, parent=parent,
                           seg_len=seg, ancestor_blocks=anc)

    def _fresh_key(self):
        self.next_key += 1
        return self.next_key

    def _resident_keys(self):
        return list(self.pool._entries)

    # -- ops -----------------------------------------------------------
    def op_put_flat(self):
        try:
            st = self._mk_state()
        except OutOfBlocks:
            return
        self.pool.put(self._fresh_key(), st, prefill_s=self.rng.random())

    def op_put_child(self):
        keys = self._resident_keys()
        if not keys:
            return
        pkey = self.rng.choice(keys)
        # pin the parent until the child is ADMITTED — the window the
        # scheduler holds a chain pin for: both the child's own
        # write_prefix and any eviction pass before the child is
        # resident could otherwise reclaim the parent out from under it
        self.pool.pin(pkey)
        try:
            st = self._mk_state(self.pool._entries[pkey].state)
            self.pool.put(self._fresh_key(), st,
                          prefill_s=self.rng.random())
        except OutOfBlocks:
            pass
        finally:
            self.pool.release(pkey)

    def op_get(self):
        if self.next_key == 0:
            return
        key = self.rng.randrange(1, self.next_key + 1)
        pin = self.rng.random() < 0.5
        st = self.pool.get(key, pin=pin)
        if st is not None and pin:
            self.pins[key] += 1

    def op_release(self):
        held = [k for k, n in self.pins.items() if n > 0]
        if not held:
            return
        key = self.rng.choice(held)
        self.pool.release(key)
        self.pins[key] -= 1

    def op_shrink_pool(self):
        self.pool.budget_bytes = self.rng.choice(self.pool_budgets)
        self.pool._evict_to_budget()

    def op_grow_pool(self):
        self.pool.budget_bytes = 1 << 30

    def op_shrink_tier(self):
        # enforcement is admit-time: a shrink strands bytes until the
        # next demotion's discard loop peels the tier back down
        self.pool.tier.budget_bytes = self.rng.choice(self.tier_budgets)

    def op_promote(self):
        # production only promotes on a pool MISS: resident keys are
        # answered by get() and never reach promote
        hosted = [k for k in self.pool.tier.keys()
                  if k not in self.pool._entries]
        if not hosted:
            return
        key = self.rng.choice(hosted)
        hseg = self.pool.tier.peek(key)
        parent = None
        if hseg.parent_key is not None:
            pe = self.pool._entries.get(hseg.parent_key)
            parent = pe.state if pe is not None else None
        pin = self.rng.random() < 0.3
        st = self.pool.promote(key, parent=parent, pin=pin,
                               prefetched=self.rng.random() < 0.5)
        if st is not None and pin:
            self.pins[key] += 1

    def op_cow(self):
        keys = self._resident_keys()
        if not keys:
            return
        key = self.rng.choice(keys)
        st = self.pool._entries[key].state
        # a reader appears (incref), then the state COWs one block for
        # a write — the reader keeps the original id; the entry is
        # pinned across the copy (cow's alloc may reclaim, and no
        # writer tolerates its own state evicting mid-write)
        held = list(st.page.blocks)
        self.bp.incref(held)
        self.readers.append(held)
        i = self.rng.randrange(len(st.page.blocks))
        self.pool.pin(key)
        try:
            st.page.blocks[i] = self.bp.cow(st.page.blocks[i])
        except OutOfBlocks:
            pass
        finally:
            self.pool.release(key)

    def op_drop_reader(self):
        if not self.readers:
            return
        lst = self.readers.pop(self.rng.randrange(len(self.readers)))
        self.bp.decref(lst)

    def op_suffix_alloc(self):
        n = self.rng.randint(1, 3)
        try:
            bids = self.bp.alloc(n, suffix=True)
        except OutOfBlocks:
            return
        self.bp.note_tokens(bids, n * BLOCK_SIZE - 1, suffix=True)
        self.suffix_holds.append(bids)

    def op_suffix_free(self):
        if not self.suffix_holds:
            return
        bids = self.suffix_holds.pop(
            self.rng.randrange(len(self.suffix_holds)))
        self.bp.decref(bids, suffix=True)

    # -- ground truth --------------------------------------------------
    def _expected_refs(self):
        """(prefix-space, suffix-space) Counters of block-id -> owner
        count, recomputed from ownership lists (NOT from allocator
        state)."""
        pfx = collections.Counter()
        for e in self.pool._entries.values():
            for b in e.state.page.blocks:
                pfx[b] += 1
            for b in e.state.ancestor_blocks:
                pfx[b] += 1
        for lst in self.readers:
            for b in lst:
                pfx[b] += 1
        sfx = collections.Counter()
        for lst in self.suffix_holds:
            for b in lst:
                sfx[b] += 1
        if self.bp.suffix_allocator is self.bp.allocator:
            # single address space: suffix holds share the one allocator
            pfx = pfx + sfx
            sfx = pfx
        return pfx, sfx

    def check(self):
        bp, pool, tier = self.bp, self.pool, self.pool.tier
        pfx, sfx = self._expected_refs()
        spaces = [(bp.allocator, pfx)]
        if bp.suffix_allocator is not bp.allocator:
            spaces.append((bp.suffix_allocator, sfx))
        for alloc, expected in spaces:
            # every block refcounted exactly once per owner
            for bid in range(1, bp.num_blocks):
                assert alloc.refcount(bid) == expected.get(bid, 0), (
                    f"block {bid}: refcount {alloc.refcount(bid)} != "
                    f"{expected.get(bid, 0)} owners")
            # free ∪ owned partitions the arena id space
            free = set(alloc._free)
            owned = {b for b, c in expected.items() if c > 0}
            assert free.isdisjoint(owned)
            assert free | owned == set(range(1, bp.num_blocks))
        # no pinned entry was evicted (or demoted): the driver's pins
        # map exactly onto resident entry refs
        for key, n in self.pins.items():
            if n > 0:
                e = pool._entries.get(key)
                assert e is not None, f"pinned key {key} was evicted"
                assert e.refs == n, (key, e.refs, n)
        # byte gauges reconcile with scratch recomputation
        assert pool.bytes_in_use == sum(
            state_bytes(e.state) for e in pool._entries.values())
        assert tier.bytes_in_use == sum(
            s.nbytes for s in tier._segments.values())
        self.stats.record_blocks(bp)
        assert self.stats.block_bytes_in_use == \
            bp.prefix_blocks_in_use * bp.prefix_block_bytes
        self.stats.record_host(tier)
        assert self.stats.host_bytes_in_use == tier.bytes_in_use
        assert self.stats.host_bytes_peak >= tier.bytes_in_use
        # tree order: a resident segment's parent is resident (eviction
        # is leaf-before-ancestor; pinned leaves anchor their path)
        resident = {e.state.uid for e in pool._entries.values()}
        for e in pool._entries.values():
            if e.state.parent is not None:
                assert e.state.parent.uid in resident, \
                    f"entry {e.key}: parent evicted under a descendant"
        # host leaf-first: the next discard victim never anchors a
        # hosted descendant
        v = tier._pick_discard()
        if v is not None:
            anchors = {s.parent_key for s in tier._segments.values()
                       if s.parent_key is not None}
            assert v.key not in anchors

    # -- episode -------------------------------------------------------
    def run(self, n_ops: int = 40) -> None:
        for _ in range(n_ops):
            getattr(self, "op_" + self.rng.choice(self.OPS))()
            self.check()
        self.teardown()

    def teardown(self) -> None:
        # unwinding every driver-held reference must balance exactly
        for key, n in list(self.pins.items()):
            for _ in range(n):
                self.pool.release(key)
        for lst in self.readers:
            self.bp.decref(lst)
        for bids in self.suffix_holds:
            self.bp.decref(bids, suffix=True)
        self.pool.clear()
        assert self.bp.blocks_in_use == 0
        assert self.bp.allocator.free_blocks == self.bp.allocator.num_usable
        assert self.bp.suffix_allocator.free_blocks == \
            self.bp.suffix_allocator.num_usable


@pytest.mark.parametrize("quantize", [False, True], ids=["f32", "int8"])
@pytest.mark.parametrize("seed", range(100))
def test_pool_invariants_random_interleavings(seed, quantize):
    PoolMachine(seed, quantize).run()


def test_pool_invariants_long_episode():
    """One deep episode per layout (more ops than any parametrized
    seed) to reach rarer interleavings: repeated demote/promote cycles
    of the same keys, budget oscillation, deeper chains."""
    PoolMachine(10_000, quantize=False).run(n_ops=150)
    PoolMachine(10_001, quantize=True).run(n_ops=150)


if hypothesis is not None:
    @hypothesis.given(
        seed=hyp_st.integers(0, 2 ** 31 - 1),
        ops=hyp_st.lists(hyp_st.sampled_from(PoolMachine.OPS),
                         min_size=1, max_size=25),
        quantize=hyp_st.booleans())
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_pool_invariants_hypothesis(seed, ops, quantize):
        """Shrinking variant: hypothesis picks the program, the machine
        checks the same invariants, and a failure minimizes to the
        shortest violating op sequence."""
        m = PoolMachine(seed, quantize)
        for op in ops:
            getattr(m, "op_" + op)()
            m.check()
        m.teardown()
