"""Distribution layer: partition rules, sanitize, host-mesh lowering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis; "
                           "pip install -e '.[test]'")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import registry as R
from repro.distributed import sharding as S
from repro.models import model as M


@pytest.fixture(scope="module")
def mesh():
    # single-device host mesh with production axis names
    return jax.make_mesh((1, 1), ("data", "model"))


def test_sanitize_divisibility(mesh):
    big = jax.make_mesh((1, 1), ("data", "model"))
    # fake a 16-wide model axis via a fabricated mesh is impossible with
    # 1 device; test the pure function against a mocked shape table.
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
        axis_names = ("pod", "data", "model")
    fm = FakeMesh()
    assert S.sanitize(("model", None), (256206, 64), fm) == P(None, None)
    assert S.sanitize(("model", None), (256000, 64), fm) == P("model", None)
    assert S.sanitize((("pod", "data"), None), (1, 8), fm) == P(None, None)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4096), st.sampled_from([None, "model", "data"]))
def test_sanitize_always_valid(dim, axis):
    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}
        axis_names = ("pod", "data", "model")
    spec = S.sanitize((axis,), (dim,), FakeMesh())
    entry = spec[0]
    if entry is not None:
        assert dim % FakeMesh.shape[entry] == 0


@pytest.mark.parametrize("arch", R.ASSIGNED_ARCHS)
def test_param_pspecs_structurally_valid(arch, mesh):
    """Every spec leaf has rank == param rank (host mesh)."""
    cfg = R.get_reduced(arch)
    params_abs = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    specs = S.param_pspecs(cfg, params_abs, mesh)
    flat_p = jax.tree_util.tree_leaves(params_abs)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= len(leaf.shape)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mixtral-8x22b",
                                  "falcon-mamba-7b", "recurrentgemma-2b"])
def test_host_mesh_lowering(arch, mesh):
    """Reduced configs lower + compile on the 1x1 host mesh (decode)."""
    from repro.launch.steps import make_decode_step
    cfg = R.get_reduced(arch).replace(dtype="float32")
    params_abs = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    psh = S.named(mesh, S.param_pspecs(cfg, params_abs, mesh))
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 2, 32))
    bsh = {"token": S.named(mesh, S.batch_pspecs(
               jax.ShapeDtypeStruct((2, 1), jnp.int32), mesh)),
           "positions": S.named(mesh, S.batch_pspecs(
               jax.ShapeDtypeStruct((2, 1), jnp.int32), mesh)),
           "cache": S.named(mesh, S.cache_pspecs(cfg, cache, mesh))}
    step = make_decode_step(cfg)
    specs = {"token": jax.ShapeDtypeStruct((2, 1), jnp.int32),
             "positions": jax.ShapeDtypeStruct((2, 1), jnp.int32),
             "cache": cache}
    with jax.set_mesh(mesh):
        compiled = jax.jit(step, in_shardings=(psh, bsh)) \
            .lower(params_abs, specs).compile()
    assert compiled is not None


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = bf16[8,128,256]{2,1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %rs = bf16[4,64]{1,0} reduce-scatter(%z)
  %cp = f32[16]{0} collective-permute(%w)
  %not_a_collective = f32[8]{0} add(%a, %b)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 8 * 128 * 256 * 2
    assert got["all-reduce"] == 1024 * 4 * 2          # 2x ring weight
    assert got["reduce-scatter"] == 4 * 64 * 2
    assert got["collective-permute"] == 16 * 4
    assert got["total"] == sum(
        got[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))


def test_input_specs_cover_all_shapes():
    for arch in R.ASSIGNED_ARCHS:
        cfg = R.get_config(arch)
        for shape in R.INPUT_SHAPES:
            if shape == "long_500k" and not cfg.supports_long_context:
                cfg2 = R.apply_swa_override(cfg, 4096)
            else:
                cfg2 = cfg
            specs = R.input_specs(cfg2, shape)
            assert specs, (arch, shape)
            info = R.INPUT_SHAPES[shape]
            if info.kind == "train":
                assert specs["tokens"].shape == (info.global_batch,
                                                 info.seq_len)
            elif info.kind == "decode":
                assert specs["token"].shape == (info.global_batch, 1)
                assert "cache" in specs
