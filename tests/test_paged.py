"""Paged KV-cache address space (DESIGN.md §8): block allocator
semantics, arena scatter/gather round trips, paged Pallas kernels vs
their jnp oracles (interpret mode — this file is the CI kernel job),
and the serving acceptance criterion: paged prefill/decode is exact vs
the dense cascade, f32 XLA bitwise at the kernel level and token-for-
token end to end (bf16 Pallas included), with COW-shared prefix blocks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.paged import (NULL_BLOCK, BlockAllocator, KVBlockPool,
                              OutOfBlocks, PageTable)
from repro.data.tokenizer import Tokenizer
from repro.kernels import ref as R
from repro.kernels import shared_prefix as SP
from repro.kernels.decode_gqa import paged_decode_gqa
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.engine import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------------
# allocator
# ----------------------------------------------------------------------
def test_allocator_reserves_null_and_refcounts():
    a = BlockAllocator(6)
    assert a.num_usable == 5 and a.free_blocks == 5
    got = a.alloc(3)
    assert NULL_BLOCK not in got and len(set(got)) == 3
    a.incref(got[:1])
    assert a.refcount(got[0]) == 2
    freed = a.decref(got)
    assert freed == got[1:]              # got[0] still referenced
    assert a.decref(got[:1]) == got[:1]
    assert a.free_blocks == 5
    with pytest.raises(OutOfBlocks):
        a.alloc(6)
    # a failed alloc must not leak partial takes
    assert a.free_blocks == 5


def test_allocator_reclaim_hook_retries_once():
    a = BlockAllocator(4)
    held = a.alloc(3)

    def reclaim(n):
        a.decref(held[:n])
    a.reclaim_hook = reclaim
    got = a.alloc(2)                     # triggers reclaim of 2 blocks
    assert len(got) == 2


def test_page_table_rows_pad_with_null():
    pt = PageTable(blocks=[3, 1, 2], length=150)
    row = pt.row(5)
    np.testing.assert_array_equal(row, [3, 1, 2, NULL_BLOCK, NULL_BLOCK])
    with pytest.raises(AssertionError):
        pt.row(2)


# ----------------------------------------------------------------------
# arena scatter / gather round trip
# ----------------------------------------------------------------------
def _gqa_cfg(vocab=64, dtype="float32", impl="xla", window=0):
    return ModelConfig(name="paged-test", family="dense", num_layers=3,
                       d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
                       d_ff=160, vocab_size=vocab, dtype=dtype,
                       attention_impl=impl, sliding_window=window)


def test_write_prefix_round_trips_and_tracks_fragmentation():
    cfg = _gqa_cfg()
    pool = KVBlockPool(cfg, num_blocks=16, block_size=8)
    P, C = 19, 32
    dense = M.init_cache(cfg, 1, C)

    def fill(path, x):
        if path[-1].key == "pos":
            seq = jnp.arange(x.shape[-1])
            return jnp.broadcast_to(jnp.where(seq < P, seq, -1), x.shape)
        return jnp.arange(x.size, dtype=x.dtype).reshape(x.shape) / x.size
    dense = jax.tree_util.tree_map_with_path(fill, dense)

    pt = pool.write_prefix(dense, P)
    assert len(pt.blocks) == 3 and pt.length == P
    assert pool.tokens_stored == P
    assert pool.fragmentation == pytest.approx(1 - P / 24)

    g = pool.gather(pt.row(4)[None])     # one NULL pad block
    for name in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(g["groups"]["0"][name][:, 0, :24]),
            np.asarray(dense["groups"]["0"][name][:, 0, :24]))
    gpos = np.asarray(g["groups"]["0"]["pos"])
    np.testing.assert_array_equal(
        gpos[:, 0, :24], np.asarray(dense["groups"]["0"]["pos"][:, 0, :24]))
    assert np.all(gpos[:, 0, 24:] == -1)          # NULL block stays empty

    pool.decref(pt.blocks)
    assert pool.blocks_in_use == 0 and pool.tokens_stored == 0


def test_alloc_suffix_resets_stale_positions():
    cfg = _gqa_cfg()
    pool = KVBlockPool(cfg, num_blocks=8, block_size=8)
    dense = M.init_cache(cfg, 1, 16)
    dense = jax.tree_util.tree_map_with_path(
        lambda p, x: jnp.zeros_like(x) if p[-1].key != "pos"
        else jnp.broadcast_to(jnp.arange(x.shape[-1]), x.shape), dense)
    pt = pool.write_prefix(dense, 16)
    pool.decref(pt.blocks)               # freed with stale pos inside
    fresh = pool.alloc_suffix(2)
    g = pool.gather(np.asarray([fresh]))
    assert np.all(np.asarray(g["groups"]["0"]["pos"]) == -1)


# ----------------------------------------------------------------------
# paged kernels vs oracles (interpret mode; the CI kernel job)
# ----------------------------------------------------------------------
def _paged_fixtures(b=3, hq=8, hkv=2, tq=7, nb=9, bs=8, d=16):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, hq, tq, d))
    k = jax.random.normal(ks[1], (nb, hkv, bs, d))
    v = jax.random.normal(ks[2], (nb, hkv, bs, d))
    pt = np.array([[1, 2, 3, 0], [4, 5, 0, 0], [6, 7, 8, 0]], np.int32)[:b]
    kpos = np.full((nb, bs), -1, np.int32)
    lens = [20, 13, 22][:b]
    for r in range(b):
        for j, blk in enumerate(pt[r]):
            if blk == NULL_BLOCK:
                continue
            for s in range(bs):
                t = j * bs + s
                if t < lens[r]:
                    kpos[blk, s] = t
    qpos = jnp.broadcast_to(jnp.arange(30, 30 + tq)[None], (b, tq))
    return q, k, v, qpos, jnp.asarray(kpos), jnp.asarray(pt)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])  # MHA/GQA/MQA
@pytest.mark.parametrize("causal,window", [(False, 0), (True, 0), (True, 9)])
def test_paged_attention_partial_matches_oracle(hq, hkv, causal, window):
    q, k, v, qpos, kpos, pt = _paged_fixtures(hq=hq, hkv=hkv)
    got = SP.paged_attention_partial(q, k, v, qpos, kpos, pt,
                                     causal=causal, window=window,
                                     interpret=True)
    want = R.paged_attention_partial_ref(q, k, v, qpos, kpos, pt,
                                         causal=causal, window=window)
    for g, w, name in zip(got, want, ("out", "m", "l")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=1e-5, rtol=1e-5, err_msg=name)


@pytest.mark.parametrize("window", [0, 9])
def test_paged_decode_partial_matches_oracle(window):
    q, k, v, _, kpos, pt = _paged_fixtures()
    qd = q[:, :, 0]
    qdp = jnp.asarray([25, 14, 23])
    got = SP.paged_decode_gqa_partial(qd, k, v, qdp, kpos, pt,
                                      window=window, interpret=True)
    want = R.paged_decode_gqa_partial_ref(qd, k, v, qdp, kpos, pt,
                                          window=window)
    for g, w, name in zip(got, want, ("out", "m", "l")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=1e-5, rtol=1e-5, err_msg=name)
    full = paged_decode_gqa(qd, k, v, qdp, kpos, pt, window=window,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(full), np.asarray(want[0]),
                               atol=1e-5, rtol=1e-5)


def test_paged_oracle_is_bitwise_dense_partial_at_matched_width():
    """Acceptance (f32 XLA): the paged oracle on a gathered page walk is
    BITWISE the dense partial on the same dense sequence — paging is a
    storage change, not a math change."""
    q, k, v, qpos, kpos, pt = _paged_fixtures()
    b, np_ = pt.shape
    hkv, bs, d = k.shape[1], k.shape[2], k.shape[3]
    kk = jnp.moveaxis(k[pt], 1, 2).reshape(b, hkv, np_ * bs, d)
    vv = jnp.moveaxis(v[pt], 1, 2).reshape(b, hkv, np_ * bs, d)
    kp = kpos[pt].reshape(b, np_ * bs)
    got = R.paged_attention_partial_ref(q, k, v, qpos, kpos, pt,
                                        causal=False)
    want = R.attention_partial_ref(q, kk, vv, qpos, kp, causal=False)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_paged_pallas_bf16_close_to_oracle():
    q, k, v, qpos, kpos, pt = _paged_fixtures()
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, k, v))
    got = SP.paged_attention_partial(qb, kb, vb, qpos, kpos, pt,
                                     causal=True, interpret=True)
    want = R.paged_attention_partial_ref(qb, kb, vb, qpos, kpos, pt,
                                         causal=True)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=2e-2, rtol=2e-2)


# ----------------------------------------------------------------------
# engine acceptance: paged serving == dense cascade serving
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def tok():
    return Tokenizer.train(["the quick brown fox jumps over the lazy dog "
                            "a graph of nodes and edges answers questions"])


def _engines(tok, dtype="float32", impl="xla", window=0, **kw):
    cfg = _gqa_cfg(tok.vocab_size, dtype, impl, window)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    paged = ServingEngine(params, cfg, tok, max_cache_len=512,
                          max_new_tokens=5, **kw)
    dense = ServingEngine(params, cfg, tok, max_cache_len=512,
                          max_new_tokens=5, paged=False)
    assert paged.use_paged and not dense.use_paged
    return paged, dense


@pytest.mark.parametrize("dtype,impl", [("float32", "xla"),
                                        ("bfloat16", "pallas")])
def test_serve_paged_exact_vs_dense_cascade(tok, dtype, impl):
    """Acceptance: mixed-cluster paged serving reproduces the dense
    cascade token for token (f32 XLA and bf16 Pallas), members sharing
    prefix blocks physically."""
    paged, dense = _engines(tok, dtype, impl)
    prefix = tok.encode("the quick brown fox jumps over the lazy dog "
                        + "a graph of nodes " * 40, bos=True)
    st_p, _ = paged.prefill_prefix(prefix)
    st_d, _ = dense.prefill_prefix(prefix)
    assert st_p.is_paged and len(st_p.page.blocks) > 1
    sfx = [tok.encode("answers questions"), tok.encode("and edges"),
           tok.encode("lazy dog")]
    out_p, t = paged.serve([Request(suffix_tokens=s, prefix=st_p)
                            for s in sfx])
    out_d, _ = dense.generate_with_prefix(st_d, sfx)
    assert t["paged"]
    assert out_p == out_d


def test_serve_paged_windowed_matches_dense(tok):
    """Sliding-window stack: paged suffix pages are never rung — the
    window is masked positionally — and must still match the dense
    ring-buffer cascade."""
    paged, dense = _engines(tok, window=8)
    prefix = tok.encode("a graph of nodes and edges", bos=True)
    st_p, _ = paged.prefill_prefix(prefix)
    st_d, _ = dense.prefill_prefix(prefix)
    sfx = [tok.encode("answers questions a graph"), tok.encode("the quick")]
    out_p, _ = paged.generate_with_prefix(st_p, sfx)
    out_d, _ = dense.generate_with_prefix(st_d, sfx)
    assert out_p == out_d


def test_serve_paged_cow_shared_block_is_exact(tok):
    """Acceptance: a cluster whose members walk a COW'd prefix block
    serves identically — the copy is bit-identical, so swapping it into
    the page table changes nothing observable."""
    paged, dense = _engines(tok)
    prefix = tok.encode("the quick brown fox jumps over the lazy dog "
                        + "answers questions " * 40, bos=True)
    st_p, _ = paged.prefill_prefix(prefix)
    st_d, _ = dense.prefill_prefix(prefix)
    assert len(st_p.page.blocks) >= 2
    # another holder appears (e.g. an overlapping batch), then this
    # state COWs its first block for a write that never happens
    pool = paged.block_pool
    pool.incref(st_p.page.blocks)
    old = st_p.page.blocks[0]
    new = pool.cow(old)
    assert new != old
    st_p.page.blocks[0] = new
    sfx = [tok.encode("and edges"), tok.encode("a graph of nodes")]
    out_p, _ = paged.generate_with_prefix(st_p, sfx)
    out_d, _ = dense.generate_with_prefix(st_d, sfx)
    assert out_p == out_d


def test_serve_prefixless_rows_match_generate(tok):
    """Rows with no prefix state (all-NULL prefix table) degrade to the
    baseline: the masked prefix partial carries no probability mass."""
    paged, _ = _engines(tok)
    prompts = [tok.encode("the quick brown fox jumps", bos=True),
               tok.encode("a graph of nodes and edges answers", bos=True)]
    outs, t = paged.serve([Request(suffix_tokens=p) for p in prompts],
                          _record=False)
    assert t["num_prefixes"] == 0
    for p, got in zip(prompts, outs):
        want, _ = paged.generate(p)
        assert got == want


def test_serve_paged_frees_suffix_blocks_and_reports_stats(tok):
    paged, _ = _engines(tok)
    stats = paged.cache_mgr.reset_stats()
    st, _ = paged.prefill_prefix(tok.encode("a graph of nodes", bos=True))
    held = paged.block_pool.blocks_in_use
    paged.generate_with_prefix(st, [tok.encode("answers questions")])
    assert paged.block_pool.blocks_in_use == held    # suffix blocks freed
    assert stats.blocks_peak > held                  # but counted at peak
    assert stats.blocks_total == paged.block_pool.allocator.num_usable
    assert 0.0 <= stats.block_occupancy <= 1.0
    assert 0.0 <= stats.block_fragmentation < 1.0
    st.release()
    assert paged.block_pool.blocks_in_use == 0
