"""SubGCache core: subgraph algebra, clustering, planner, cache manager."""
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis; "
                           "pip install -e '.[test]'")
from hypothesis import given, settings, strategies as st

from repro.core.cache import CacheStats, ClusterCacheManager, PrefixState
from repro.core.clustering import LINKAGES, hierarchical_clustering
from repro.core.planner import plan_batch, plan_singleton
from repro.core.subgraph import Subgraph, merge_subgraphs, textualize

# ----------------------------------------------------------------------
# subgraph algebra (hypothesis)
# ----------------------------------------------------------------------
edges_st = st.lists(
    st.tuples(st.integers(0, 15), st.sampled_from(["a", "b", "c"]),
              st.integers(0, 15)),
    max_size=20)


def _sg(edges):
    return Subgraph.from_lists([], edges)


@settings(max_examples=50, deadline=None)
@given(edges_st, edges_st)
def test_union_commutative(e1, e2):
    assert _sg(e1).union(_sg(e2)) == _sg(e2).union(_sg(e1))


@settings(max_examples=50, deadline=None)
@given(edges_st, edges_st, edges_st)
def test_union_associative(e1, e2, e3):
    a, b, c = _sg(e1), _sg(e2), _sg(e3)
    assert a.union(b).union(c) == a.union(b.union(c))


@settings(max_examples=50, deadline=None)
@given(edges_st)
def test_union_idempotent(e1):
    a = _sg(e1)
    assert a.union(a) == a
    assert merge_subgraphs([a, a, a]) == a


@settings(max_examples=30, deadline=None)
@given(edges_st, edges_st)
def test_members_subset_of_representative(e1, e2):
    """Paper §3.3: the representative subgraph contains every member."""
    a, b = _sg(e1), _sg(e2)
    rep = merge_subgraphs([a, b])
    assert a.nodes <= rep.nodes and a.edges <= rep.edges
    assert b.nodes <= rep.nodes and b.edges <= rep.edges


@settings(max_examples=30, deadline=None)
@given(edges_st, edges_st)
def test_jaccard_bounds(e1, e2):
    j = _sg(e1).jaccard(_sg(e2))
    assert 0.0 <= j <= 1.0
    assert _sg(e1).jaccard(_sg(e1)) == 1.0


def test_textualize_deterministic_and_order_normalized():
    node_text = [f"name: n{i}" for i in range(6)]
    a = Subgraph.from_lists([0, 3], [(0, "r", 3), (3, "s", 5)])
    b = Subgraph.from_lists([3, 0], [(3, "s", 5), (0, "r", 3)])
    assert textualize(a, node_text) == textualize(b, node_text)
    assert "src,edge_attr,dst" in textualize(a, node_text)


# ----------------------------------------------------------------------
# clustering
# ----------------------------------------------------------------------
def _norm(labels):
    seen, out = {}, []
    for v in labels:
        out.append(seen.setdefault(v, len(seen)))
    return tuple(out)


@pytest.mark.parametrize("linkage", ["ward", "single", "complete", "average"])
def test_clustering_matches_scipy(linkage):
    scipy = pytest.importorskip("scipy.cluster.hierarchy")
    rng = np.random.default_rng(0)
    for _ in range(6):
        m = int(rng.integers(6, 40))
        x = rng.normal(size=(m, 8))
        c = int(rng.integers(2, 6))
        ours = _norm(hierarchical_clustering(x, c, linkage))
        Z = scipy.linkage(x, method=linkage, metric="euclidean")
        sp = _norm(scipy.fcluster(Z, c, criterion="maxclust"))
        assert ours == sp, (linkage, m, c)


def test_clustering_centroid_groups_duplicates():
    # centroid differs from scipy on dendrogram inversions; check the
    # partition property instead: identical points cluster together.
    rng = np.random.default_rng(1)
    a = rng.normal(size=(1, 8))
    x = np.concatenate([a + 1e-6 * rng.normal(size=(10, 8)),
                        a + 5.0 + 1e-6 * rng.normal(size=(10, 8))])
    labels = hierarchical_clustering(x, 2, "centroid")
    assert len(set(labels[:10])) == 1 and len(set(labels[10:])) == 1
    assert labels[0] != labels[10]


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 25), st.integers(1, 6),
       st.sampled_from(list(LINKAGES)))
def test_clustering_label_invariants(m, c, linkage):
    rng = np.random.default_rng(m * 31 + c)
    x = rng.normal(size=(m, 4))
    labels = hierarchical_clustering(x, c, linkage)
    assert labels.shape == (m,)
    assert len(set(labels.tolist())) == min(c, m)
    assert set(labels.tolist()) == set(range(min(c, m)))


def test_clustering_one_cluster_and_m_clusters():
    x = np.random.default_rng(0).normal(size=(12, 4))
    assert set(hierarchical_clustering(x, 1, "ward")) == {0}
    assert len(set(hierarchical_clustering(x, 12, "ward"))) == 12


# ----------------------------------------------------------------------
# planner
# ----------------------------------------------------------------------
def test_plan_batch_covers_all_queries_once():
    rng = np.random.default_rng(0)
    subs = [Subgraph.from_lists([i, i + 1], [(i, "r", i + 1)])
            for i in range(10)]
    emb = rng.normal(size=(10, 8))
    plan = plan_batch(subs, emb, num_clusters=3)
    seen = sorted(i for c in plan.clusters for i in c.member_indices)
    assert seen == list(range(10))
    for c in plan.clusters:
        for i in c.member_indices:
            assert subs[i].nodes <= c.representative.nodes


def test_plan_singleton_degenerates_to_vanilla():
    subs = [Subgraph.from_lists([i], []) for i in range(5)]
    plan = plan_singleton(subs)
    assert len(plan.clusters) == 5
    assert all(len(c.member_indices) == 1 for c in plan.clusters)
    assert plan.reuse_factor == 1.0


# ----------------------------------------------------------------------
# cache manager
# ----------------------------------------------------------------------
def test_cluster_cache_policy_enforced():
    import jax.numpy as jnp
    mgr = ClusterCacheManager()
    s1 = PrefixState(cache={"k": jnp.zeros((1, 4))}, prefix_len=4,
                     capacity=16)
    with mgr.cluster(s1):
        assert mgr.live_state is s1
        with pytest.raises(AssertionError):
            with mgr.cluster(s1):
                pass
    assert mgr.live_state is None      # released


def test_cache_stats_accounting():
    st_ = CacheStats()
    st_.record_cluster(prefix_len=100, n_members=4)
    for _ in range(4):
        st_.record_member(member_prompt_len=110, suffix_len=10)
    st_.finalize()
    assert st_.prefill_tokens_baseline == 440
    assert st_.prefill_tokens_cached == 100 + 40
    assert abs(st_.prefill_savings - 440 / 140) < 1e-9
