"""Optimizer, checkpointing, train loop."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt


def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.0)}
    state = opt.init_state(params)
    cfg = opt.AdamWConfig(learning_rate=0.1, weight_decay=0.0,
                          clip_norm=0.0)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, m = opt.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < l0 * 0.05


def test_adamw_frozen_predicate():
    params = {"frozen": jnp.ones(3), "train": jnp.ones(3)}
    state = opt.init_state(params)
    cfg = opt.AdamWConfig(learning_rate=0.1)
    g = {"frozen": jnp.ones(3), "train": jnp.ones(3)}
    p2, _, _ = opt.apply_updates(params, g, state, cfg,
                                 trainable=lambda path: "frozen" not in path)
    assert np.allclose(np.asarray(p2["frozen"]), 1.0)
    assert not np.allclose(np.asarray(p2["train"]), 1.0)


def test_adamw_grad_clipping_metric():
    params = {"w": jnp.ones(4)}
    state = opt.init_state(params)
    cfg = opt.AdamWConfig(clip_norm=1.0)
    g = {"w": jnp.full(4, 100.0)}
    _, _, metrics = opt.apply_updates(params, g, state, cfg)
    assert float(metrics["grad_norm"]) == 200.0


def test_checkpoint_roundtrip():
    params = {"a": {"b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
              "c": [jnp.ones(2), jnp.zeros(3)]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        ckpt.save(path, params, {"step": 7})
        like = jax.eval_shape(lambda: params)
        loaded, meta = ckpt.load(path, like)
        assert meta["step"] == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_loop_reduces_loss_tiny_lm():
    from repro.models import model as M
    from repro.models.config import ModelConfig
    from repro.training.train_loop import train

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=17,
                      dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    fixed = rng.integers(0, 17, size=(4, 12))

    def batches():
        while True:
            yield {"tokens": jnp.asarray(fixed, jnp.int32),
                   "labels": jnp.asarray(np.roll(fixed, -1, 1), jnp.int32),
                   "mask": jnp.ones((4, 12), jnp.float32)}

    params, hist = train(params, cfg,
                         opt.AdamWConfig(learning_rate=5e-3,
                                         weight_decay=0.0),
                         batches(), num_steps=60, log_every=30,
                         log_fn=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8
