"""Replica serving cluster throughput scaling (DESIGN.md §13).

Replays ONE Poisson arrival trace through ``serve_stream`` at 1, 2 and
4 replicas on the same model substrate and measures throughput as
queries / makespan, where makespan is the slowest replica's virtual
clock (per-replica clocks advance by each replica's MEASURED serve
wall time, so N replicas model N devices even though the bench runs
them interleaved on one CPU).

Two arms:

  * **uniform** — the scene-graph query mix as generated; clusters
    spread over replicas by least-loaded spawn, so throughput should
    scale near-linearly (thresholds: >=1.6x at 2 replicas, >=2.7x at
    4).
  * **skew** — half the trace is ONE hot cluster (the same query
    repeated).  A skew present from the FIRST arrival is absorbed by
    least-loaded spawn alone: the hot cluster ends up isolated on its
    own replica, and the arm asserts the recovered throughput at 2
    replicas stays >= 70% of the uniform 2-replica arm.
  * **shift** — placement forms under the uniform mix, THEN the trace
    flips to the skewed mix without resetting placement.  Affinity now
    pins the hot cluster and its co-located neighbours to one replica;
    only the rebalancer's host-round-trip migrations can shed the
    neighbours.  The arm replays the shifted trace with rebalancing
    frozen vs active and reports the gain plus the migration count.

Token identity is asserted per COLD run against the single-replica
drain oracle (the shared assigner sees arrivals in the same global
order at any replica count).  Timing comes from warm replays
(best-of-3 makespan) through the SAME router — placements, cluster
population, and every replica's jit caches stay hot; warm replays are
not re-asserted for identity because the warm assigner's drifted
centroids may legally re-cluster borderline queries.  Writes
``BENCH_replica_serving.json`` at the repo root.  Runs on CPU.

    PYTHONPATH=src python benchmarks/replica_scaling.py
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.data.scenegraph import generate_scene_graph
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.rag.pipeline import GraphRAGPipeline
from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
from repro.rag.text_encoder import TextEncoder
from repro.serving.engine import ServingEngine
from repro.serving.metrics import router_report, trace_summary


def bench_pipeline(max_new_tokens: int):
    graph, queries = generate_scene_graph()
    tok = Tokenizer.train([q.question + " " + q.answer for q in queries]
                          + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="bench-replica", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(64))
    engine = ServingEngine(params, cfg, tok, max_cache_len=512,
                           max_new_tokens=max_new_tokens)
    pipe = GraphRAGPipeline(index=index, retriever=GRetrieverRetriever(index),
                            engine=engine, tokenizer=tok,
                            use_soft_prompt=False)
    return pipe, queries


def _serve(pipe, items, arrivals, n, threshold, max_batch, router=None):
    """One replica-path replay; ``_serve_stream_replicas`` directly so
    the n=1 baseline ALSO runs the router event loop (same clock
    semantics in numerator and denominator of the scaling ratio)."""
    return pipe._serve_stream_replicas(
        items, list(arrivals), replicas=n, max_batch=max_batch,
        pool_budget_bytes=1 << 26, threshold=threshold,
        max_clusters=None, mode="drain", chunk=8, max_suffix_len=None,
        tree_levels=1, tree_clusters=None, host_tier_bytes=None,
        router=router)


def run_arm(pipe, items, arrivals, n, threshold, max_batch,
            oracle_tokens, rep_lens, replays=3, log_fn=print,
            return_router=False):
    """Cold run (builds the router, asserts token identity vs the
    oracle), then warm best-of-``replays`` makespan through the same
    router.  EVERY replica's engine is warmed over the full
    (batch, prefix-length) shape grid first — a migration may hand any
    cluster to any replica, and a one-time jit compile landing on the
    destination's clock would be charged as if it were serving work."""
    recs, _, router = _serve(pipe, items, arrivals, n, threshold,
                             max_batch)
    identical = [r.generated for r in recs] == oracle_tokens
    assert identical, \
        f"replica serving (n={n}) must match the single-replica oracle"
    bs = tuple(sorted({1, 2, max_batch}))
    for r in router.replicas:
        r.engine.warmup_pooled(rep_lens, batches=bs, num_prefixes=bs)
    _serve(pipe, items, arrivals, n, threshold, max_batch,
           router=router)                      # untimed settling replay
    best_recs, best_span = None, float("inf")
    for _ in range(replays):
        # each timed replay re-runs the PLACEMENT policy from scratch
        # (spawns + rebalances on this replay's own measured loads)
        # instead of inheriting wherever the previous replay's
        # migrations left the map; jit caches and pools stay warm
        router.placement.clear()
        recs_w, _, _ = _serve(pipe, items, arrivals, n, threshold,
                              max_batch, router=router)
        if router.makespan < best_span:
            best_recs, best_span = recs_w, router.makespan
    rep = router_report(router, best_recs)
    out = {
        "replicas": n,
        "makespan_s": round(best_span, 4),
        "throughput_qps": round(len(items) / best_span, 3),
        "token_identical_cold": identical,
        "mean_ttft_ms": trace_summary(best_recs)["mean_ttft_ms"],
        "imbalance": rep["imbalance"],
        "migrations": rep["migrations"],
        "affinity_hit_rate": {
            k: v["affinity_hit_rate"] for k, v in rep["replicas"].items()},
        "router": rep,
    }
    log_fn(f"  n={n}: makespan {best_span:7.3f}s  "
           f"throughput {out['throughput_qps']:7.2f} q/s  "
           f"imbalance {rep['imbalance']:.2f}  "
           f"migrations {rep['migrations']}")
    return (out, router) if return_router else out


def run(num_queries: int = 48, max_batch: int = 4, gap_s: float = 0.0002,
        threshold: float = 0.15, max_new_tokens: int = 48,
        replicas=(1, 2, 4), replays: int = 3,
        shift_gap_s: float = 0.002, seed: int = 0, log_fn=print):
    pipe, queries = bench_pipeline(max_new_tokens)
    rng = np.random.default_rng(seed)

    uniq = queries[:num_queries]
    arrivals = np.cumsum(rng.exponential(gap_s, size=num_queries))
    # skew trace: every other slot is the SAME query -> one cluster
    # carries half the offered load
    hot = uniq[0]
    skew = [hot if i % 2 == 0 else uniq[i] for i in range(num_queries)]
    rep_lens = sorted({len(pipe.tokenizer.encode(
        pipe.prefix_text(pipe.retriever.retrieve(it.question)),
        bos=True)) for it in uniq})

    result = {"uniform": {}, "skew": {}}
    oracles = {}
    for name, items in (("uniform", uniq), ("skew", skew)):
        log_fn(f"[{name}] oracle: single-replica drain")
        orc, _, _ = pipe.serve_stream(
            items, list(arrivals), mode="drain", max_batch=max_batch,
            threshold=threshold, pool_budget_bytes=1 << 26)
        oracles[name] = [r.generated for r in orc]
        ns = replicas if name == "uniform" else (1, 2)
        for n in ns:
            result[name][f"n{n}"] = run_arm(
                pipe, items, arrivals, n, threshold, max_batch,
                oracles[name], rep_lens, replays=replays, log_fn=log_fn)

    uni = result["uniform"]
    base = uni["n1"]["throughput_qps"]
    for n in replicas:
        if n == 1:
            continue
        uni[f"n{n}"]["scaling_x"] = round(
            uni[f"n{n}"]["throughput_qps"] / base, 3)
    sk = result["skew"]
    sk["n2"]["scaling_x"] = round(
        sk["n2"]["throughput_qps"] / sk["n1"]["throughput_qps"], 3)
    # skew recovery at spawn time: 2-replica skew throughput relative
    # to the uniform 2-replica arm (a skew KNOWN from the first arrival
    # is absorbed by least-loaded spawn alone — the hot cluster ends up
    # isolated on its own replica)
    result["skew_recovery_vs_uniform"] = round(
        sk["n2"]["throughput_qps"] / uni["n2"]["throughput_qps"], 3)
    result["shift"] = run_shift_arm(
        pipe, uniq, skew, threshold, max_batch, oracles["uniform"],
        rep_lens, num_queries, shift_gap_s, rng, replays=replays,
        log_fn=log_fn)

    log_fn(f"uniform scaling: x2={uni.get('n2', {}).get('scaling_x')}  "
           f"x4={uni.get('n4', {}).get('scaling_x')}")
    log_fn(f"skew: scaling x2={sk['n2']['scaling_x']}  "
           f"recovery vs uniform "
           f"{result['skew_recovery_vs_uniform']:.2f}")
    sh = result["shift"]
    log_fn(f"shift: rebalance x{sh['rebalance_gain_x']} over frozen "
           f"placement, recovery vs uniform "
           f"{sh['recovery_vs_uniform']:.2f}, "
           f"migrations {sh['rebalance']['migrations']}")
    return result


def run_shift_arm(pipe, uniq, skew, threshold, max_batch, oracle_tokens,
                  rep_lens, num_queries, shift_gap_s, rng, replays=3,
                  log_fn=print):
    """Workload shift — where MIGRATION (not spawn placement) is the
    recovery mechanism: placement forms under the uniform mix, then the
    trace flips to the skewed mix WITHOUT resetting placement.  Cluster
    affinity now pins the hot cluster AND its co-located neighbours to
    one replica; only the rebalancer's host-round-trip migrations can
    shed the neighbours.  Arrivals are spread over the serve window
    (``shift_gap_s``) because migration redirects FUTURE arrivals —
    against an instantaneous burst every query is already queued before
    the first rebalance can fire.  Compares the same shifted trace with
    rebalancing frozen (hot_ratio=inf) vs active."""
    from repro.serving.metrics import router_report
    arr = np.cumsum(rng.exponential(shift_gap_s, size=num_queries))
    log_fn("[shift] uniform reference at the shift arrival rate")
    # tokens depend on items + arrival ORDER only, so the uniform
    # oracle tokens transfer to the rescaled arrival vector
    ref, router = run_arm(pipe, uniq, arr, 2, threshold, max_batch,
                          oracle_tokens, rep_lens, replays=replays,
                          log_fn=log_fn, return_router=True)
    snap = dict(router.placement)        # placement the uniform mix built
    out = {"uniform_ref": ref}
    for label, hr in (("no_rebalance", float("inf")),
                      ("rebalance", 1.25)):
        router.hot_ratio = hr
        best, best_rep = float("inf"), None
        for _ in range(replays):
            router.placement.clear()
            router.placement.update(snap)
            _serve(pipe, skew, arr, 2, threshold, max_batch,
                   router=router)
            if router.makespan < best:
                best, best_rep = router.makespan, router_report(router)
        out[label] = {
            "makespan_s": round(best, 4),
            "throughput_qps": round(num_queries / best, 3),
            "migrations": best_rep["migrations"],
            "imbalance": best_rep["imbalance"],
        }
        log_fn(f"  {label:12s} makespan {best:7.3f}s  "
               f"throughput {out[label]['throughput_qps']:7.2f} q/s  "
               f"migrations {best_rep['migrations']}")
    out["rebalance_gain_x"] = round(
        out["rebalance"]["throughput_qps"]
        / out["no_rebalance"]["throughput_qps"], 3)
    out["recovery_vs_uniform"] = round(
        out["rebalance"]["throughput_qps"] / ref["throughput_qps"], 3)
    assert out["rebalance"]["migrations"] >= 1, \
        "the shifted mix must actually exercise rebalancing"
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=48)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--gap-s", type=float, default=0.0002)
    ap.add_argument("--threshold", type=float, default=0.15)
    ap.add_argument("--max-new-tokens", type=int, default=48)
    ap.add_argument("--replays", type=int, default=3)
    ap.add_argument("--shift-gap-s", type=float, default=0.002)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_replica_serving.json"))
    args = ap.parse_args()
    result = run(num_queries=args.queries, max_batch=args.max_batch,
                 gap_s=args.gap_s, threshold=args.threshold,
                 max_new_tokens=args.max_new_tokens, replays=args.replays,
                 shift_gap_s=args.shift_gap_s)
    payload = {
        "benchmark": "replica_serving_scaling_poisson",
        "config": "bench-replica (2L d64 GQA 4:2, f32, scene-graph RAG)",
        "trace": {"queries": args.queries, "poisson_gap_s": args.gap_s,
                  "shift_poisson_gap_s": args.shift_gap_s,
                  "max_batch": args.max_batch,
                  "spawn_threshold": args.threshold,
                  "max_new_tokens": args.max_new_tokens,
                  "mode": "drain", "timing": f"warm best-of-{args.replays}"},
        "result": result,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
