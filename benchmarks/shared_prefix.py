"""Broadcast vs shared-prefix cascade serving across member batch sizes.

Measures what the split prefix/suffix cache actually changes (DESIGN.md
§5), per member batch size B:

  * ``cache_bytes``        — HBM allocated for KV slots while serving one
                             cluster (prefix state + member cache).
                             Broadcast pays B×(P+S) slots, cascade pays
                             P + B×S.
  * ``prefix_read_bytes``  — prefix KV bytes streamed per suffix-prefill
                             layer pass: broadcast re-reads the
                             replicated prefix B times, cascade reads the
                             batch-1 buffers once per kv-head group.
  * ``prefill_s`` / ``decode_s`` — measured wall time (post-warmup).

Writes ``BENCH_shared_prefix.json`` at the repo root to seed the perf
trajectory.  Runs on CPU in interpret-free XLA mode; no workbench
training needed (timing is backbone-agnostic, so random weights do).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.bucketing import bucket_len
from repro.serving.engine import ServingEngine


def bench_config(vocab_size: int) -> ModelConfig:
    """Small attention-only GQA stack (llama-family shape)."""
    return ModelConfig(name="bench-cascade", family="dense", num_layers=4,
                       d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
                       d_ff=256, vocab_size=vocab_size, dtype="float32")


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def _kv_bytes_per_layer(cfg: ModelConfig, batch: int, capacity: int) -> int:
    """K+V bytes of one layer's cache block (the HBM the attention pass
    must stream)."""
    itemsize = jnp.dtype(cfg.dtype).itemsize
    return 2 * batch * capacity * cfg.num_kv_heads * cfg.head_dim_ * itemsize


def run(batch_sizes=(2, 4, 8, 16), prefix_len: int = 192,
        suffix_len: int = 24, max_new_tokens: int = 8, repeats: int = 3,
        log_fn=print):
    rng = np.random.default_rng(0)
    tok = Tokenizer.train(["a b c d e f g h"])
    cfg = bench_config(max(64, tok.vocab_size))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    n_layers = len(cfg.layer_specs())

    engines = {
        # paged=False: this benchmark isolates the DENSE cascade vs
        # broadcast (paged serving has its own bench, paged_serving.py)
        "cascade": ServingEngine(params, cfg, tok, max_cache_len=1024,
                                 max_new_tokens=max_new_tokens,
                                 paged=False),
        "broadcast": ServingEngine(params, cfg, tok, max_cache_len=1024,
                                   max_new_tokens=max_new_tokens,
                                   split_prefix=False),
    }
    assert engines["cascade"].use_split_prefix
    assert not engines["broadcast"].use_split_prefix

    prefix = [int(t) for t in rng.integers(4, cfg.vocab_size,
                                           size=prefix_len)]
    rows = []
    for b in batch_sizes:
        suffixes = [[int(t) for t in rng.integers(4, cfg.vocab_size,
                                                  size=suffix_len)]
                    for _ in range(b)]
        row = {"batch": b, "prefix_len": prefix_len,
               "suffix_len": suffix_len}
        for mode, eng in engines.items():
            state, _ = eng.prefill_prefix(prefix, _record=False)
            eng.generate_with_prefix(state, suffixes,
                                     _record=False)        # compile warmup
            best = {"prefill_s": float("inf"), "decode_s": float("inf")}
            for _ in range(repeats):
                state, _ = eng.prefill_prefix(prefix)
                _, t = eng.generate_with_prefix(state, suffixes)
                best["prefill_s"] = min(best["prefill_s"], t["prefill_s"])
                best["decode_s"] = min(best["decode_s"], t["decode_s"])

            # prefix-read accounting uses prefix TOKENS on both sides
            # (not each mode's capacity bucket) so the ratio is the
            # honest "once per member vs once": exactly B
            if mode == "cascade":
                suffix_cap = eng._suffix_capacity_for(
                    bucket_len(suffix_len, eng.bucket))
                member_cache = jax.eval_shape(
                    lambda e=eng, c=suffix_cap:
                    M.init_suffix_cache(e.cfg, b, c))
                # batch-1 prefix buffers read once per kv-head group
                prefix_read = n_layers * _kv_bytes_per_layer(
                    cfg, 1, state.prefix_len)
            else:
                member_cache = jax.eval_shape(
                    lambda e=eng, s=state: M.init_cache(e.cfg, b, s.capacity))
                # replicated prefix re-streamed once per member
                prefix_read = n_layers * _kv_bytes_per_layer(
                    cfg, b, state.prefix_len)
            row[mode] = {
                "cache_bytes": _tree_bytes(state.cache)
                               + _tree_bytes(member_cache),
                "prefix_read_bytes_per_prefill": prefix_read,
                "prefill_s": round(best["prefill_s"], 6),
                "decode_s": round(best["decode_s"], 6),
            }
        c, br = row["cascade"], row["broadcast"]
        row["cache_bytes_ratio"] = round(br["cache_bytes"]
                                         / c["cache_bytes"], 3)
        row["prefix_read_ratio"] = round(
            br["prefix_read_bytes_per_prefill"]
            / c["prefix_read_bytes_per_prefill"], 3)
        row["prefill_speedup"] = round(br["prefill_s"] / c["prefill_s"], 3)
        log_fn(f"B={b:3d}: cache {br['cache_bytes']/2**20:7.1f}MiB -> "
               f"{c['cache_bytes']/2**20:7.1f}MiB (x{row['cache_bytes_ratio']:.2f})"
               f" | prefix-read x{row['prefix_read_ratio']:.2f}"
               f" | prefill {br['prefill_s']*1e3:8.2f}ms -> "
               f"{c['prefill_s']*1e3:8.2f}ms (x{row['prefill_speedup']:.2f})")
        rows.append(row)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[2, 4, 8, 16])
    ap.add_argument("--prefix-len", type=int, default=192)
    ap.add_argument("--suffix-len", type=int, default=24)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_shared_prefix.json"))
    args = ap.parse_args()
    rows = run(tuple(args.sizes), prefix_len=args.prefix_len,
               suffix_len=args.suffix_len)
    with open(args.out, "w") as f:
        json.dump({"benchmark": "shared_prefix_cascade_vs_broadcast",
                   "config": "bench-cascade (4L d128 GQA 8:2, f32)",
                   "rows": rows}, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
