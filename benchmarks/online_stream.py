"""Online pooled serving vs offline batch vs no-cache under Poisson traffic.

Replays one Poisson arrival trace through three serving modes and
reports TTFT per query (queue wait included — a streaming user
experiences it):

  * ``no_cache``  — FIFO, one query at a time, full prompt prefill
                    (the G-Retriever baseline under streaming traffic).
  * ``offline``   — the paper's batch pipeline (``run_subgcache``):
                    every query must WAIT for the last arrival before
                    the dendrogram can be cut; per-query TTFT adds that
                    wait (and is otherwise optimistic — cross-cluster
                    queueing inside the batch is not charged).
  * ``online``    — ``serve_stream`` (DESIGN.md §7): micro-batches,
                    incremental cluster assignment, byte-budgeted
                    ``PrefixPool``, multi-prefix batched decode.

Every mode is warmed up on a throwaway trace first (jit compilation
never lands in a timed region, EXPERIMENTS.md protocol).  Writes
``BENCH_online_stream.json`` at the repo root; the headline check is
``online`` (whose steady state serves suffix-only prefills from pool
hits) beating ``no_cache`` mean TTFT per query.  Runs on CPU.

    PYTHONPATH=src python benchmarks/online_stream.py
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.data.scenegraph import generate_scene_graph
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.rag.pipeline import GraphRAGPipeline
from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
from repro.rag.text_encoder import TextEncoder
from repro.serving.engine import ServingEngine
from repro.serving.metrics import QueryRecord


def bench_pipeline(max_new_tokens: int):
    """(GraphRAGPipeline, queries) on random weights — timing is
    backbone-agnostic; accuracy is not measured here."""
    graph, queries = generate_scene_graph()
    tok = Tokenizer.train([q.question + " " + q.answer for q in queries]
                          + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="bench-online", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(64))
    engine = ServingEngine(params, cfg, tok, max_cache_len=512,
                           max_new_tokens=max_new_tokens)
    pipe = GraphRAGPipeline(index=index, retriever=GRetrieverRetriever(index),
                            engine=engine, tokenizer=tok,
                            use_soft_prompt=False)
    return pipe, queries


def serve_nocache(pipe: GraphRAGPipeline, items, arrivals):
    """FIFO single-query serving: the no-cache streaming baseline."""
    order = np.argsort(arrivals, kind="stable")
    records = [None] * len(items)
    clock = 0.0
    for i in order:
        now = max(clock, float(arrivals[i]))
        t0 = time.perf_counter()
        it = items[i]
        t1 = time.perf_counter()
        sg = pipe.retriever.retrieve(it.question)
        rt = time.perf_counter() - t1
        t1 = time.perf_counter()
        prompt = pipe.prefix_text(sg) + " " + pipe.suffix_text(it.question)
        toks = pipe.tokenizer.encode(prompt, bos=True)
        t_build = time.perf_counter() - t1
        out, t = pipe.engine.generate(toks)
        text = pipe.tokenizer.decode(out)
        records[i] = QueryRecord(
            query=it.question, answer=it.answer, generated=text,
            correct=False, retrieval_s=rt,
            queue_wait_s=now - float(arrivals[i]), prompt_build_s=t_build,
            prefill_s=t["prefill_s"], decode_s=t["decode_s"],
            prompt_tokens=len(toks))
        clock = now + (time.perf_counter() - t0)
    return records


def serve_offline(pipe: GraphRAGPipeline, items, arrivals,
                  num_clusters: int):
    """The paper's batch pipeline on streaming arrivals: everything
    waits for the LAST arrival, then one offline plan is served."""
    records, _, _, _ = pipe.run_subgcache(items, num_clusters=num_clusters)
    horizon = float(np.max(arrivals))
    for r, t_arr in zip(records, arrivals):
        r.queue_wait_s = horizon - float(t_arr)
    return records


def _summ(records):
    ttft = np.array([r.ttft for r in records])
    return {
        "mean_ttft_ms": round(1e3 * float(np.mean(ttft)), 3),
        "p50_ttft_ms": round(1e3 * float(np.median(ttft)), 3),
        "p90_ttft_ms": round(1e3 * float(np.percentile(ttft, 90)), 3),
        "mean_queue_wait_ms": round(
            1e3 * float(np.mean([r.queue_wait_s for r in records])), 3),
        "mean_pftt_ms": round(
            1e3 * float(np.mean([r.pftt for r in records])), 3),
    }


def run(num_queries: int = 16, max_batch: int = 4, gap_s: float = 0.05,
        threshold: float = 0.25, num_clusters: int = 4,
        max_new_tokens: int = 8, seed: int = 0, log_fn=print):
    pipe, queries = bench_pipeline(max_new_tokens)
    items = queries[:num_queries]
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(gap_s, size=len(items)))

    # ---- warmup: compile every shape bucket each mode touches --------
    # the (batch, pool-size) grid is compiled systematically — online
    # micro-batch composition depends on arrival dynamics, so a single
    # replay would miss buckets the faster post-compile run touches —
    # then each mode replays the identical trace once, timings discarded.
    # every representative length the trace can serve: on the paged
    # backend each page-table WIDTH bucket is its own compiled shape,
    # so a single max-length warmup would leave narrower tables cold
    rep_lens = sorted({len(pipe.tokenizer.encode(
        pipe.prefix_text(pipe.retriever.retrieve(it.question)), bos=True))
        for it in items})
    bs = tuple(sorted({1, 2, max_batch}))
    pipe.engine.warmup_pooled(rep_lens, batches=bs, num_prefixes=bs)
    # two untimed replays: micro-batch composition depends on measured
    # service times, so the drain pattern only settles once post-compile
    for _ in range(2):
        pipe.serve_stream(items, arrivals, mode="drain",
                          max_batch=max_batch,
                          threshold=threshold, pool_budget_bytes=1 << 26)
    serve_nocache(pipe, items, arrivals)
    pipe.run_subgcache(items, num_clusters=num_clusters)

    # ---- timed runs ---------------------------------------------------
    recs_on, _, sched = pipe.serve_stream(
        items, arrivals, mode="drain", max_batch=max_batch,
        threshold=threshold, pool_budget_bytes=1 << 26)
    stats = sched.pool.stats
    recs_nc = serve_nocache(pipe, items, arrivals)
    recs_off = serve_offline(pipe, items, arrivals, num_clusters)

    result = {
        "no_cache": _summ(recs_nc),
        "offline": _summ(recs_off),
        "online": _summ(recs_on),
    }
    hit = [r for r in recs_on if r.cached_tokens > 0]
    if hit:
        result["online"]["hit_mean_ttft_ms"] = _summ(hit)["mean_ttft_ms"]
    result["online"]["pool"] = {
        "hits": stats.pool_hits, "misses": stats.pool_misses,
        "evictions": stats.pool_evictions,
        "reprefills": stats.pool_reprefills,
        "hit_rate": round(stats.pool_hit_rate, 3),
        "clusters": len(sched.assigner.clusters),
    }
    result["speedup_ttft_online_vs_no_cache"] = round(
        result["no_cache"]["mean_ttft_ms"] / result["online"]["mean_ttft_ms"],
        3)
    result["speedup_ttft_online_vs_offline"] = round(
        result["offline"]["mean_ttft_ms"] / result["online"]["mean_ttft_ms"],
        3)
    for mode in ("no_cache", "offline", "online"):
        s = result[mode]
        log_fn(f"{mode:9s} mean TTFT {s['mean_ttft_ms']:9.1f}ms  "
               f"(wait {s['mean_queue_wait_ms']:8.1f}ms, "
               f"pftt {s['mean_pftt_ms']:7.1f}ms)")
    log_fn(f"online vs no-cache TTFT: "
           f"x{result['speedup_ttft_online_vs_no_cache']:.2f}  "
           f"pool hit rate {result['online']['pool']['hit_rate']:.0%}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--gap-s", type=float, default=0.05)
    ap.add_argument("--threshold", type=float, default=0.25)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_online_stream.json"))
    args = ap.parse_args()
    result = run(num_queries=args.queries, max_batch=args.max_batch,
                 gap_s=args.gap_s, threshold=args.threshold,
                 num_clusters=args.clusters)
    payload = {
        "benchmark": "online_stream_poisson_ttft",
        "config": "bench-online (2L d64 GQA 4:2, f32, scene-graph RAG)",
        "trace": {"queries": args.queries, "poisson_gap_s": args.gap_s,
                  "max_batch": args.max_batch,
                  "spawn_threshold": args.threshold,
                  "offline_num_clusters": args.clusters},
        "result": result,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
