"""Benchmark harness entrypoint: one function per paper table/figure.

``python -m benchmarks.run`` executes the fast suite and prints
``name,us_per_call,derived`` CSV rows.  The heavyweight full-scale
variants live in the sibling modules (table2_overall, fig3_cluster_sweep,
fig4_cluster_time, table3_linkage, table4_batch_size, roofline) and are
driven with larger query counts from the CLI.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS = []


def row(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def _time(fn, iters=3, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


# ----------------------------------------------------------------------
def bench_kernels():
    """Pallas kernels (interpret mode) vs jnp oracle — per-call us."""
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(0)
    B, Hq, Hkv, D, S, T = 2, 8, 2, 64, 256, 64
    q = jax.random.normal(key, (B, Hq, T, D))
    k = jax.random.normal(key, (B, Hkv, S, D))
    v = jax.random.normal(key, (B, Hkv, S, D))
    q_pos = jnp.broadcast_to(128 + jnp.arange(T)[None], (B, T))
    k_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    f1 = lambda: ops.prefix_attention(q, k, v, q_pos, k_pos)
    f2 = jax.jit(lambda: ref.prefix_attention_ref(q, k, v, q_pos, k_pos))
    us1 = _time(lambda: jax.block_until_ready(f1()))
    us2 = _time(lambda: jax.block_until_ready(f2()))
    row("kernel.prefix_attention.pallas_interpret", us1, f"ref_us={us2:.0f}")

    qd = jax.random.normal(key, (B, Hq, D))
    us = _time(lambda: jax.block_until_ready(
        ops.decode_gqa(qd, k, v, q_pos[:, 0], k_pos)))
    row("kernel.decode_gqa.pallas_interpret", us)

    Bt, T2, Di, N = 2, 64, 128, 16
    x = jax.random.normal(key, (Bt, T2, Di))
    dt = jax.nn.softplus(jax.random.normal(key, (Bt, T2, Di))) * 0.1
    Bm = jax.random.normal(key, (Bt, T2, N))
    Cm = jax.random.normal(key, (Bt, T2, N))
    A = -jnp.exp(jax.random.normal(key, (Di, N)))
    us = _time(lambda: jax.block_until_ready(
        ops.ssm_scan(x, dt, Bm, Cm, A, block_d=64, block_t=32)))
    row("kernel.ssm_scan.pallas_interpret", us)

    W = 128
    xw = jax.random.normal(key, (Bt, T2, W))
    al = -jax.nn.softplus(jax.random.normal(key, (Bt, T2, W)))
    us = _time(lambda: jax.block_until_ready(
        ops.rglru_scan(xw, al, block_w=64, block_t=32)))
    row("kernel.rglru_scan.pallas_interpret", us)


def bench_clustering():
    """Hierarchical clustering cost (paper Fig. 4 substrate)."""
    from repro.core.clustering import hierarchical_clustering
    rng = np.random.default_rng(0)
    for m in (50, 200):
        x = rng.normal(size=(m, 64))
        us = _time(lambda: hierarchical_clustering(x, 5, "ward"), iters=2)
        row(f"core.clustering.ward.m{m}", us)


def bench_moe_dispatch():
    """Sort-based MoE dispatch vs dense oracle."""
    from repro.models import moe as moe_lib
    key = jax.random.PRNGKey(0)
    p = moe_lib.init_moe(key, 128, 256, 8, jnp.float32)
    x = jax.random.normal(key, (4, 128, 128))
    f_sort = jax.jit(lambda: moe_lib.apply_moe(x=x, p=p, top_k=2)[0])
    f_dense = jax.jit(lambda: moe_lib.apply_moe_dense_oracle(x=x, p=p, top_k=2))
    us1 = _time(lambda: jax.block_until_ready(f_sort()))
    us2 = _time(lambda: jax.block_until_ready(f_dense()))
    row("moe.dispatch.sort_capacity", us1, f"dense_oracle_us={us2:.0f}")


def bench_subgcache_small():
    """Reduced Table-2: 24 in-batch queries on the cached tiny backbone."""
    from benchmarks import table2_overall
    logs = []
    t0 = time.perf_counter()
    rows_ = table2_overall.run(num_queries=24, train_steps=200,
                               datasets=("scene",),
                               retrievers=("gretriever",),
                               log_fn=lambda *a: logs.append(" ".join(map(str, a))))
    us = (time.perf_counter() - t0) * 1e6
    r = rows_[0]
    row("paper.table2.scene.gretriever", us,
        f"ttft_x={r['speedup']['ttft_x']:.2f};pftt_x={r['speedup']['pftt_x']:.2f};"
        f"dacc={r['speedup']['acc_delta']:+.1f}")
    for line in logs:
        print("#", line)


def main() -> None:
    os.makedirs("results", exist_ok=True)
    print("name,us_per_call,derived")
    bench_kernels()
    bench_clustering()
    bench_moe_dispatch()
    bench_subgcache_small()
    # roofline table (if the dry-run sweep has produced results)
    if os.path.exists("results/dryrun.json"):
        import json
        from benchmarks.roofline import fmt_table
        with open("results/dryrun.json") as f:
            results = json.load(f)
        ok = sum(1 for r in results if r["status"] == "ok")
        row("dryrun.pairs_ok", 0.0, f"count={ok}/{len(results)}")


if __name__ == "__main__":
    main()
