"""Hierarchical prefix trees vs the flat per-cluster prefix layout
under the PR 2 Poisson trace (DESIGN.md §10).

Replays one Poisson arrival trace through ``serve_stream`` twice at the
SAME prefix-pool HBM byte budget:

  * ``flat`` — the PR 4 path: one flat prefix per leaf cluster, seeded
    from an offline ``plan_batch`` cut (``from_plan`` warm start);
  * ``tree`` — the same leaf clusters cut from the SAME dendrogram,
    but each leaf's prefix is a root→leaf CHAIN: ancestor segments
    (the content sibling clusters share) are pooled ONCE and every
    descendant path references them.

The budget is sized so the flat layout cannot keep every cluster
prefix resident — layout efficiency decides what stays cached.  The
tree keeps more prefix tokens resident per byte (shared segments are
stored once), so it re-prefills less and serves a lower mean TTFT.

Reported per mode: mean/p95 TTFT, total prefill tokens (prefix +
suffix actually computed), pool counters, resident prefix tokens
(each pooled segment counted once), and the per-level tree accounting
(``trace_summary(records, stats)``).  Token identity is ASSERTED per
replay: the tree trace served continuous must reproduce the tree
drain-serve oracle token for token (scheduling changes, math never).

A ``dendrogram_cut_reuse`` section times the fig3-style cluster sweep
with the merge tree computed once vs re-clustered per point.

Writes ``BENCH_tree_serving.json`` at the repo root.  Runs on CPU.

    PYTHONPATH=src python benchmarks/tree_serving.py
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core.clustering import build_dendrogram
from repro.core.planner import plan_batch, plan_prefix_tree
from repro.core.prefix_pool import PrefixPool
from repro.data.scenegraph import generate_scene_graph
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.core.paged import KVBlockPool
from repro.rag.pipeline import GraphRAGPipeline
from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
from repro.rag.text_encoder import TextEncoder
from repro.serving.bucketing import blocks_for
from repro.serving.engine import ServingEngine
from repro.serving.metrics import trace_summary
from repro.serving.scheduler import OnlineClusterAssigner, OnlineScheduler

MAX_CACHE_LEN = 1024
BLOCK_SIZE = 32


def substrate():
    graph, queries = generate_scene_graph()
    tok = Tokenizer.train([q.question + " " + q.answer for q in queries]
                          + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="bench-tree", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(64))
    return graph, queries, tok, cfg, params, index


def make_pipe(tok, cfg, params, index, max_new_tokens, arena_blocks):
    # top_k=8 retrieval: representative prefixes long enough that
    # re-prefilling one costs real compute, and overlapping enough that
    # sibling clusters share substantial ancestor content — the
    # workload regime hierarchical prefix trees exist for
    engine = ServingEngine(params, cfg, tok, max_cache_len=MAX_CACHE_LEN,
                           max_new_tokens=max_new_tokens,
                           block_size=BLOCK_SIZE,
                           arena_blocks=arena_blocks)
    return GraphRAGPipeline(index=index,
                            retriever=GRetrieverRetriever(index, top_k=8),
                            engine=engine, tokenizer=tok,
                            use_soft_prompt=False)


def _seed_scheduler(pipe, subgraphs, emb, *, tree, num_clusters,
                    tree_levels, budget, dendrogram):
    """Both modes seed the SAME leaf clusters from the SAME dendrogram;
    only the prefix layout differs (flat single segments vs chains)."""
    if tree:
        plan = plan_prefix_tree(subgraphs, emb, num_clusters,
                                tree_levels=tree_levels,
                                dendrogram=dendrogram)
        assigner = OnlineClusterAssigner.from_tree_plan(plan, emb)
    else:
        plan = plan_batch(subgraphs, emb, num_clusters,
                          dendrogram=dendrogram)
        assigner = OnlineClusterAssigner.from_plan(plan, emb)
    return OnlineScheduler(pipe.engine, assigner, PrefixPool(budget),
                           pipe._prefix_payload,
                           segment_tokens_fn=pipe._segment_payload), plan


def _resident_path_tokens(sched) -> int:
    """Prefix tokens SERVABLE from cache at this instant: for every
    cluster whose leaf entry is resident, its full path length.  This
    is the coverage metric the tree layout improves — a shared ancestor
    occupies its bytes ONCE but contributes to every resident
    descendant path (flat layouts pay those bytes per cluster)."""
    total = 0
    for c in sched.assigner.clusters:
        key = ("seg", c.chain.keys[-1]) if c.chain is not None \
            else c.cluster_id
        e = sched.pool.entry(key)
        if e is not None:
            total += e.state.prefix_len
    return total


def _warm_chains(pipe, subgraphs, emb, **seed_kw):
    """Compile pass: materialize every cluster's chain once (extension
    prefills are their own jit signatures — an unwarmed one would land
    an XLA compile inside a timed TTFT), then drop the states."""
    sched, _ = _seed_scheduler(pipe, subgraphs, emb, **seed_kw)
    for cid in range(len(sched.assigner.clusters)):
        sched.ensure_chain(cid)
    sched.pool.clear()


def _chain_lens(pipe, plan, tree):
    """Distinct prefix lengths covering the page-table WIDTHS the trace
    can serve (the warmup grid).  A chain's width is the SUM of its
    segments' block counts (each segment rounds up to whole blocks), so
    tree lengths are emitted width-equivalent — ``width × block_size``
    tokens compile exactly the bucket the chain will walk."""
    tokf = pipe.tokenizer
    out = set()
    if tree:
        for leaf in plan.leaves:
            blocks = 0
            chain = plan.chain(leaf)
            for i, content in enumerate(chain.contents):
                base = chain.contents[i - 1] if i else None
                payload = pipe._segment_payload(content, base)
                toks = payload[0] if isinstance(payload, tuple) else payload
                blocks += blocks_for(len(toks), BLOCK_SIZE)
            out.add(blocks * BLOCK_SIZE)
    else:
        for cp in plan.clusters:
            out.add(len(tokf.encode(pipe.prefix_text(cp.representative),
                                    bos=True)))
    return sorted(out)


def run(num_queries: int = 24, max_batch: int = 4, gap_s: float = 0.04,
        num_clusters: int = 6, tree_levels: int = 3,
        max_new_tokens: int = 8, seed: int = 0,
        budget_frac: float = 0.5, log_fn=print):
    graph, queries, tok, cfg, params, index = substrate()
    items = queries[:num_queries]
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(gap_s, size=len(items)))

    # one retrieval + embedding + dendrogram pass shared by both modes
    probe = make_pipe(tok, cfg, params, index, max_new_tokens, 64)
    subgraphs = [probe.retriever.retrieve(it.question) for it in items]
    emb = probe.embed_for_clustering(subgraphs)
    dd = build_dendrogram(emb)
    flat_plan = plan_batch(subgraphs, emb, num_clusters, dendrogram=dd)
    flat_lens = _chain_lens(probe, flat_plan, tree=False)

    # equal byte budget: a FRACTION of what all flat cluster prefixes
    # cost resident at once — the flat pool must evict, the tree's
    # shared ancestors stretch the same bytes further
    per_block = KVBlockPool.block_bytes_for(cfg, BLOCK_SIZE)
    flat_total_blocks = sum(blocks_for(p, BLOCK_SIZE) for p in flat_lens)
    budget = int(budget_frac * flat_total_blocks * per_block)
    arena_blocks = (flat_total_blocks + 2 * max_batch
                    * blocks_for(MAX_CACHE_LEN, BLOCK_SIZE) + 32)

    result = {"trace": {
        "queries": num_queries, "poisson_gap_s": gap_s,
        "max_batch": max_batch, "num_clusters": num_clusters,
        "tree_levels": tree_levels, "budget_bytes": budget,
        "budget_frac_of_flat_resident": budget_frac,
        "flat_prefix_lens": flat_lens}}

    # ------------------------------------------------------------------
    # build + warm BOTH modes up front, then INTERLEAVE the timed
    # replays pairwise: whole-benchmark CPU drift (frequency, page
    # cache, contention) is much larger than the layout effect, so an
    # unpaired flat-phase-then-tree-phase protocol measures the
    # machine, not the layout.  At a warm 100% hit rate the two
    # layouts serve at identical speed (no steady-state chain
    # overhead); the paired cold replays isolate what the tree
    # actually changes — how much re-prefill the byte budget forces.
    # ------------------------------------------------------------------
    pipes, oracles, seed_kws = {}, {}, {}
    for mode in ("flat", "tree"):
        tree = mode == "tree"
        pipe = make_pipe(tok, cfg, params, index, max_new_tokens,
                         arena_blocks)
        seed_kw = dict(tree=tree, num_clusters=num_clusters,
                       tree_levels=tree_levels, budget=budget,
                       dendrogram=dd)
        sched, plan = _seed_scheduler(pipe, subgraphs, emb, **seed_kw)
        pipe.warmup_stream(items, max_batch=max_batch, chunk=2,
                           prefix_lens=_chain_lens(pipe, plan, tree))
        _warm_chains(pipe, subgraphs, emb, **seed_kw)
        if tree:
            result["trace"]["tree_levels_realized"] = plan.levels
            result["trace"]["tree_nodes"] = len(plan.nodes)
        # token-identity oracle: the SAME cluster population served
        # drain-style must emit identical generations per query
        oracle, _, _ = pipe.serve_stream(
            items, arrivals, mode="drain", max_batch=max_batch,
            pool_budget_bytes=budget, scheduler=sched)
        sched.pool.clear()
        # one untimed continuous replay settles the drain pattern the
        # timed replays will see (measured service times feed back into
        # micro-batch composition — EXPERIMENTS.md protocol)
        warm, _ = _seed_scheduler(pipe, subgraphs, emb, **seed_kw)
        pipe.serve_stream(items, arrivals, mode="continuous",
                          max_batch=max_batch, chunk=2, scheduler=warm)
        pipes[mode], oracles[mode], seed_kws[mode] = pipe, oracle, seed_kw

    runs = {"flat": [], "tree": []}
    for _ in range(5):
        for mode in ("flat", "tree"):
            pipe = pipes[mode]
            sched, _ = _seed_scheduler(pipe, subgraphs, emb,
                                       **seed_kws[mode])
            recs, _, sched = pipe.serve_stream(
                items, arrivals, mode="continuous", max_batch=max_batch,
                chunk=2, scheduler=sched)
            assert ([r.generated for r in recs]
                    == [r.generated for r in oracles[mode]]), \
                f"{mode}: continuous trace diverged from the drain oracle"
            stats = sched.pool.stats
            sched.pool.observe_tree_residency()
            summ = trace_summary(recs, stats)
            summ["pool"] = {
                "hits": stats.pool_hits, "misses": stats.pool_misses,
                "evictions": stats.pool_evictions,
                "reprefills": stats.pool_reprefills,
                "hit_rate": round(stats.pool_hit_rate, 3),
                "resident_end": len(sched.pool),
            }
            summ["prefix_tokens_resident_end"] = sched.pool.tokens_resident
            summ["resident_path_tokens_end"] = _resident_path_tokens(sched)
            runs[mode].append(summ)

    pair_ratios = sorted(f["mean_ttft_ms"] / t["mean_ttft_ms"]
                         for f, t in zip(runs["flat"], runs["tree"]))
    for mode in ("flat", "tree"):
        order = sorted(runs[mode], key=lambda s: s["mean_ttft_ms"])
        best = order[len(order) // 2]        # median replay
        best["runs_mean_ttft_ms"] = [s["mean_ttft_ms"]
                                     for s in runs[mode]]
        best["token_identical_vs_drain"] = True
        result[mode] = best
        log_fn(f"{mode:5s} mean TTFT {best['mean_ttft_ms']:8.1f}ms  "
               f"prefill tokens {best['prefill_tokens_total']:6d}  "
               f"resident prefix tokens "
               f"{best['prefix_tokens_resident_end']:5d}  "
               f"hit rate {best['pool']['hit_rate']:.0%}")
    result["paired_ttft_ratios_flat_over_tree"] = [
        round(r, 3) for r in pair_ratios]

    # the PAIRED median is the headline: adjacent replays share machine
    # conditions, so their ratio reflects the layout, not CPU drift
    result["ttft_ratio_flat_over_tree"] = round(
        pair_ratios[len(pair_ratios) // 2], 3)
    result["prefill_tokens_ratio_flat_over_tree"] = round(
        result["flat"]["prefill_tokens_total"]
        / max(1, result["tree"]["prefill_tokens_total"]), 3)
    result["resident_path_tokens_ratio_tree_over_flat"] = round(
        result["tree"]["resident_path_tokens_end"]
        / max(1, result["flat"]["resident_path_tokens_end"]), 3)

    # fig3 satellite witness: cut reuse vs re-clustering per sweep point
    sweep = [1, 2, 3, 4, 5, 8, 12]
    t0 = time.perf_counter()
    for k in sweep:
        plan_batch(subgraphs, emb, k)
    t_recluster = time.perf_counter() - t0
    t0 = time.perf_counter()
    dd2 = build_dendrogram(emb)
    for k in sweep:
        plan_batch(subgraphs, emb, k, dendrogram=dd2)
    t_reuse = time.perf_counter() - t0
    result["dendrogram_cut_reuse"] = {
        "sweep_points": sweep,
        "recluster_per_point_s": round(t_recluster, 4),
        "build_once_cut_each_s": round(t_reuse, 4),
        "speedup_x": round(t_recluster / max(t_reuse, 1e-9), 2),
    }
    log_fn(f"TTFT flat/tree x{result['ttft_ratio_flat_over_tree']:.2f}  "
           f"prefill tokens flat/tree "
           f"x{result['prefill_tokens_ratio_flat_over_tree']:.2f}  "
           f"resident path tokens tree/flat "
           f"x{result['resident_path_tokens_ratio_tree_over_flat']:.2f}  "
           f"sweep cut-reuse "
           f"x{result['dendrogram_cut_reuse']['speedup_x']:.1f}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--gap-s", type=float, default=0.04)
    ap.add_argument("--clusters", type=int, default=6)
    ap.add_argument("--tree-levels", type=int, default=3)
    ap.add_argument("--budget-frac", type=float, default=0.5)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_tree_serving.json"))
    args = ap.parse_args()
    result = run(num_queries=args.queries, max_batch=args.max_batch,
                 gap_s=args.gap_s, num_clusters=args.clusters,
                 tree_levels=args.tree_levels,
                 budget_frac=args.budget_frac)
    payload = {
        "benchmark": "tree_vs_flat_prefix_poisson",
        "config": "bench-tree (2L d64 GQA 4:2, f32, scene-graph RAG, "
                  f"top_k=8, block_size={BLOCK_SIZE})",
        "result": result,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
