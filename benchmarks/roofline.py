"""Roofline table formatter: reads results/dryrun.json -> EXPERIMENTS table.

Per (arch x shape), single-pod mesh: the three roofline terms, dominant
bottleneck, model-FLOPs ratio, and per-device memory; multi-pod rows show
the compile proof.

``--fused-json BENCH_fused_serving.json`` additionally prints the decode
bytes-moved table: modeled HBM bytes one decode step streams through
attention per serving arm (prefix KV at the arena itemsize + dequant
scales, suffix KV at compute dtype, and the multi-launch partial-tensor
write+read traffic the fused cascade kernel deletes) — decode is
memory-bound, so bytes/token IS its roofline term.
"""
from __future__ import annotations

import argparse
import json
import os


def fmt_table(results, multi_pod=False):
    rows = []
    head = (f"| {'arch':22s} | {'shape':11s} | {'compute_s':>9s} | "
            f"{'memory_s':>9s} | {'collect_s':>9s} | {'dominant':10s} | "
            f"{'useful%':>7s} | {'temp GiB':>8s} |")
    sep = "|" + "|".join("-" * (len(c) + 2) for c in
                         ["arch" + " " * 18, "shape" + " " * 6, "x" * 9,
                          "x" * 9, "x" * 9, "dominant" + "  ", "x" * 7,
                          "x" * 8]) + "|"
    rows.append(head)
    rows.append(sep)
    for r in results:
        if r.get("multi_pod") != multi_pod:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']:22s} | {r['shape']:11s} | "
                        f"{'—':>9s} | {'—':>9s} | {'—':>9s} | "
                        f"{'skip':10s} | {'—':>7s} | {'—':>8s} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']:22s} | {r['shape']:11s} | ERROR: "
                        f"{r['note'][:60]} |")
            continue
        temp = r["memory"].get("temp_size_in_bytes", 0) / 2 ** 30
        if "roofline" in r:
            rt = r["roofline"]
            rows.append(
                f"| {r['arch']:22s} | {r['shape']:11s} | "
                f"{rt['compute_s']:9.4f} | {rt['memory_s']:9.4f} | "
                f"{rt['collective_s']:9.4f} | {rt['dominant']:10s} | "
                f"{100*rt['useful_flops_ratio']:7.1f} | {temp:8.2f} |")
        else:
            rows.append(
                f"| {r['arch']:22s} | {r['shape']:11s} | "
                f"{'ok':>9s} | {'ok':>9s} | {'ok':>9s} | "
                f"{'compiled':10s} | {'—':>7s} | {temp:8.2f} |")
    return "\n".join(rows)


def fmt_decode_bytes_table(fused_result):
    """Decode bytes-moved rows from ``BENCH_fused_serving.json``'s
    ``modeled_decode_bytes_per_token`` sections (one row per arm)."""
    arms = [k for k, v in fused_result.items()
            if isinstance(v, dict) and "modeled_decode_bytes_per_token" in v]
    rows = [(f"| {'serving arm':17s} | {'prefix KV':>9s} | {'scales':>7s} | "
             f"{'suffix KV':>9s} | {'partials':>8s} | {'total/tok':>9s} |"),
            "|" + "|".join("-" * n for n in (19, 11, 9, 11, 10, 11)) + "|"]
    base = None
    for arm in arms:
        m = fused_result[arm]["modeled_decode_bytes_per_token"]
        base = base or m["total"]
        rows.append(
            f"| {arm:17s} | {m['prefix_kv']:9d} | {m['scales']:7d} | "
            f"{m['suffix_kv']:9d} | {m['partial_tensors']:8d} | "
            f"{m['total']:6d} x{base / max(1, m['total']):.2f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun.json")
    ap.add_argument("--fused-json", default="BENCH_fused_serving.json")
    args = ap.parse_args()
    if os.path.exists(args.json):
        with open(args.json) as f:
            results = json.load(f)
        print("## single-pod (16x16 = 256 chips) — roofline terms")
        print(fmt_table(results, multi_pod=False))
        print()
        print("## multi-pod (2x16x16 = 512 chips) — compile proof")
        print(fmt_table(results, multi_pod=True))
    if os.path.exists(args.fused_json):
        with open(args.fused_json) as f:
            fused = json.load(f)
        print()
        print("## decode HBM bytes moved per generated token (modeled)")
        print(fmt_decode_bytes_table(fused["result"]))


if __name__ == "__main__":
    main()
