"""Roofline table formatter: reads results/dryrun.json -> EXPERIMENTS table.

Per (arch x shape), single-pod mesh: the three roofline terms, dominant
bottleneck, model-FLOPs ratio, and per-device memory; multi-pod rows show
the compile proof.
"""
from __future__ import annotations

import argparse
import json


def fmt_table(results, multi_pod=False):
    rows = []
    head = (f"| {'arch':22s} | {'shape':11s} | {'compute_s':>9s} | "
            f"{'memory_s':>9s} | {'collect_s':>9s} | {'dominant':10s} | "
            f"{'useful%':>7s} | {'temp GiB':>8s} |")
    sep = "|" + "|".join("-" * (len(c) + 2) for c in
                         ["arch" + " " * 18, "shape" + " " * 6, "x" * 9,
                          "x" * 9, "x" * 9, "dominant" + "  ", "x" * 7,
                          "x" * 8]) + "|"
    rows.append(head)
    rows.append(sep)
    for r in results:
        if r.get("multi_pod") != multi_pod:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']:22s} | {r['shape']:11s} | "
                        f"{'—':>9s} | {'—':>9s} | {'—':>9s} | "
                        f"{'skip':10s} | {'—':>7s} | {'—':>8s} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']:22s} | {r['shape']:11s} | ERROR: "
                        f"{r['note'][:60]} |")
            continue
        temp = r["memory"].get("temp_size_in_bytes", 0) / 2 ** 30
        if "roofline" in r:
            rt = r["roofline"]
            rows.append(
                f"| {r['arch']:22s} | {r['shape']:11s} | "
                f"{rt['compute_s']:9.4f} | {rt['memory_s']:9.4f} | "
                f"{rt['collective_s']:9.4f} | {rt['dominant']:10s} | "
                f"{100*rt['useful_flops_ratio']:7.1f} | {temp:8.2f} |")
        else:
            rows.append(
                f"| {r['arch']:22s} | {r['shape']:11s} | "
                f"{'ok':>9s} | {'ok':>9s} | {'ok':>9s} | "
                f"{'compiled':10s} | {'—':>7s} | {temp:8.2f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun.json")
    args = ap.parse_args()
    with open(args.json) as f:
        results = json.load(f)
    print("## single-pod (16x16 = 256 chips) — roofline terms")
    print(fmt_table(results, multi_pod=False))
    print()
    print("## multi-pod (2x16x16 = 512 chips) — compile proof")
    print(fmt_table(results, multi_pod=True))


if __name__ == "__main__":
    main()
