"""Fused single-pass cascade kernel + int8 prefix blocks over the PR 5
tree trace (DESIGN.md §11).

Replays ONE Poisson arrival trace through the hierarchical prefix-tree
scheduler (the ``tree`` mode of ``benchmarks/tree_serving.py`` — same
substrate, same dendrogram, same leaf clusters) under three serving
arms at the SAME PrefixPool byte budget:

  * ``multilaunch_bf16`` — bf16 Pallas, ``fused=False``: per-segment
    partial-attention launches folded by the LSE merge (the PR 3-5
    path);
  * ``fused_bf16``       — bf16 Pallas, ``fused=True``: ONE kernel per
    layer walks prefix chain + suffix blocks carrying the (o, m, l)
    accumulator in-register — no partial tensors, no fold pass;
  * ``fused_int8``       — fused + ``quantize_prefix=True``: prefix
    blocks resident as int8 with per-(block, kv-head) f32 scales,
    dequantized in-register after DMA.  Half the bytes per resident
    path token, so the SAME budget keeps ~2x the path tokens cached
    and re-prefills less.

Token identity is ASSERTED per replay: each arm's continuous trace
must reproduce its own drain-serve oracle, and the fused bf16 arm must
be token-identical to the multi-launch arm (same math, one launch).
The int8 arm reports its greedy-token match rate against bf16 instead
(the quality gate; thresholds in EXPERIMENTS.md).

Reported per arm: mean/p95 TTFT, decode ms/token, pool counters,
resident path tokens at the shared budget, and MODELED decode
HBM bytes/token (KV bytes walked per generated token plus, for the
multi-launch arm, the partial-tensor write+read traffic the fusion
deletes) — the roofline term CPU-interpret timings cannot show.
``benchmarks/roofline.py --fused-json`` formats that model as a table.

NOTE: Pallas kernels run in interpret mode off-TPU, so the measured
millisecond numbers are emulation timings — comparable across arms
(same interpreter), not absolute.  The JSON marks this.

Writes ``BENCH_fused_serving.json`` at the repo root.  Runs on CPU.

    PYTHONPATH=src python benchmarks/fused_serving.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import tree_serving as TS  # noqa: E402  (substrate + scheduler helpers)

from repro.core.clustering import build_dendrogram  # noqa: E402
from repro.core.paged import KVBlockPool  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.rag.pipeline import GraphRAGPipeline  # noqa: E402
from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex  # noqa: E402
from repro.rag.text_encoder import TextEncoder  # noqa: E402
from repro.data.scenegraph import generate_scene_graph  # noqa: E402
from repro.data.tokenizer import Tokenizer  # noqa: E402
from repro.serving.bucketing import blocks_for  # noqa: E402
from repro.serving.engine import ServingEngine  # noqa: E402
from repro.serving.metrics import trace_summary  # noqa: E402

MAX_CACHE_LEN = 1024
BLOCK_SIZE = TS.BLOCK_SIZE

ARMS = (
    ("multilaunch_bf16", dict(fused=False, quantize_prefix=False)),
    ("fused_bf16", dict(fused=True, quantize_prefix=False)),
    ("fused_int8", dict(fused=True, quantize_prefix=True)),
)


def substrate(impl: str, dtype: str):
    graph, queries = generate_scene_graph()
    tok = Tokenizer.train([q.question + " " + q.answer for q in queries]
                          + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="bench-fused", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype=dtype,
                      attention_impl=impl)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(64))
    return graph, queries, tok, cfg, params, index


def make_pipe(tok, cfg, params, index, max_new_tokens, arena_blocks,
              *, fused, quantize_prefix):
    engine = ServingEngine(params, cfg, tok, max_cache_len=MAX_CACHE_LEN,
                           max_new_tokens=max_new_tokens,
                           block_size=BLOCK_SIZE,
                           arena_blocks=arena_blocks, fused=fused,
                           quantize_prefix=quantize_prefix)
    return GraphRAGPipeline(index=index,
                            retriever=GRetrieverRetriever(index, top_k=8),
                            engine=engine, tokenizer=tok,
                            use_soft_prompt=False)


def modeled_decode_bytes_per_token(cfg, *, path_tokens: int,
                                   suffix_tokens: int, fused: bool,
                                   quantized: bool) -> dict:
    """HBM bytes one decode step moves through attention, per layer
    summed over layers: the full path KV is streamed once (prefix at
    its ARENA itemsize + per-block scales when quantized; suffix at
    compute dtype), and the multi-launch path additionally writes then
    re-reads a per-segment (o, m, l) partial for the LSE fold — the
    traffic the fused kernel deletes."""
    hq, hkv, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    comp = 2 if cfg.dtype in ("bfloat16", "float16") else 4
    kv_item = 1 if quantized else comp
    nbp = blocks_for(path_tokens, BLOCK_SIZE)
    prefix = path_tokens * 2 * hkv * d * kv_item
    scales = (nbp * 2 * hkv * 4) if quantized else 0
    suffix = suffix_tokens * 2 * hkv * d * comp
    # two partial launches (prefix, suffix) each write o[Hq,D] + m/l
    # [Hq] in f32; the fold reads both back
    partials = 0 if fused else 2 * 2 * (hq * (d + 2)) * 4
    per_layer = prefix + scales + suffix + partials
    return {"prefix_kv": prefix * cfg.num_layers,
            "scales": scales * cfg.num_layers,
            "suffix_kv": suffix * cfg.num_layers,
            "partial_tensors": partials * cfg.num_layers,
            "total": per_layer * cfg.num_layers}


def run(num_queries: int = 12, max_batch: int = 4, gap_s: float = 0.04,
        num_clusters: int = 4, tree_levels: int = 2,
        max_new_tokens: int = 6, seed: int = 0, replays: int = 3,
        budget_frac: float = 0.5, impl: str = "pallas",
        dtype: str = "bfloat16", log_fn=print):
    graph, queries, tok, cfg, params, index = substrate(impl, dtype)
    items = queries[:num_queries]
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(gap_s, size=len(items)))

    # one retrieval + embedding + dendrogram pass shared by every arm
    probe = make_pipe(tok, cfg, params, index, max_new_tokens, 64,
                      fused=True, quantize_prefix=False)
    subgraphs = [probe.retriever.retrieve(it.question) for it in items]
    emb = probe.embed_for_clustering(subgraphs)
    dd = build_dendrogram(emb)

    # constrained budget: a fraction of what the TREE layout costs
    # fully resident at compute dtype — the bf16 arms must evict;
    # int8 halves the per-token price so the same bytes hold ~2x
    seed_kw = dict(tree=True, num_clusters=num_clusters,
                   tree_levels=tree_levels, budget=1 << 60, dendrogram=dd)
    _, plan = TS._seed_scheduler(probe, subgraphs, emb, **seed_kw)
    tree_lens = TS._chain_lens(probe, plan, tree=True)
    per_block = KVBlockPool.block_bytes_for(cfg, BLOCK_SIZE)
    tree_blocks = sum(blocks_for(p, BLOCK_SIZE) for p in tree_lens)
    budget = int(budget_frac * tree_blocks * per_block)
    arena_blocks = (tree_blocks + 2 * max_batch
                    * blocks_for(MAX_CACHE_LEN, BLOCK_SIZE) + 32)
    seed_kw["budget"] = budget

    mean_path = int(np.mean(tree_lens))
    result = {"trace": {
        "queries": num_queries, "poisson_gap_s": gap_s,
        "max_batch": max_batch, "num_clusters": num_clusters,
        "tree_levels": tree_levels, "budget_bytes": budget,
        "budget_frac_of_tree_resident": budget_frac,
        "tree_path_lens": tree_lens, "impl": impl, "dtype": dtype,
        "interpret_mode": jax.default_backend() != "tpu",
        "replays": replays}}

    # build + warm every arm up front, then interleave the timed
    # replays pairwise (tree_serving.py protocol: adjacent replays
    # share machine conditions, so cross-arm ratios reflect the
    # serving path, not CPU drift)
    pipes, oracles = {}, {}
    for name, kw in ARMS:
        pipe = make_pipe(tok, cfg, params, index, max_new_tokens,
                         arena_blocks, **kw)
        sched, _ = TS._seed_scheduler(pipe, subgraphs, emb, **seed_kw)
        pipe.warmup_stream(items, max_batch=max_batch, chunk=2,
                           prefix_lens=tree_lens)
        TS._warm_chains(pipe, subgraphs, emb, **seed_kw)
        oracle, _, _ = pipe.serve_stream(
            items, arrivals, mode="drain", max_batch=max_batch,
            pool_budget_bytes=budget, scheduler=sched)
        sched.pool.clear()
        warm, _ = TS._seed_scheduler(pipe, subgraphs, emb, **seed_kw)
        pipe.serve_stream(items, arrivals, mode="continuous",
                          max_batch=max_batch, chunk=2, scheduler=warm)
        pipes[name], oracles[name] = pipe, oracle

    # the fused bf16 arm must serve the very tokens multi-launch does —
    # one-launch fusion is a scheduling change, never a math change
    base_toks = [r.generated for r in oracles["multilaunch_bf16"]]
    assert [r.generated for r in oracles["fused_bf16"]] == base_toks, \
        "fused bf16 diverged from multi-launch tokens"
    q8_toks = [r.generated for r in oracles["fused_int8"]]
    # generation-level quality proxy for the trace (the per-token gate
    # lives in tests/test_fused_quant.py): fraction of queries whose
    # full greedy generation is unchanged under int8 prefixes
    int8_match = float(np.mean([a == b for a, b in
                                zip(base_toks, q8_toks)]))

    runs = {name: [] for name, _ in ARMS}
    for _ in range(replays):
        for name, kw in ARMS:
            pipe = pipes[name]
            sched, _ = TS._seed_scheduler(pipe, subgraphs, emb, **seed_kw)
            recs, _, sched = pipe.serve_stream(
                items, arrivals, mode="continuous", max_batch=max_batch,
                chunk=2, scheduler=sched)
            assert ([r.generated for r in recs]
                    == [r.generated for r in oracles[name]]), \
                f"{name}: continuous trace diverged from the drain oracle"
            stats = sched.pool.stats
            summ = trace_summary(recs, stats)
            dec_tok = sum(r.decode_steps for r in recs)
            summ["decode_ms_per_token"] = round(
                1e3 * sum(r.decode_s for r in recs) / max(1, dec_tok), 3)
            summ["pool"] = {
                "hits": stats.pool_hits, "misses": stats.pool_misses,
                "reprefills": stats.pool_reprefills,
                "hit_rate": round(stats.pool_hit_rate, 3)}
            summ["resident_path_tokens_end"] = \
                TS._resident_path_tokens(sched)
            runs[name].append(summ)

    for name, kw in ARMS:
        order = sorted(runs[name], key=lambda s: s["mean_ttft_ms"])
        med = order[len(order) // 2]
        med["runs_mean_ttft_ms"] = [s["mean_ttft_ms"]
                                    for s in runs[name]]
        med["token_identical_vs_drain"] = True
        med["modeled_decode_bytes_per_token"] = \
            modeled_decode_bytes_per_token(
                cfg, path_tokens=mean_path,
                suffix_tokens=32 + max_new_tokens,
                fused=kw["fused"], quantized=kw["quantize_prefix"])
        result[name] = med
        kib = med["modeled_decode_bytes_per_token"]["total"] / 1024
        log_fn(f"{name:17s} mean TTFT {med['mean_ttft_ms']:8.1f}ms  "
               f"decode {med['decode_ms_per_token']:7.2f}ms/tok  "
               f"resident path tokens "
               f"{med['resident_path_tokens_end']:5d}  "
               f"modeled {kib:.1f} KiB/tok")

    result["fused_bf16_token_identical_to_multilaunch"] = True
    result["int8_generation_match_rate"] = round(int8_match, 4)
    result["ttft_ratio_multilaunch_over_fused_int8"] = round(
        result["multilaunch_bf16"]["mean_ttft_ms"]
        / max(1e-9, result["fused_int8"]["mean_ttft_ms"]), 3)
    result["resident_path_tokens_ratio_int8_over_bf16"] = round(
        result["fused_int8"]["resident_path_tokens_end"]
        / max(1, result["fused_bf16"]["resident_path_tokens_end"]), 3)
    result["modeled_bytes_ratio_multilaunch_over_fused_int8"] = round(
        result["multilaunch_bf16"]["modeled_decode_bytes_per_token"]["total"]
        / max(1, result["fused_int8"]
              ["modeled_decode_bytes_per_token"]["total"]), 3)
    log_fn(f"int8 generation match {int8_match:.1%}  "
           f"TTFT multi/int8 "
           f"x{result['ttft_ratio_multilaunch_over_fused_int8']:.2f}  "
           f"resident int8/bf16 "
           f"x{result['resident_path_tokens_ratio_int8_over_bf16']:.2f}  "
           f"modeled bytes multi/int8 "
           f"x{result['modeled_bytes_ratio_multilaunch_over_fused_int8']:.2f}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--gap-s", type=float, default=0.04)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--tree-levels", type=int, default=2)
    ap.add_argument("--replays", type=int, default=3)
    ap.add_argument("--budget-frac", type=float, default=0.5)
    ap.add_argument("--impl", default="pallas",
                    choices=["pallas", "xla"])
    ap.add_argument("--dtype", default="bfloat16")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_fused_serving.json"))
    args = ap.parse_args()
    result = run(num_queries=args.queries, max_batch=args.max_batch,
                 gap_s=args.gap_s, num_clusters=args.clusters,
                 tree_levels=args.tree_levels, replays=args.replays,
                 budget_frac=args.budget_frac, impl=args.impl,
                 dtype=args.dtype)
    payload = {
        "benchmark": "fused_cascade_int8_prefix_tree_trace",
        "config": f"bench-fused (2L d64 GQA 4:2, {args.dtype}, "
                  f"{args.impl}, scene-graph RAG, top_k=8, "
                  f"block_size={BLOCK_SIZE})",
        "result": result,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
