"""Perf hillclimb driver: lower one (arch, shape) with a variant and
report the fitted roofline terms + memory.  Appends to results/perf.json.

  PYTHONPATH=src python benchmarks/perf_iter.py --arch tinyllama-1.1b \
      --shape train_4k --name seqshard --set seq_shard_boundary=true
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import json

from repro.launch import dryrun as D
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.configs import registry as R
from repro.models import attention as attn_mod


def parse_val(v):
    if v in ("true", "True"):
        return True
    if v in ("false", "False"):
        return False
    try:
        return int(v)
    except ValueError:
        return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--name", required=True)
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--out", default="results/perf.json")
    args = ap.parse_args()
    variant = {}
    for kv in args.set:
        k, v = kv.split("=")
        variant[k] = parse_val(v)

    cfg = D.build_cfg(args.arch, args.shape, D.SWA_OVERRIDE_WINDOW)
    mesh = make_production_mesh()

    # full-depth scan lowering for memory
    full = D.lower_one(cfg, args.shape, mesh, variant=variant)

    # two-point accounting
    attn_mod.UNROLL_CHUNKS = True
    a1 = D.lower_one(D._accounting_cfg(cfg, 1), args.shape, mesh,
                     variant=variant)
    a2 = D.lower_one(D._accounting_cfg(cfg, 2), args.shape, mesh,
                     variant=variant)
    attn_mod.UNROLL_CHUNKS = False
    from repro.models.model import group_period
    groups = cfg.num_layers / group_period(cfg)

    def fit(k1, k2=None):
        v1 = a1[k1] if k2 is None else a1[k1][k2]
        v2 = a2[k1] if k2 is None else a2[k1][k2]
        per = v2 - v1
        return max(0.0, (v1 - per) + per * groups)

    flops, bytes_acc, coll = fit("flops"), fit("bytes"), fit("coll", "total")
    rec = {
        "arch": args.arch, "shape": args.shape, "variant_name": args.name,
        "variant": variant,
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll / ICI_BW,
        "collectives": {op: fit("coll", op) for op in D._COLLECTIVES},
        "temp_gib": full["memory"].get("temp_size_in_bytes", 0) / 2 ** 30,
        "flops_per_chip": flops, "bytes_per_chip": bytes_acc,
        "collective_bytes_per_chip": coll,
    }
    print(json.dumps(rec, indent=1))
    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    results.append(rec)
    os.makedirs("results", exist_ok=True)
    json.dump(results, open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
