"""Paper Figure 3: impact of cluster number on ACC and TTFT.

The agglomeration is greedy and target-independent, so the sweep
computes the O(m^3) merge tree ONCE (``build_dendrogram`` over the
test items' retrieval embeddings) and every ``num_clusters`` point is
a cheap cut replay — re-clustering per point re-paid the full
agglomeration m-fold for identical merges."""
from __future__ import annotations

import argparse

from repro.rag.workbench import build_workbench, test_items


def run(num_queries: int = 100, clusters=(1, 2, 3, 4, 5, 10, 20, 30, 40, 50),
        dataset: str = "scene", train_steps: int = 300, log_fn=print):
    from repro.core.clustering import build_dendrogram
    wb = build_workbench(dataset, train_steps=train_steps, log_fn=log_fn)
    items = test_items(wb, num_queries)
    pipe = wb.pipeline("gretriever")
    pipe.engine.warmup()
    rb, sb = pipe.run_baseline(items)
    log_fn(f"baseline: ACC {sb.acc:.2f} TTFT {sb.ttft_ms:.2f}ms")
    out = [{"clusters": 0, "acc": sb.acc, "ttft_ms": sb.ttft_ms,
            "name": "baseline"}]
    # one dendrogram serves every sweep point (cuts nest; the labels
    # are byte-identical to per-point re-clustering)
    subgraphs, _ = pipe.retrieve_all(items)
    dd = build_dendrogram(pipe.embed_for_clustering(subgraphs))
    for c in clusters:
        if c > len(items):
            continue
        _, ss, plan, stats = pipe.run_subgcache(items, num_clusters=c,
                                                dendrogram=dd)
        log_fn(f"c={c:3d}: ACC {ss.acc:6.2f}  TTFT {ss.ttft_ms:8.2f}ms  "
               f"RT {ss.rt_ms:8.2f}ms  reuse x{plan.reuse_factor:.1f}  "
               f"savings x{stats.prefill_savings:.2f}")
        out.append({"clusters": c, "acc": ss.acc, "ttft_ms": ss.ttft_ms,
                    "rt_ms": ss.rt_ms, "reuse": plan.reuse_factor})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="scene")
    ap.add_argument("--num-queries", type=int, default=100)
    args = ap.parse_args()
    run(args.num_queries, dataset=args.dataset)


if __name__ == "__main__":
    main()
