"""Paper Table 3: sensitivity to the hierarchical-clustering linkage."""
from __future__ import annotations

import argparse

from repro.core.clustering import LINKAGES
from repro.rag.workbench import build_workbench, test_items
from repro.serving.metrics import speedup


def run(num_queries: int = 100, dataset: str = "scene",
        num_clusters: int = 2, train_steps: int = 300, log_fn=print):
    wb = build_workbench(dataset, train_steps=train_steps, log_fn=log_fn)
    items = test_items(wb, num_queries)
    pipe = wb.pipeline("gretriever")
    pipe.engine.warmup()
    rb, sb = pipe.run_baseline(items)
    log_fn(sb.row())
    out = []
    for link in LINKAGES:
        _, ss, _, stats = pipe.run_subgcache(items, num_clusters=num_clusters,
                                             linkage=link)
        sp = speedup(sb, ss)
        log_fn(f"{link:9s}: dACC {sp['acc_delta']:+6.2f}  "
               f"RT x{sp['rt_x']:5.2f}  TTFT x{sp['ttft_x']:5.2f}  "
               f"PFTT x{sp['pftt_x']:5.2f}")
        out.append({"linkage": link, **sp})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="scene")
    ap.add_argument("--num-queries", type=int, default=100)
    ap.add_argument("--clusters", type=int, default=2)
    args = ap.parse_args()
    run(args.num_queries, dataset=args.dataset, num_clusters=args.clusters)


if __name__ == "__main__":
    main()
