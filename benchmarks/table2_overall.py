"""Paper Table 2: overall ACC / RT / TTFT / PFTT, baseline vs +SubGCache.

Two datasets x two graph-RAG frameworks (G-Retriever, GRAG), with the
paper's cluster settings (Scene Graph: c=1; OAG: c=2).
"""
from __future__ import annotations

import argparse

from repro.rag.workbench import build_workbench, test_items
from repro.serving.metrics import speedup


def run(num_queries: int = 100, train_steps: int = 300, datasets=None,
        retrievers=("gretriever", "grag"), log_fn=print):
    rows = []
    datasets = datasets or ("scene", "oag")
    cluster_for = {"scene": 1, "oag": 2}
    for ds in datasets:
        wb = build_workbench(ds, train_steps=train_steps, log_fn=log_fn)
        items = test_items(wb, num_queries)
        for ret in retrievers:
            pipe = wb.pipeline(ret)
            pipe.engine.warmup()
            # pass 1 warms every (batch, suffix, capacity) bucket; pass 2
            # is the measured run (compile time excluded, as in the paper)
            pipe.run_baseline(items[: max(2, len(items) // 8)])
            pipe.run_subgcache(items, num_clusters=cluster_for[ds])
            rb, sb = pipe.run_baseline(items)
            rs, ss, plan, stats = pipe.run_subgcache(
                items, num_clusters=cluster_for[ds])
            sp = speedup(sb, ss)
            log_fn(f"--- {ds} / {ret} ---")
            log_fn(sb.row())
            log_fn(ss.row())
            log_fn(f"delta: ACC {sp['acc_delta']:+.2f}  RT x{sp['rt_x']:.2f}"
                   f"  TTFT x{sp['ttft_x']:.2f}  PFTT x{sp['pftt_x']:.2f}"
                   f"  (prefill token savings x{stats.prefill_savings:.2f})")
            rows.append({"dataset": ds, "retriever": ret,
                         "baseline": sb, "subgcache": ss, "speedup": sp,
                         "stats": stats})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-queries", type=int, default=100)
    ap.add_argument("--train-steps", type=int, default=300)
    args = ap.parse_args()
    run(args.num_queries, args.train_steps)


if __name__ == "__main__":
    main()
