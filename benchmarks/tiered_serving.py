"""Host-tier (HBM → host → recompute) vs recompute-on-miss under a
THRASH budget (DESIGN.md §12).

Replays one Poisson arrival trace through ``serve_stream`` twice at the
SAME prefix-pool HBM byte budget, sized so the pool CANNOT keep the
cluster working set resident (hit rate < 50% without a tier — the
regime where eviction policy stops mattering and miss COST is
everything):

  * ``recompute`` — the PR 4/5 path: an eviction discards the segment's
    blocks; the next hit on that cluster pays a full re-prefill;
  * ``tiered`` — the same pool with a host-memory tier attached
    (``host_tier_bytes``): evictions demote block bits to host numpy
    buffers, later hits promote them back through an async
    ``device_put`` that overlaps the batch's suffix prefill, and
    queued-but-not-admitted arrivals are speculatively prefetched so
    the transfer overlaps their queue wait.  Re-prefill remains only
    for double misses.

Token identity is ASSERTED three ways: each arm's continuous replays
must reproduce that arm's drain-serve oracle token for token, and the
two oracles must agree with each other — a promoted segment serves
bit-for-bit the blocks it was demoted from, so the tier changes WHERE
bytes live, never what is generated.

Reported per arm: mean/p95 TTFT, pool counters (the recompute arm's
hit rate is the thrash witness), and the full tier ledger
(``tier_report``): demotion/promotion counts and bytes, promotion rate
(fraction of would-be re-prefills absorbed), prefetch hit rate
(speculation precision), and residual promotion wait (what the async
transfer failed to overlap — ~0 is the overlap claim, measured).
Replays are interleaved pairwise so the headline ratio compares
adjacent runs under shared machine conditions, not CPU drift.

Writes ``BENCH_tiered_serving.json`` at the repo root.  Runs on CPU.

    PYTHONPATH=src python benchmarks/tiered_serving.py
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.core.clustering import build_dendrogram
from repro.core.paged import KVBlockPool
from repro.core.planner import plan_batch
from repro.core.prefix_pool import PrefixPool
from repro.data.scenegraph import generate_scene_graph
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.rag.pipeline import GraphRAGPipeline
from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
from repro.rag.text_encoder import TextEncoder
from repro.serving.bucketing import blocks_for
from repro.serving.engine import ServingEngine
from repro.serving.metrics import trace_summary
from repro.serving.scheduler import OnlineClusterAssigner, OnlineScheduler

MAX_CACHE_LEN = 1024
BLOCK_SIZE = 32


def substrate():
    graph, queries = generate_scene_graph()
    tok = Tokenizer.train([q.question + " " + q.answer for q in queries]
                          + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="bench-tier", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(64))
    return graph, queries, tok, cfg, params, index


def make_pipe(tok, cfg, params, index, max_new_tokens, arena_blocks):
    # top_k=8 retrieval: representative prefixes long enough that a
    # re-prefill costs real compute — the miss penalty the tier erases
    engine = ServingEngine(params, cfg, tok, max_cache_len=MAX_CACHE_LEN,
                           max_new_tokens=max_new_tokens,
                           block_size=BLOCK_SIZE,
                           arena_blocks=arena_blocks)
    return GraphRAGPipeline(index=index,
                            retriever=GRetrieverRetriever(index, top_k=8),
                            engine=engine, tokenizer=tok,
                            use_soft_prompt=False)


def _seed_scheduler(pipe, subgraphs, emb, *, num_clusters, budget,
                    dendrogram):
    """Both arms seed the SAME flat leaf clusters from the SAME
    dendrogram cut; only the miss path differs (tier vs recompute)."""
    plan = plan_batch(subgraphs, emb, num_clusters, dendrogram=dendrogram)
    assigner = OnlineClusterAssigner.from_plan(plan, emb)
    return OnlineScheduler(pipe.engine, assigner, PrefixPool(budget),
                           pipe._prefix_payload,
                           segment_tokens_fn=pipe._segment_payload), plan


def _prefix_lens(pipe, plan):
    tokf = pipe.tokenizer
    return sorted({len(tokf.encode(pipe.prefix_text(cp.representative),
                                   bos=True)) for cp in plan.clusters})


def _warm_clusters(pipe, subgraphs, emb, **seed_kw):
    """Compile pass: materialize every cluster prefix once (prefill
    signatures), then exercise one demote → promote round trip so the
    transfer/scatter jits are warm before anything is timed."""
    sched, _ = _seed_scheduler(pipe, subgraphs, emb, **seed_kw)
    from repro.core.tiered import HostTier
    sched.pool.attach_host_tier(HostTier(1 << 30))
    for cid in range(len(sched.assigner.clusters)):
        sched.ensure_chain(cid)
    sched.pool.budget_bytes = 1          # demote everything resident
    sched.pool._evict_to_budget()
    sched.pool.budget_bytes = seed_kw["budget"]
    for cid in range(len(sched.assigner.clusters)):
        sched.ensure_chain(cid)          # promotes (new jit signatures)
    sched.pool.tier.drain_pending()
    sched.pool.clear()


def run(num_queries: int = 24, max_batch: int = 4, gap_s: float = 0.04,
        num_clusters: int = 6, max_new_tokens: int = 8, seed: int = 0,
        budget_frac: float = 0.35, log_fn=print):
    graph, queries, tok, cfg, params, index = substrate()
    items = queries[:num_queries]
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(gap_s, size=len(items)))

    # one retrieval + embedding + dendrogram pass shared by both arms
    probe = make_pipe(tok, cfg, params, index, max_new_tokens, 64)
    subgraphs = [probe.retriever.retrieve(it.question) for it in items]
    emb = probe.embed_for_clustering(subgraphs)
    dd = build_dendrogram(emb)
    plan = plan_batch(subgraphs, emb, num_clusters, dendrogram=dd)
    lens = _prefix_lens(probe, plan)

    # THRASH budget: a fraction of what all cluster prefixes cost
    # resident at once, small enough that serving the trace without a
    # tier misses more than it hits — the no-tier hit rate is recorded
    # below as the witness
    per_block = KVBlockPool.block_bytes_for(cfg, BLOCK_SIZE)
    total_blocks = sum(blocks_for(p, BLOCK_SIZE) for p in lens)
    budget = int(budget_frac * total_blocks * per_block)
    host_budget = 2 * total_blocks * per_block   # host RAM is plentiful
    arena_blocks = (total_blocks + 2 * max_batch
                    * blocks_for(MAX_CACHE_LEN, BLOCK_SIZE) + 32)

    result = {"trace": {
        "queries": num_queries, "poisson_gap_s": gap_s,
        "max_batch": max_batch, "num_clusters": num_clusters,
        "budget_bytes": budget, "host_tier_bytes": host_budget,
        "budget_frac_of_resident": budget_frac, "prefix_lens": lens}}

    # build + warm BOTH arms up front, then INTERLEAVE the timed
    # replays pairwise (the tree_serving protocol: adjacent replays
    # share machine conditions, so their ratio reflects the miss path,
    # not CPU drift)
    pipes, oracles, tiers = {}, {}, {"recompute": None, "tiered": host_budget}
    seed_kw = dict(num_clusters=num_clusters, budget=budget, dendrogram=dd)
    for arm in ("recompute", "tiered"):
        pipe = make_pipe(tok, cfg, params, index, max_new_tokens,
                         arena_blocks)
        pipe.warmup_stream(items, max_batch=max_batch, chunk=2,
                           prefix_lens=lens)
        _warm_clusters(pipe, subgraphs, emb, **seed_kw)
        # token-identity oracle: the SAME cluster population served
        # drain-style must emit identical generations per query
        sched, _ = _seed_scheduler(pipe, subgraphs, emb, **seed_kw)
        oracle, _, _ = pipe.serve_stream(
            items, arrivals, mode="drain", max_batch=max_batch,
            scheduler=sched, host_tier_bytes=tiers[arm])
        sched.pool.clear()
        # one untimed continuous replay settles the drain pattern the
        # timed replays will see (EXPERIMENTS.md protocol)
        warm, _ = _seed_scheduler(pipe, subgraphs, emb, **seed_kw)
        pipe.serve_stream(items, arrivals, mode="continuous",
                          max_batch=max_batch, chunk=2, scheduler=warm,
                          host_tier_bytes=tiers[arm])
        pipes[arm], oracles[arm] = pipe, oracle

    # the tier changes where bytes live, never what is generated: the
    # two arms' oracles must agree token for token
    assert ([r.generated for r in oracles["recompute"]]
            == [r.generated for r in oracles["tiered"]]), \
        "tiered drain oracle diverged from the recompute oracle"

    runs = {"recompute": [], "tiered": []}
    for _ in range(5):
        for arm in ("recompute", "tiered"):
            pipe = pipes[arm]
            sched, _ = _seed_scheduler(pipe, subgraphs, emb, **seed_kw)
            recs, _, sched = pipe.serve_stream(
                items, arrivals, mode="continuous", max_batch=max_batch,
                chunk=2, scheduler=sched, host_tier_bytes=tiers[arm])
            assert ([r.generated for r in recs]
                    == [r.generated for r in oracles[arm]]), \
                f"{arm}: continuous trace diverged from the drain oracle"
            stats = sched.pool.stats
            summ = trace_summary(recs, stats)
            summ["pool"] = {
                "hits": stats.pool_hits, "misses": stats.pool_misses,
                "evictions": stats.pool_evictions,
                "reprefills": stats.pool_reprefills,
                "hit_rate": round(stats.pool_hit_rate, 3),
            }
            runs[arm].append(summ)

    pair_ratios = sorted(r["mean_ttft_ms"] / t["mean_ttft_ms"]
                         for r, t in zip(runs["recompute"], runs["tiered"]))
    for arm in ("recompute", "tiered"):
        order = sorted(runs[arm], key=lambda s: s["mean_ttft_ms"])
        best = order[len(order) // 2]        # median replay
        best["runs_mean_ttft_ms"] = [s["mean_ttft_ms"] for s in runs[arm]]
        best["token_identical_vs_drain"] = True
        result[arm] = best
        log_fn(f"{arm:9s} mean TTFT {best['mean_ttft_ms']:8.1f}ms  "
               f"prefill tokens {best['prefill_tokens_total']:6d}  "
               f"hit rate {best['pool']['hit_rate']:.0%}  "
               f"promotions {best['tier']['promotions']:3d}  "
               f"prefetch hit rate {best['tier']['prefetch_hit_rate']:.0%}")

    # thrash witness: without the tier the budget really is too small
    result["thrash_hit_rate_no_tier"] = result["recompute"]["pool"][
        "hit_rate"]
    # the PAIRED median is the headline
    result["ttft_ratio_recompute_over_tiered"] = round(
        pair_ratios[len(pair_ratios) // 2], 3)
    result["paired_ttft_ratios_recompute_over_tiered"] = [
        round(r, 3) for r in pair_ratios]
    result["prefill_tokens_ratio_recompute_over_tiered"] = round(
        result["recompute"]["prefill_tokens_total"]
        / max(1, result["tiered"]["prefill_tokens_total"]), 3)
    log_fn(f"TTFT recompute/tiered "
           f"x{result['ttft_ratio_recompute_over_tiered']:.2f}  "
           f"prefill tokens recompute/tiered "
           f"x{result['prefill_tokens_ratio_recompute_over_tiered']:.2f}  "
           f"no-tier hit rate "
           f"{result['thrash_hit_rate_no_tier']:.0%}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--gap-s", type=float, default=0.04)
    ap.add_argument("--clusters", type=int, default=6)
    ap.add_argument("--budget-frac", type=float, default=0.35)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_tiered_serving.json"))
    args = ap.parse_args()
    result = run(num_queries=args.queries, max_batch=args.max_batch,
                 gap_s=args.gap_s, num_clusters=args.clusters,
                 budget_frac=args.budget_frac)
    payload = {
        "benchmark": "tiered_prefix_cache_vs_recompute_poisson",
        "config": "bench-tier (2L d64 GQA 4:2, f32, scene-graph RAG, "
                  f"top_k=8, block_size={BLOCK_SIZE})",
        "result": result,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
