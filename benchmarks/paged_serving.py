"""Paged vs padded-dense pooled serving under the PR 2 Poisson trace.

Replays one Poisson arrival trace through ``serve_stream`` twice at the
SAME prefix-pool HBM byte budget:

  * ``paged`` — the block-pool backend (DESIGN.md §8): every resident
    prefix costs exactly ``ceil(P / block_size)`` blocks; suffix blocks
    are transient and freed per batch.
  * ``dense`` — ``paged=False``: every resident prefix costs its full
    power-of-two capacity bucket (the pad-to-capacity layout the PR 2
    stacked pool also paid), served through the dense cascade.

Reported per mode: TTFT (queue wait included), pool hit/miss/eviction
counters, and the HBM high-water mark (paged: peak blocks ×
block_bytes; dense: the capacity-bucket bytes of the resident states).
A separate **capacity model** packs the trace's actual representative
prefixes into the shared budget under both layouts — the headline
``resident_ratio`` is how many more cacheable prefixes the paged layout
keeps alive at equal bytes (acceptance: >= 1.3x, i.e. the
pad-to-capacity waste the padded pool baked into every entry).

Writes ``BENCH_paged_serving.json`` at the repo root.  Runs on CPU.

    PYTHONPATH=src python benchmarks/paged_serving.py
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.data.scenegraph import generate_scene_graph
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.core.paged import KVBlockPool
from repro.rag.pipeline import GraphRAGPipeline
from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
from repro.rag.text_encoder import TextEncoder
from repro.serving.bucketing import blocks_for, bucket_capacity
from repro.serving.engine import ServingEngine

MAX_CACHE_LEN = 512
BLOCK_SIZE = 64


def substrate():
    graph, queries = generate_scene_graph()
    tok = Tokenizer.train([q.question + " " + q.answer for q in queries]
                          + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="bench-paged", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(64))
    return graph, queries, tok, cfg, params, index


def make_pipe(tok, cfg, params, index, max_new_tokens, *, paged,
              arena_blocks=None):
    engine = ServingEngine(params, cfg, tok, max_cache_len=MAX_CACHE_LEN,
                           max_new_tokens=max_new_tokens, paged=paged,
                           block_size=BLOCK_SIZE, arena_blocks=arena_blocks)
    return GraphRAGPipeline(index=index,
                            retriever=GRetrieverRetriever(index),
                            engine=engine, tokenizer=tok,
                            use_soft_prompt=False)


def _summ(records):
    ttft = np.array([r.ttft for r in records])
    return {
        "mean_ttft_ms": round(1e3 * float(np.mean(ttft)), 3),
        "p50_ttft_ms": round(1e3 * float(np.median(ttft)), 3),
        "p90_ttft_ms": round(1e3 * float(np.percentile(ttft, 90)), 3),
        "mean_queue_wait_ms": round(
            1e3 * float(np.mean([r.queue_wait_s for r in records])), 3),
    }


def _slot_bytes(cfg) -> int:
    """HBM bytes one KV slot costs across all attention layers."""
    return KVBlockPool.block_bytes_for(cfg, 1)


def capacity_model(cfg, rep_lens, budget_bytes):
    """Pack the trace's representative prefixes (token lengths
    ``rep_lens``, arrival order) into ``budget_bytes`` under both
    layouts; returns resident counts + per-layout slot totals."""
    per_slot = _slot_bytes(cfg)
    dense_resident = paged_resident = 0
    dense_bytes = paged_bytes = 0
    for p in rep_lens:
        d = bucket_capacity(p, 128, MAX_CACHE_LEN, "prefix") * per_slot
        g = blocks_for(p, BLOCK_SIZE) * BLOCK_SIZE * per_slot
        if dense_bytes + d <= budget_bytes:
            dense_bytes += d
            dense_resident += 1
        if paged_bytes + g <= budget_bytes:
            paged_bytes += g
            paged_resident += 1
    return {
        "budget_bytes": budget_bytes,
        "prefixes": len(rep_lens),
        "prefix_token_lens": rep_lens,
        "resident_padded_dense": dense_resident,
        "resident_paged": paged_resident,
        "bytes_padded_dense": dense_bytes,
        "bytes_paged": paged_bytes,
        "resident_ratio": round(paged_resident / max(1, dense_resident), 3),
    }


def run(num_queries: int = 16, max_batch: int = 4, gap_s: float = 0.05,
        threshold: float = 0.25, max_new_tokens: int = 8, seed: int = 0,
        budget_prefixes: int = 2, log_fn=print):
    graph, queries, tok, cfg, params, index = substrate()
    items = queries[:num_queries]
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(gap_s, size=len(items)))

    # budget: enough padded-dense slots for ``budget_prefixes`` typical
    # representatives — tight enough that layout efficiency decides how
    # many clusters stay resident
    probe = make_pipe(tok, cfg, params, index, max_new_tokens, paged=False)
    sgs = {}
    for it in items:
        sg = probe.retriever.retrieve(it.question)
        sgs[min(sg.nodes)] = len(tok.encode(probe.prefix_text(sg), bos=True))
    rep_lens = list(sgs.values())
    typical = int(np.median(rep_lens))
    per_slot = _slot_bytes(cfg)
    budget = budget_prefixes * bucket_capacity(
        typical, 128, MAX_CACHE_LEN, "prefix") * per_slot
    # the paged arena must hold the budgeted prefixes plus transient
    # suffix blocks for a full micro-batch, plus warmup's worst case
    # (num_prefixes states of the widest representative at once) —
    # residency is enforced by the POOL byte budget, not arena size,
    # so the headroom does not distort the comparison
    arena_blocks = (budget // KVBlockPool.block_bytes_for(cfg, BLOCK_SIZE)
                    + 4 * max_batch
                    + 4 * blocks_for(max(rep_lens), BLOCK_SIZE))

    result = {"trace": {"queries": num_queries, "poisson_gap_s": gap_s,
                        "max_batch": max_batch,
                        "spawn_threshold": threshold,
                        "budget_bytes": budget}}
    for mode, paged in (("paged", True), ("dense", False)):
        pipe = make_pipe(tok, cfg, params, index, max_new_tokens,
                         paged=paged,
                         arena_blocks=arena_blocks if paged else None)
        bs = tuple(sorted({1, 2, max_batch}))
        # warm every page-width bucket the trace's representatives span
        # (each width is its own compiled shape on the paged backend),
        # then replay the identical trace twice untimed: micro-batch
        # composition depends on measured service times, so the second
        # replay settles the drain pattern the timed replay will see
        pipe.engine.warmup_pooled(rep_lens, batches=bs, num_prefixes=bs)
        for _ in range(2):
            pipe.serve_stream(items, arrivals, mode="drain",
                              max_batch=max_batch, threshold=threshold,
                              pool_budget_bytes=budget)
        # best-of-3 timed replays (EXPERIMENTS.md protocol): the
        # discrete-event clock feeds measured service times back into
        # batch composition, so single replays are noisy on CPU.  Pool
        # counters are captured per run, BEFORE the next run's fresh
        # scheduler clears the previous pool's block references.
        runs = []
        for _ in range(3):
            recs, _, sched = pipe.serve_stream(
                items, arrivals, mode="drain", max_batch=max_batch,
                threshold=threshold, pool_budget_bytes=budget)
            stats = sched.pool.stats
            summ = _summ(recs)
            summ["pool"] = {
                "hits": stats.pool_hits, "misses": stats.pool_misses,
                "evictions": stats.pool_evictions,
                "reprefills": stats.pool_reprefills,
                "hit_rate": round(stats.pool_hit_rate, 3),
                "clusters": len(sched.assigner.clusters),
                "resident_end": len(sched.pool),
            }
            if paged:
                bp = pipe.engine.block_pool
                # a TRUE high-water mark: peak blocks in use, including
                # every in-flight suffix block (CacheStats.blocks_peak)
                summ["hbm_high_water_bytes"] = (stats.blocks_peak
                                                * bp.block_bytes)
                summ["block_fragmentation"] = round(
                    stats.block_fragmentation, 4)
                summ["blocks_peak"] = stats.blocks_peak
            else:
                from repro.core.prefix_pool import state_bytes
                # NOT comparable to the paged high-water mark:
                # end-of-run POOL residency only (per-batch dense
                # suffix caches and broadcast scratch are untracked)
                summ["pool_resident_bytes_end"] = sum(
                    state_bytes(e.state) for e in
                    (sched.pool.entry(k) for k in sched.pool.keys))
            runs.append(summ)
        summ = min(runs, key=lambda s: s["mean_ttft_ms"])
        summ["runs_mean_ttft_ms"] = [s["mean_ttft_ms"] for s in runs]
        hbm = summ.get("hbm_high_water_bytes",
                       summ.get("pool_resident_bytes_end", 0))
        result[mode] = summ
        log_fn(f"{mode:6s} mean TTFT {summ['mean_ttft_ms']:9.1f}ms  "
               f"hit rate {summ['pool']['hit_rate']:.0%}  "
               f"resident {summ['pool']['resident_end']}  "
               f"{'hbm high-water' if paged else 'pool bytes end'} "
               f"{hbm/2**20:.2f}MiB")

    result["capacity_model"] = capacity_model(cfg, rep_lens, budget)
    result["resident_ratio_at_equal_budget"] = \
        result["capacity_model"]["resident_ratio"]
    result["ttft_ratio_dense_over_paged"] = round(
        result["dense"]["mean_ttft_ms"] / result["paged"]["mean_ttft_ms"], 3)
    log_fn(f"resident prefixes at equal budget: paged "
           f"{result['capacity_model']['resident_paged']} vs padded "
           f"{result['capacity_model']['resident_padded_dense']} "
           f"(x{result['resident_ratio_at_equal_budget']:.2f}); "
           f"TTFT dense/paged x{result['ttft_ratio_dense_over_paged']:.2f}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--gap-s", type=float, default=0.05)
    ap.add_argument("--threshold", type=float, default=0.25)
    ap.add_argument("--budget-prefixes", type=int, default=2)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_paged_serving.json"))
    args = ap.parse_args()
    result = run(num_queries=args.queries, max_batch=args.max_batch,
                 gap_s=args.gap_s, threshold=args.threshold,
                 budget_prefixes=args.budget_prefixes)
    payload = {
        "benchmark": "paged_vs_padded_pool_poisson",
        "config": "bench-paged (2L d64 GQA 4:2, f32, scene-graph RAG, "
                  f"block_size={BLOCK_SIZE})",
        "result": result,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
