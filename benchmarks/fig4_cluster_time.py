"""Paper Figure 4: cluster processing time vs LLM response time by
cluster count — validates the paper's 'minimal processing overhead' claim."""
from __future__ import annotations

import argparse

from repro.rag.workbench import build_workbench, test_items


def run(num_queries: int = 100, clusters=(1, 2, 5, 10, 20, 50),
        dataset: str = "scene", train_steps: int = 300, log_fn=print):
    wb = build_workbench(dataset, train_steps=train_steps, log_fn=log_fn)
    items = test_items(wb, num_queries)
    pipe = wb.pipeline("gretriever")
    pipe.engine.warmup()
    out = []
    for c in clusters:
        if c > len(items):
            continue
        recs, ss, plan, _ = pipe.run_subgcache(items, num_clusters=c)
        llm_ms = ss.rt_ms * len(items)              # total LLM time
        cl_ms = ss.cluster_processing_ms            # total cluster time
        frac = cl_ms / max(cl_ms + llm_ms, 1e-9) * 100
        log_fn(f"c={c:3d}: cluster {cl_ms:8.2f}ms  llm {llm_ms:10.2f}ms  "
               f"overhead {frac:5.2f}%")
        out.append({"clusters": c, "cluster_ms": cl_ms, "llm_ms": llm_ms,
                    "overhead_pct": frac})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="scene")
    ap.add_argument("--num-queries", type=int, default=100)
    args = ap.parse_args()
    run(args.num_queries, dataset=args.dataset)


if __name__ == "__main__":
    main()
