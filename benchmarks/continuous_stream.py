"""Continuous in-flight batching vs the drain-serve loop (DESIGN.md §9).

Replays ONE Poisson arrival trace through ``serve_stream`` twice on the
same engine substrate:

  * ``drain``      — the PR 3 online path: the queue is drained into
                     micro-batches and each batch decodes to FULL
                     completion (every row burns the whole
                     ``max_new_tokens`` budget; a request arriving one
                     tick late waits out the entire batch).
  * ``continuous`` — the persistent in-flight batch: fixed-size decode
                     chunks, EOS retirement frees suffix blocks
                     mid-flight, arrivals admit into free slots between
                     chunks.

Both modes produce token-identical outputs (asserted per replay — the
continuous loop reschedules work, never changes math); the comparison
is pure scheduling: mean/p95 TTFT and queue wait on the same trace.
Shapes are warmed via ``warmup_stream`` (the (admission-batch,
page-width) grid) plus two untimed replays per mode (drain-pattern
settling out of the timed region), then timed best-of-3
(EXPERIMENTS.md protocol — the discrete-event clock feeds measured
service times back into admission, so single replays are noisy on CPU).
Writes ``BENCH_continuous_stream.json`` at the repo root.  Runs on CPU.

    PYTHONPATH=src python benchmarks/continuous_stream.py
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.data.scenegraph import generate_scene_graph
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.rag.pipeline import GraphRAGPipeline
from repro.rag.retriever import GRetrieverRetriever, RetrieverIndex
from repro.rag.text_encoder import TextEncoder
from repro.serving.engine import ServingEngine
from repro.serving.metrics import trace_summary


def bench_pipeline(max_new_tokens: int):
    """(GraphRAGPipeline, queries) on random weights — timing is
    backbone-agnostic; accuracy is not measured here."""
    graph, queries = generate_scene_graph()
    tok = Tokenizer.train([q.question + " " + q.answer for q in queries]
                          + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="bench-cont", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    index = RetrieverIndex.build(graph, TextEncoder(64))
    engine = ServingEngine(params, cfg, tok, max_cache_len=512,
                           max_new_tokens=max_new_tokens)
    pipe = GraphRAGPipeline(index=index, retriever=GRetrieverRetriever(index),
                            engine=engine, tokenizer=tok,
                            use_soft_prompt=False)
    return pipe, queries


def run(num_queries: int = 24, max_batch: int = 4, gap_s: float = 0.03,
        threshold: float = 0.25, max_new_tokens: int = 32, chunk: int = 8,
        seed: int = 0, log_fn=print):
    pipe, queries = bench_pipeline(max_new_tokens)
    items = queries[:num_queries]
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(gap_s, size=len(items)))

    # tokenize-once trace geometry: the continuous loop's suffix
    # capacity is a compiled shape sized to the longest suffix
    max_sfx = max(len(pipe.tokenizer.encode(pipe.suffix_text(it.question)))
                  for it in items)

    def replay(mode):
        recs, _, sched = pipe.serve_stream(
            items, arrivals, mode=mode, max_batch=max_batch, chunk=chunk,
            threshold=threshold, pool_budget_bytes=1 << 26,
            max_suffix_len=max_sfx)
        return recs, sched

    # ---- warmup: compiles + drain-pattern settling, untimed ----------
    rep_lens = sorted({len(pipe.tokenizer.encode(
        pipe.prefix_text(pipe.retriever.retrieve(it.question)), bos=True))
        for it in items})
    bs = tuple(sorted({1, 2, max_batch}))
    pipe.engine.warmup_pooled(rep_lens, batches=bs, num_prefixes=bs)
    pipe.warmup_stream(items, max_batch=max_batch, chunk=chunk,
                       prefix_lens=rep_lens, max_suffix_len=max_sfx)
    for mode in ("drain", "continuous"):
        for _ in range(2):
            replay(mode)

    # ---- timed: best-of-3 per mode, token identity asserted ----------
    result, tokens = {}, {}
    for mode in ("drain", "continuous"):
        best, best_recs, best_sched = None, None, None
        for _ in range(3):
            recs, sched = replay(mode)
            s = trace_summary(recs)
            if best is None or s["mean_ttft_ms"] < best["mean_ttft_ms"]:
                # keep the scheduler WITH its replay: hit/miss counts
                # vary across replays and must match the reported run
                best, best_recs, best_sched = s, recs, sched
        tokens[mode] = [r.generated for r in best_recs]
        best["pool_hit_rate"] = round(
            best_sched.pool.stats.pool_hit_rate, 3)
        result[mode] = best
    token_identical = tokens["drain"] == tokens["continuous"]
    assert token_identical, \
        "continuous serving must be token-identical to the drain oracle"
    result["token_identical"] = token_identical
    result["speedup_mean_ttft"] = round(
        result["drain"]["mean_ttft_ms"]
        / result["continuous"]["mean_ttft_ms"], 3)
    result["speedup_p95_ttft"] = round(
        result["drain"]["p95_ttft_ms"]
        / result["continuous"]["p95_ttft_ms"], 3)
    result["speedup_p95_queue_wait"] = round(
        result["drain"]["p95_queue_wait_ms"]
        / max(result["continuous"]["p95_queue_wait_ms"], 1e-3), 3)
    for mode in ("drain", "continuous"):
        s = result[mode]
        log_fn(f"{mode:10s} mean TTFT {s['mean_ttft_ms']:8.1f}ms  "
               f"p95 {s['p95_ttft_ms']:8.1f}ms  "
               f"wait p95 {s['p95_queue_wait_ms']:8.1f}ms  "
               f"decode steps {s['mean_decode_steps']:5.1f}")
    log_fn(f"continuous vs drain: mean TTFT x{result['speedup_mean_ttft']}"
           f"  p95 queue wait x{result['speedup_p95_queue_wait']}"
           f"  (token-identical: {token_identical})")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--gap-s", type=float, default=0.03)
    ap.add_argument("--threshold", type=float, default=0.25)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_continuous_stream.json"))
    args = ap.parse_args()
    result = run(num_queries=args.queries, max_batch=args.max_batch,
                 gap_s=args.gap_s, threshold=args.threshold,
                 max_new_tokens=args.max_new_tokens, chunk=args.chunk)
    payload = {
        "benchmark": "continuous_vs_drain_stream_poisson",
        "config": "bench-cont (2L d64 GQA 4:2, f32, scene-graph RAG)",
        "trace": {"queries": args.queries, "poisson_gap_s": args.gap_s,
                  "max_batch": args.max_batch,
                  "spawn_threshold": args.threshold,
                  "max_new_tokens": args.max_new_tokens,
                  "decode_chunk": args.chunk},
        "result": result,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
