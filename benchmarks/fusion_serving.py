"""Cross-cluster segment fusion vs chain-only prefix reuse
(DESIGN.md §14) on a trace built so chain reuse MISSES but segment
reuse HITS.

The workload is K clusters in ``GROUP_SIZE``-cluster groups; every
cluster in a group embeds the SAME long context segment behind
per-cluster roots of *different lengths*, and the groups' shared
segments have *different lengths* (``CTX_LENS``):

    cluster i prompt = root_i (R_i tokens, all R_i distinct)
                       + ctx_g (C_g tokens, shared within group g)
                       + delta_i (D tokens, unique)

Chain (prefix-tree) reuse only shares literal token *prefixes*: the
roots differ, so every cluster prefills its own copy of its ``ctx_g``
— the tree layout cannot see the overlap.  The composition path caches
each ``ctx_g`` once (under the group's first cluster — the donor),
finds it through the content-addressed segment registry, and SPLICES
it into every other group member's prompt at a different base position
— canonical-K storage plus read-time RoPE delta rotation make the
cached blocks valid at any offset.  Only the roots, deltas, and a
recompute window/mask of ``ctx_g`` are prefilled fresh.

The MIXED segment lengths are what separates the two recompute dials:
``recompute_frac`` spends proportionally to segment length (f * C_g
tokens per splice) even though splice staleness concentrates in a
roughly length-INDEPENDENT leading region, so one frac over-repairs
the long segments and under-repairs the short ones at once; a drift
budget spends the same absolute tokens per splice exactly where the
scores put them.

Arms (all on one engine, f32/XLA, paged + fused path):

  * ``dense``   — no reuse: every query prefills its full prompt;
  * ``chain``   — the DESIGN.md §10 chain path (``compose_frac=None``);
  * ``compose@f`` — ``try_compose`` armed at ``recompute_frac = f``
    for f in ``FRACS`` (1.0 degenerates to dense recompute of every
    spliced token and must be token-identical to the chain arm);
  * ``drift@B`` — drift-scored selective recompute (DESIGN.md §15) at
    ``recompute_budget = B`` tokens per spliced segment: the layer-0
    attention-mass x staleness probe picks WHICH blocks of the splice
    to re-prefill instead of always the leading window (``B = MAX_CTX``
    selects every block and must be token-identical to the chain arm).

Reported per arm: prefix prefill tokens (EMPIRICAL, from the serving
stats — asserted equal to the analytic count from the plan semantics),
mean/p95 TTFT share, wall time, and the greedy-match rate against the
dense arm (mean leading-token agreement of the generated
continuations).

Gates, asserted on every timed replay:

  1. ``chain`` serves token-identically to ``dense`` (f32/XLA);
  2. ``compose@1.0`` AND ``drift@MAX_CTX`` serve token-identically to
     ``chain``;
  3. some PARTIAL reuse arm (fixed frac or drift budget) cuts prefix
     prefill tokens >= 2.0x vs the chain arm while clearing a >= 0.90
     greedy-match rate — the headline: fusion reuse wins where chain
     reuse cannot, at near-dense output.  On this mixed-length trace
     every FIXED frac misses one axis (one frac over-repairs the long
     splices and under-repairs the short ones at once), so the winners
     here are drift arms;
  4. some partial drift arm BEATS the fixed-window frontier: >= 1.3x
     the best fixed arm's prefill cut at >= its greedy-match rate (or
     an equal cut at a strictly higher match) — selective recompute
     spends the same budget where the attention drift actually is;
  5. admission (one-shot section): on a repeat-heavy replay of the
     same trace the "cost" policy declines >= 1 engage and finishes
     with FEWER total prefill tokens than greedy engagement;
  6. identity (one-shot section): the compose@1.0 and drift@MAX_CTX
     identities re-asserted against the chain arm on a bf16/Pallas
     engine (interpret mode, reduced trace).

Writes ``BENCH_fusion_serving.json`` at the repo root.  Runs on CPU.

    PYTHONPATH=src python benchmarks/fusion_serving.py
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core.cache import recompute_window
from repro.core.planner import ChainSpec
from repro.core.prefix_pool import PrefixPool
from repro.data.scenegraph import generate_scene_graph
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.engine import Request, ServingEngine
from repro.serving.scheduler import (Assignment, OnlineCluster,
                                     OnlineScheduler)

MAX_CACHE_LEN = 1024
BLOCK_SIZE = 32
NUM_CLUSTERS = 12           # K: one query per cluster per replay
GROUP_SIZE = 4              # clusters per ctx group; first = donor
CTX_LENS = [64, 256, 512]   # C_g: shared-segment length per group —
                            # the length SPREAD is what separates a
                            # relative frac from an absolute budget
MAX_CTX = max(CTX_LENS)
DELTA_LEN = 8               # D: unique per-cluster tail segment
SUFFIX_LEN = 10             # query suffix appended after the prefix
ROOT_LENS = [3 + i for i in range(NUM_CLUSTERS)]   # all distinct ->
                                                   # every splice is
                                                   # re-based
FRACS = [0.25, 0.5, 1.0]    # recompute_frac points for the compose arm
BUDGETS = [32, 64, 128, MAX_CTX]   # drift recompute budgets (tokens
                                   # per splice); MAX_CTX masks every
                                   # block -> the chain-identity anchor
GATE_MIN_PREFILL_CUT = 2.0  # vs the chain arm, at some partial frac
GATE_MIN_MATCH = 0.90       # greedy-match rate vs dense, same frac
GATE_DRIFT_CUT_RATIO = 1.3  # drift cut over the BEST fixed partial
                            # arm's cut, at >= its match rate
MAX_NEW_TOKENS = 12
REPLAYS = 3


# ----------------------------------------------------------------------
def substrate():
    """Scene-graph text -> tokenizer -> tiny dense model + the segment
    library (roots / shared ctx / deltas / suffixes) cut from the
    corpus token stream at non-overlapping offsets."""
    graph, queries = generate_scene_graph()
    tok = Tokenizer.train([q.question + " " + q.answer for q in queries]
                          + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="bench-fusion", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    stream = tok.encode(" ".join(graph.node_text))
    need = sum(CTX_LENS) + sum(ROOT_LENS) + NUM_CLUSTERS * (DELTA_LEN
                                                            + SUFFIX_LEN)
    while len(stream) < need:
        stream = stream + stream
    off = 0

    def take(n):
        nonlocal off
        piece, off = stream[off: off + n], off + n
        return piece

    from repro.data.tokenizer import BOS
    ctxs = [take(c) for c in CTX_LENS]
    roots = [[BOS] + take(r - 1) for r in ROOT_LENS]
    deltas = [take(DELTA_LEN) for _ in range(NUM_CLUSTERS)]
    suffixes = [take(SUFFIX_LEN) for _ in range(NUM_CLUSTERS)]
    return tok, cfg, params, ctxs, roots, deltas, suffixes


def make_engine(tok, cfg, params):
    return ServingEngine(params, cfg, tok, max_cache_len=MAX_CACHE_LEN,
                         max_new_tokens=MAX_NEW_TOKENS,
                         block_size=BLOCK_SIZE, arena_blocks=256)


def make_scheduler(eng, chains):
    """An ``OnlineScheduler`` whose cluster ``i`` carries the stub
    chain ``chains[i]`` (a list of raw token-id segments) — content in,
    content out, so the trace controls the registry keys exactly."""
    class _Assigner:
        clusters: list = []

        def representative(self, cid):
            return self.clusters[cid].representative

    asg = _Assigner()
    asg.clusters = [
        OnlineCluster(cluster_id=i, centroid=np.zeros(4, np.float32),
                      representative=None,
                      chain=ChainSpec(
                          keys=[f"c{i}s{j}" for j in range(len(segs))],
                          contents=[list(s) for s in segs]))
        for i, segs in enumerate(chains)]
    return OnlineScheduler(eng, asg, PrefixPool(1 << 28),
                           prefix_tokens_fn=lambda rep: list(rep),
                           segment_tokens_fn=lambda c, b: list(c))


# ----------------------------------------------------------------------
def run_dense(eng, prompts, suffixes):
    """No-reuse baseline: full prompt prefilled per query."""
    rows, t0 = [], time.perf_counter()
    for prompt, sfx in zip(prompts, suffixes):
        outs, t = eng.serve([Request(prompt + sfx)], _record=False)
        steps = max(1, len(outs[0]))
        rows.append(dict(tokens=outs[0],
                         computed=len(prompt) + len(sfx),
                         ttft=t["prefill_share"][0]
                         + t["decode_share"][0] / steps))
    return rows, time.perf_counter() - t0


def run_scheduled(eng, chains, suffixes, frac, budget=None,
                  admission="greedy"):
    """Chain arm (``frac is None``), compose arm, or drift arm
    (``budget`` set, frac = 0.0): one query per cluster through
    ``serve_batch``.  Computed prefix tokens are taken from the serving
    stats — ``prefix_tokens_computed`` covers chain prefills, and a
    composed row computes ``prefix_len`` minus the tokens it spliced
    from cache (gap + drift-masked / boundary-window tokens)."""
    sched = make_scheduler(eng, chains)
    sched.compose_frac = frac
    sched.compose_budget = budget
    sched.compose_admission = admission
    stats = eng.cache_mgr.stats
    rows, seen, t0 = [], set(), time.perf_counter()
    for cid, sfx in enumerate(suffixes):
        p0 = stats.prefix_tokens_computed
        s0 = stats.compose_spliced_tokens
        c0 = stats.compose_requests
        out = sched.serve_batch(
            [np.zeros(4, np.float32)], [None], [sfx],
            assignments=[Assignment(cluster_id=cid,
                                    is_new=cid not in seen,
                                    distance=0.0)])
        seen.add(cid)
        q = out[0]
        composed = stats.compose_requests > c0
        computed = (stats.prefix_tokens_computed - p0) + len(sfx)
        if composed:
            computed += q.prefix_len - (stats.compose_spliced_tokens - s0)
        steps = max(1, len(q.tokens))
        rows.append(dict(tokens=q.tokens, computed=computed,
                         composed=composed,
                         ttft=q.prefix_share_s + q.prefill_s
                         + q.decode_s / steps))
    wall = time.perf_counter() - t0
    sched.pool.clear()
    assert eng.block_pool.blocks_in_use == 0
    return rows, wall


def expected_tokens(roots, ctx_list, deltas, suffixes, frac):
    """Analytic computed-token count the empirical stats must match."""
    sfx = sum(len(s) for s in suffixes)
    if frac == "dense" or frac is None:   # dense, or chain cold-prefill
        return sum(len(r) + len(c) + len(d)
                   for r, c, d in zip(roots, ctx_list, deltas)) + sfx
    total = sfx
    for i, (r, c, d) in enumerate(zip(roots, ctx_list, deltas)):
        if i % GROUP_SIZE == 0:
            # group donor: cold-chains its full prompt, seeding the
            # registry with ctx_g for the rest of the group
            total += len(r) + len(c) + len(d)
            continue
        if isinstance(frac, tuple):
            # drift@B: budget quantizes UP to whole blocks; every C_g
            # divides BLOCK_SIZE so each maskable block is full — the
            # count is exact REGARDLESS of which blocks the scores pick
            win = min(-(-frac[1] // BLOCK_SIZE) * BLOCK_SIZE, len(c))
        else:
            # compose: fixed leading boundary window, f * C_g tokens
            win = recompute_window(len(c), frac)
        total += len(r) + len(d) + win
    return total


def match_rate(rows, ref_rows):
    """Mean leading-token agreement of the generated continuations."""
    fracs = []
    for r, ref in zip(rows, ref_rows):
        a, b = r["tokens"], ref["tokens"]
        n = max(1, max(len(a), len(b)))
        m = 0
        for x, y in zip(a, b):
            if x != y:
                break
            m += 1
        fracs.append(m / n)
    return float(np.mean(fracs))


# ----------------------------------------------------------------------
def run_admission(tok, cfg, params, chains, suffixes):
    """Composition-aware admission (gate 5): a repeat-heavy replay —
    cluster 0 cold, clusters 1..3 (the rest of ctx group 0) arriving
    3x each — under both policies at frac = 0.5.  Greedy engages every arrival and pays the
    gap + window recompute each time; "cost" projects the repeats from
    ``CacheStats.cluster_arrivals`` (doubling heuristic), sees that one
    chain prefill amortizes cheaper, declines, and lets the repeats hit
    the pool."""
    def trace(policy):
        eng = make_engine(tok, cfg, params)
        sched = make_scheduler(eng, chains)
        sched.compose_frac = 0.5
        sched.compose_admission = policy
        eng.gap_admit = None          # isolate the admission decision
        st = eng.cache_mgr.stats
        total = 0

        def serve(cid, is_new):
            nonlocal total
            p0, s0, c0 = (st.prefix_tokens_computed,
                          st.compose_spliced_tokens, st.compose_requests)
            q = sched.serve_batch(
                [np.zeros(4, np.float32)], [None], [suffixes[cid]],
                assignments=[Assignment(cluster_id=cid, is_new=is_new,
                                        distance=0.0)])[0]
            total += (st.prefix_tokens_computed - p0) + len(suffixes[cid])
            if st.compose_requests > c0:
                total += q.prefix_len - (st.compose_spliced_tokens - s0)

        serve(0, True)
        for _ in range(3):
            for cid in (1, 2, 3):
                serve(cid, False)
        declines, engages = st.compose_declines, st.compose_requests
        sched.pool.clear()
        assert eng.block_pool.blocks_in_use == 0
        return total, declines, engages

    toks_g, dec_g, eng_g = trace("greedy")
    toks_c, dec_c, eng_c = trace("cost")
    assert dec_g == 0 and eng_g > 0       # greedy engaged throughout
    assert dec_c >= 1                     # cost refused >= 1 engage ...
    assert toks_c < toks_g                # ... and total prefill fell
    return {
        "trace": "cluster 0 cold + clusters 1-3 arriving 3x each",
        "compose_frac": 0.5,
        "prefill_tokens": {"greedy": toks_g, "cost": toks_c},
        "declines": {"greedy": dec_g, "cost": dec_c},
        "engages": {"greedy": eng_g, "cost": eng_c},
        "cost_saves_tokens": True,
    }


def run_bf16_identity(tok, ctx, roots, deltas, suffixes):
    """Identity gate 6 on bf16/Pallas (interpret mode on CPU, so a
    reduced 3-cluster trace over the group-0 ctx): compose@1.0 and
    drift@MAX_CTX must serve token-identically to the chain arm on
    that engine too."""
    n = 3
    cfg = ModelConfig(name="bench-fusion-bf16", family="dense",
                      num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=tok.vocab_size,
                      dtype="bfloat16", attention_impl="pallas")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, tok, max_cache_len=MAX_CACHE_LEN,
                        max_new_tokens=4, block_size=BLOCK_SIZE,
                        arena_blocks=256)
    chains = [[r, ctx, d] for r, d in zip(roots[:n], deltas[:n])]
    sfx = suffixes[:n]
    chain_rows, _ = run_scheduled(eng, chains, sfx, None)
    comp_rows, _ = run_scheduled(eng, chains, sfx, 1.0)
    drift_rows, _ = run_scheduled(eng, chains, sfx, 0.0, budget=MAX_CTX)
    for i in range(n):
        assert comp_rows[i]["tokens"] == chain_rows[i]["tokens"]
        assert drift_rows[i]["tokens"] == chain_rows[i]["tokens"]
    return {"clusters": n, "dtype": "bfloat16", "impl": "pallas",
            "compose_frac1_identical_to_chain": True,
            "drift_full_budget_identical_to_chain": True}


def run(out_path):
    tok, cfg, params, ctxs, roots, deltas, suffixes = substrate()
    eng = make_engine(tok, cfg, params)
    ctx_list = [ctxs[i // GROUP_SIZE] for i in range(NUM_CLUSTERS)]
    chains = [[r, c, d] for r, c, d in zip(roots, ctx_list, deltas)]
    prompts = [r + c + d for r, c, d in zip(roots, ctx_list, deltas)]
    arms = ([("dense", "dense"), ("chain", None)]
            + [(f"compose@{f}", f) for f in FRACS]
            + [(f"drift@{b}", ("drift", b)) for b in BUDGETS])

    def run_arm(frac):
        if frac == "dense":
            return run_dense(eng, prompts, suffixes)
        if isinstance(frac, tuple):
            return run_scheduled(eng, chains, suffixes, 0.0,
                                 budget=frac[1])
        return run_scheduled(eng, chains, suffixes, frac)

    # warm pass: compiles every prefill/decode shape each arm touches,
    # and exercises the identity gates once before timing
    for _, frac in arms:
        run_arm(frac)

    results = {name: {"computed": [], "ttft_mean_s": [], "ttft_p95_s": [],
                      "wall_s": [], "match_vs_dense": [],
                      "composed_rows": 0}
               for name, _ in arms}
    for _ in range(REPLAYS):
        replay = {}
        for name, frac in arms:          # interleaved: arms alternate
            rows, wall = run_arm(frac)
            replay[name] = rows
            r = results[name]
            computed = sum(x["computed"] for x in rows)
            assert computed == expected_tokens(roots, ctx_list, deltas,
                                               suffixes, frac), \
                (name, computed)         # exact accounting gate
            r["computed"].append(computed)
            ttfts = [x["ttft"] for x in rows]
            r["ttft_mean_s"].append(float(np.mean(ttfts)))
            r["ttft_p95_s"].append(float(np.percentile(ttfts, 95)))
            r["wall_s"].append(wall)
            r["composed_rows"] = sum(x.get("composed", False)
                                     for x in rows)
        # token-identity gates (f32/XLA), every replay
        for i in range(NUM_CLUSTERS):
            assert replay["chain"][i]["tokens"] == \
                replay["dense"][i]["tokens"]
            assert replay["compose@1.0"][i]["tokens"] == \
                replay["chain"][i]["tokens"]
            assert replay[f"drift@{MAX_CTX}"][i]["tokens"] == \
                replay["chain"][i]["tokens"]
        for name, _ in arms:
            results[name]["match_vs_dense"].append(
                match_rate(replay[name], replay["dense"]))

    arms_out, chain_tokens = {}, None
    for name, frac in arms:
        r = results[name]
        assert len(set(r["computed"])) == 1     # deterministic per arm
        arms_out[name] = dict(
            prefill_tokens=r["computed"][0],
            ttft_mean_s=float(np.median(r["ttft_mean_s"])),
            ttft_p95_s=float(np.median(r["ttft_p95_s"])),
            wall_s=float(np.median(r["wall_s"])),
            greedy_match_vs_dense=float(np.median(r["match_vs_dense"])),
            composed_rows=r["composed_rows"])
        if name == "chain":
            chain_tokens = arms_out[name]["prefill_tokens"]
    for name, frac in arms:
        arms_out[name]["prefill_cut_vs_chain"] = round(
            chain_tokens / arms_out[name]["prefill_tokens"], 3)

    # headline gate 3: a PARTIAL reuse arm (fixed frac or drift budget)
    # that wins on both axes at once — on the mixed-length trace the
    # fixed fracs each miss one axis, so the winners are drift arms
    winners = [
        name for name, frac in arms
        if ((isinstance(frac, float) and frac < 1.0)
            or (isinstance(frac, tuple) and frac[1] < MAX_CTX))
        and arms_out[name]["prefill_cut_vs_chain"] >= GATE_MIN_PREFILL_CUT
        and arms_out[name]["greedy_match_vs_dense"] >= GATE_MIN_MATCH]
    assert winners, arms_out

    # headline gate 4: drift beats the fixed-window FRONTIER — at least
    # one partial drift arm takes >= GATE_DRIFT_CUT_RATIO x the best
    # fixed arm's prefill cut without giving up match (or matches its
    # cut at strictly higher fidelity)
    best_fixed = max(
        (name for name, frac in arms
         if isinstance(frac, float) and frac < 1.0),
        key=lambda n: arms_out[n]["prefill_cut_vs_chain"])
    fx_cut = arms_out[best_fixed]["prefill_cut_vs_chain"]
    fx_match = arms_out[best_fixed]["greedy_match_vs_dense"]
    drift_winners = []
    for name, frac in arms:
        if not (isinstance(frac, tuple) and frac[1] < MAX_CTX):
            continue
        cut = arms_out[name]["prefill_cut_vs_chain"]
        match = arms_out[name]["greedy_match_vs_dense"]
        if ((cut >= GATE_DRIFT_CUT_RATIO * fx_cut and match >= fx_match)
                or (cut >= fx_cut and match > fx_match)):
            drift_winners.append(name)
    assert drift_winners, (best_fixed, fx_cut, fx_match, arms_out)

    # one-shot sections: admission policy + bf16/Pallas identity
    admission = run_admission(tok, cfg, params, chains, suffixes)
    bf16 = run_bf16_identity(tok, ctxs[0], roots, deltas, suffixes)

    report = {
        "bench": "fusion_serving",
        "design": "DESIGN.md §14/§15: spliceable KV segments, read-time "
                  "RoPE delta rotation, content-addressed registry, "
                  "drift-scored selective recompute, cost admission",
        "config": dict(model=cfg.name, num_layers=cfg.num_layers,
                       d_model=cfg.d_model, num_heads=cfg.num_heads,
                       num_kv_heads=cfg.num_kv_heads, dtype=cfg.dtype,
                       vocab_size=cfg.vocab_size,
                       max_cache_len=MAX_CACHE_LEN,
                       block_size=BLOCK_SIZE,
                       max_new_tokens=MAX_NEW_TOKENS,
                       num_clusters=NUM_CLUSTERS, group_size=GROUP_SIZE,
                       ctx_lens=CTX_LENS,
                       root_lens=ROOT_LENS, delta_len=DELTA_LEN,
                       suffix_len=SUFFIX_LEN, fracs=FRACS,
                       budgets=BUDGETS, replays=REPLAYS,
                       gate_min_prefill_cut=GATE_MIN_PREFILL_CUT,
                       gate_min_match=GATE_MIN_MATCH,
                       gate_drift_cut_ratio=GATE_DRIFT_CUT_RATIO),
        "arms": arms_out,
        "gates": {
            "chain_token_identical_to_dense": True,
            "compose_frac1_token_identical_to_chain": True,
            "drift_full_budget_token_identical_to_chain": True,
            "accounting_matches_plan_semantics": True,
            "partial_frac_winners": winners,
            "fixed_window_frontier": {
                "arm": best_fixed, "prefill_cut_vs_chain": fx_cut,
                "greedy_match_vs_dense": fx_match},
            "drift_frontier_winners": drift_winners,
            "admission": admission,
            "bf16_pallas_identity": bf16,
        },
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(json.dumps(report["arms"], indent=2))
    print("winners:", winners, "drift:", drift_winners, "->", out_path)
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_fusion_serving.json"))
    args = ap.parse_args()
    run(args.out)


if __name__ == "__main__":
    main()
