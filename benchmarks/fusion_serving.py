"""Cross-cluster segment fusion vs chain-only prefix reuse
(DESIGN.md §14) on a trace built so chain reuse MISSES but segment
reuse HITS.

The workload is K clusters whose prompts all embed the SAME long
context segment behind per-cluster roots of *different lengths*:

    cluster i prompt = root_i (R_i tokens, all R_i distinct)
                       + ctx (C tokens, identical content)
                       + delta_i (D tokens, unique)

Chain (prefix-tree) reuse only shares literal token *prefixes*: the
roots differ, so every cluster prefills its own copy of ``ctx`` — the
tree layout cannot see the overlap.  The composition path caches
``ctx`` once (under cluster 0's chain), finds it through the
content-addressed segment registry, and SPLICES it into every other
cluster's prompt at a different base position — canonical-K storage
plus read-time RoPE delta rotation make the cached blocks valid at any
offset.  Only the roots, deltas, and a leading ``recompute_frac``
boundary window of ``ctx`` are prefilled fresh.

Arms (all on one engine, f32/XLA, paged + fused path):

  * ``dense``   — no reuse: every query prefills its full prompt;
  * ``chain``   — the DESIGN.md §10 chain path (``compose_frac=None``);
  * ``compose@f`` — ``try_compose`` armed at ``recompute_frac = f``
    for f in ``FRACS`` (1.0 degenerates to dense recompute of every
    spliced token and must be token-identical to the chain arm).

Reported per arm: prefix prefill tokens (EMPIRICAL, from the serving
stats — asserted equal to the analytic count from the plan semantics),
mean/p95 TTFT share, wall time, and the greedy-match rate against the
dense arm (mean leading-token agreement of the generated
continuations).

Gates, asserted on every timed replay:

  1. ``chain`` serves token-identically to ``dense`` (f32/XLA);
  2. ``compose@1.0`` serves token-identically to ``chain``;
  3. some PARTIAL frac cuts prefix prefill tokens >= 2.0x vs the chain
     arm while clearing a >= 0.90 greedy-match rate — the headline:
     fusion reuse wins where chain reuse cannot, at near-dense output.

Writes ``BENCH_fusion_serving.json`` at the repo root.  Runs on CPU.

    PYTHONPATH=src python benchmarks/fusion_serving.py
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core.cache import recompute_window
from repro.core.planner import ChainSpec
from repro.core.prefix_pool import PrefixPool
from repro.data.scenegraph import generate_scene_graph
from repro.data.tokenizer import Tokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.engine import Request, ServingEngine
from repro.serving.scheduler import (Assignment, OnlineCluster,
                                     OnlineScheduler)

MAX_CACHE_LEN = 1024
BLOCK_SIZE = 32
NUM_CLUSTERS = 12           # K: one query per cluster per replay
CTX_LEN = 256               # C: the shared (spliceable) segment
DELTA_LEN = 8               # D: unique per-cluster tail segment
SUFFIX_LEN = 10             # query suffix appended after the prefix
ROOT_LENS = [3 + i for i in range(NUM_CLUSTERS)]   # all distinct ->
                                                   # every splice is
                                                   # re-based
FRACS = [0.25, 0.5, 1.0]    # recompute_frac points for the compose arm
GATE_MIN_PREFILL_CUT = 2.0  # vs the chain arm, at some partial frac
GATE_MIN_MATCH = 0.90       # greedy-match rate vs dense, same frac
MAX_NEW_TOKENS = 12
REPLAYS = 3


# ----------------------------------------------------------------------
def substrate():
    """Scene-graph text -> tokenizer -> tiny dense model + the segment
    library (roots / shared ctx / deltas / suffixes) cut from the
    corpus token stream at non-overlapping offsets."""
    graph, queries = generate_scene_graph()
    tok = Tokenizer.train([q.question + " " + q.answer for q in queries]
                          + graph.node_text, max_vocab=2048)
    cfg = ModelConfig(name="bench-fusion", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    stream = tok.encode(" ".join(graph.node_text))
    need = CTX_LEN + sum(ROOT_LENS) + NUM_CLUSTERS * (DELTA_LEN
                                                      + SUFFIX_LEN)
    while len(stream) < need:
        stream = stream + stream
    off = 0

    def take(n):
        nonlocal off
        piece, off = stream[off: off + n], off + n
        return piece

    from repro.data.tokenizer import BOS
    ctx = take(CTX_LEN)
    roots = [[BOS] + take(r - 1) for r in ROOT_LENS]
    deltas = [take(DELTA_LEN) for _ in range(NUM_CLUSTERS)]
    suffixes = [take(SUFFIX_LEN) for _ in range(NUM_CLUSTERS)]
    return tok, cfg, params, ctx, roots, deltas, suffixes


def make_engine(tok, cfg, params):
    return ServingEngine(params, cfg, tok, max_cache_len=MAX_CACHE_LEN,
                         max_new_tokens=MAX_NEW_TOKENS,
                         block_size=BLOCK_SIZE, arena_blocks=256)


def make_scheduler(eng, chains):
    """An ``OnlineScheduler`` whose cluster ``i`` carries the stub
    chain ``chains[i]`` (a list of raw token-id segments) — content in,
    content out, so the trace controls the registry keys exactly."""
    class _Assigner:
        clusters: list = []

        def representative(self, cid):
            return self.clusters[cid].representative

    asg = _Assigner()
    asg.clusters = [
        OnlineCluster(cluster_id=i, centroid=np.zeros(4, np.float32),
                      representative=None,
                      chain=ChainSpec(
                          keys=[f"c{i}s{j}" for j in range(len(segs))],
                          contents=[list(s) for s in segs]))
        for i, segs in enumerate(chains)]
    return OnlineScheduler(eng, asg, PrefixPool(1 << 28),
                           prefix_tokens_fn=lambda rep: list(rep),
                           segment_tokens_fn=lambda c, b: list(c))


# ----------------------------------------------------------------------
def run_dense(eng, prompts, suffixes):
    """No-reuse baseline: full prompt prefilled per query."""
    rows, t0 = [], time.perf_counter()
    for prompt, sfx in zip(prompts, suffixes):
        outs, t = eng.serve([Request(prompt + sfx)], _record=False)
        steps = max(1, len(outs[0]))
        rows.append(dict(tokens=outs[0],
                         computed=len(prompt) + len(sfx),
                         ttft=t["prefill_share"][0]
                         + t["decode_share"][0] / steps))
    return rows, time.perf_counter() - t0


def run_scheduled(eng, chains, suffixes, frac):
    """Chain arm (``frac is None``) or compose arm: one query per
    cluster through ``serve_batch``.  Computed prefix tokens are taken
    from the serving stats — ``prefix_tokens_computed`` covers chain
    prefills, and a composed row computes ``prefix_len`` minus the
    tokens it spliced from cache (gap + boundary-window tokens)."""
    sched = make_scheduler(eng, chains)
    sched.compose_frac = frac
    stats = eng.cache_mgr.stats
    rows, seen, t0 = [], set(), time.perf_counter()
    for cid, sfx in enumerate(suffixes):
        p0 = stats.prefix_tokens_computed
        s0 = stats.compose_spliced_tokens
        c0 = stats.compose_requests
        out = sched.serve_batch(
            [np.zeros(4, np.float32)], [None], [sfx],
            assignments=[Assignment(cluster_id=cid,
                                    is_new=cid not in seen,
                                    distance=0.0)])
        seen.add(cid)
        q = out[0]
        composed = stats.compose_requests > c0
        computed = (stats.prefix_tokens_computed - p0) + len(sfx)
        if composed:
            computed += q.prefix_len - (stats.compose_spliced_tokens - s0)
        steps = max(1, len(q.tokens))
        rows.append(dict(tokens=q.tokens, computed=computed,
                         composed=composed,
                         ttft=q.prefix_share_s + q.prefill_s
                         + q.decode_s / steps))
    wall = time.perf_counter() - t0
    sched.pool.clear()
    assert eng.block_pool.blocks_in_use == 0
    return rows, wall


def expected_tokens(roots, ctx, deltas, suffixes, frac):
    """Analytic computed-token count the empirical stats must match."""
    sfx = sum(len(s) for s in suffixes)
    if frac == "dense":
        return sum(len(r) + len(ctx) + len(d)
                   for r, d in zip(roots, deltas)) + sfx
    if frac is None:        # chain: every segment prefilled once, cold
        return sum(len(r) + len(ctx) + len(d)
                   for r, d in zip(roots, deltas)) + sfx
    # compose: cluster 0 cold-chains; the rest splice ctx and prefill
    # only their root + delta gaps and the boundary window
    win = recompute_window(len(ctx), frac)
    return (len(roots[0]) + len(ctx) + len(deltas[0])
            + sum(len(r) + len(d) + win
                  for r, d in zip(roots[1:], deltas[1:]))) + sfx


def match_rate(rows, ref_rows):
    """Mean leading-token agreement of the generated continuations."""
    fracs = []
    for r, ref in zip(rows, ref_rows):
        a, b = r["tokens"], ref["tokens"]
        n = max(1, max(len(a), len(b)))
        m = 0
        for x, y in zip(a, b):
            if x != y:
                break
            m += 1
        fracs.append(m / n)
    return float(np.mean(fracs))


# ----------------------------------------------------------------------
def run(out_path):
    tok, cfg, params, ctx, roots, deltas, suffixes = substrate()
    eng = make_engine(tok, cfg, params)
    chains = [[r, ctx, d] for r, d in zip(roots, deltas)]
    prompts = [r + ctx + d for r, d in zip(roots, deltas)]
    arms = [("dense", "dense"), ("chain", None)] + \
        [(f"compose@{f}", f) for f in FRACS]

    # warm pass: compiles every prefill/decode shape each arm touches,
    # and exercises the identity gates once before timing
    for _, frac in arms:
        if frac == "dense":
            run_dense(eng, prompts, suffixes)
        else:
            run_scheduled(eng, chains, suffixes, frac)

    results = {name: {"computed": [], "ttft_mean_s": [], "ttft_p95_s": [],
                      "wall_s": [], "match_vs_dense": [],
                      "composed_rows": 0}
               for name, _ in arms}
    for _ in range(REPLAYS):
        replay = {}
        for name, frac in arms:          # interleaved: arms alternate
            if frac == "dense":
                rows, wall = run_dense(eng, prompts, suffixes)
            else:
                rows, wall = run_scheduled(eng, chains, suffixes, frac)
            replay[name] = rows
            r = results[name]
            computed = sum(x["computed"] for x in rows)
            assert computed == expected_tokens(roots, ctx, deltas,
                                               suffixes, frac), \
                (name, computed)         # exact accounting gate
            r["computed"].append(computed)
            ttfts = [x["ttft"] for x in rows]
            r["ttft_mean_s"].append(float(np.mean(ttfts)))
            r["ttft_p95_s"].append(float(np.percentile(ttfts, 95)))
            r["wall_s"].append(wall)
            r["composed_rows"] = sum(x.get("composed", False)
                                     for x in rows)
        # token-identity gates (f32/XLA), every replay
        for i in range(NUM_CLUSTERS):
            assert replay["chain"][i]["tokens"] == \
                replay["dense"][i]["tokens"]
            assert replay["compose@1.0"][i]["tokens"] == \
                replay["chain"][i]["tokens"]
        for name, _ in arms:
            results[name]["match_vs_dense"].append(
                match_rate(replay[name], replay["dense"]))

    arms_out, chain_tokens = {}, None
    for name, frac in arms:
        r = results[name]
        assert len(set(r["computed"])) == 1     # deterministic per arm
        arms_out[name] = dict(
            prefill_tokens=r["computed"][0],
            ttft_mean_s=float(np.median(r["ttft_mean_s"])),
            ttft_p95_s=float(np.median(r["ttft_p95_s"])),
            wall_s=float(np.median(r["wall_s"])),
            greedy_match_vs_dense=float(np.median(r["match_vs_dense"])),
            composed_rows=r["composed_rows"])
        if name == "chain":
            chain_tokens = arms_out[name]["prefill_tokens"]
    for name, frac in arms:
        arms_out[name]["prefill_cut_vs_chain"] = round(
            chain_tokens / arms_out[name]["prefill_tokens"], 3)

    # headline gate: a PARTIAL frac that wins on both axes at once
    winners = [
        name for name, frac in arms
        if isinstance(frac, float) and frac < 1.0
        and arms_out[name]["prefill_cut_vs_chain"] >= GATE_MIN_PREFILL_CUT
        and arms_out[name]["greedy_match_vs_dense"] >= GATE_MIN_MATCH]
    assert winners, arms_out

    report = {
        "bench": "fusion_serving",
        "design": "DESIGN.md §14: spliceable KV segments, read-time "
                  "RoPE delta rotation, content-addressed registry",
        "config": dict(model=cfg.name, num_layers=cfg.num_layers,
                       d_model=cfg.d_model, num_heads=cfg.num_heads,
                       num_kv_heads=cfg.num_kv_heads, dtype=cfg.dtype,
                       vocab_size=cfg.vocab_size,
                       max_cache_len=MAX_CACHE_LEN,
                       block_size=BLOCK_SIZE,
                       max_new_tokens=MAX_NEW_TOKENS,
                       num_clusters=NUM_CLUSTERS, ctx_len=CTX_LEN,
                       root_lens=ROOT_LENS, delta_len=DELTA_LEN,
                       suffix_len=SUFFIX_LEN, fracs=FRACS,
                       replays=REPLAYS,
                       gate_min_prefill_cut=GATE_MIN_PREFILL_CUT,
                       gate_min_match=GATE_MIN_MATCH),
        "arms": arms_out,
        "gates": {
            "chain_token_identical_to_dense": True,
            "compose_frac1_token_identical_to_chain": True,
            "accounting_matches_plan_semantics": True,
            "partial_frac_winners": winners,
        },
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(json.dumps(report["arms"], indent=2))
    print("winners:", winners, "->", out_path)
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_fusion_serving.json"))
    args = ap.parse_args()
    run(args.out)


if __name__ == "__main__":
    main()
