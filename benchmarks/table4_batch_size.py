"""Paper Table 4 / A.4: effect of the in-batch query count."""
from __future__ import annotations

import argparse

from repro.rag.workbench import build_workbench, serving_report, test_items
from repro.serving.metrics import speedup


def run(sizes=(25, 50, 100), dataset: str = "scene", num_clusters: int = 2,
        train_steps: int = 300, log_fn=print):
    wb = build_workbench(dataset, train_steps=train_steps, log_fn=log_fn)
    pipe = wb.pipeline("gretriever")
    pipe.engine.warmup()
    out = []
    for n in sizes:
        items = test_items(wb, n, seed=1000 + n)
        rb, sb = pipe.run_baseline(items)
        _, ss, _, stats = pipe.run_subgcache(items, num_clusters=num_clusters)
        sp = speedup(sb, ss)
        rep = serving_report(pipe)
        log_fn(f"batch {n:4d}: base ACC {sb.acc:6.2f} TTFT {sb.ttft_ms:8.2f}"
               f" | ours ACC {ss.acc:6.2f} TTFT {ss.ttft_ms:8.2f}"
               f" | dACC {sp['acc_delta']:+5.2f} TTFT x{sp['ttft_x']:.2f}"
               f" PFTT x{sp['pftt_x']:.2f}"
               f" | prefill savings x{rep['prefill_savings']:.2f}"
               f" ({'cascade' if rep['split_prefix'] else 'broadcast'})")
        out.append({"batch": n, **sp, **rep})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="scene")
    ap.add_argument("--sizes", type=int, nargs="+", default=[25, 50, 100])
    args = ap.parse_args()
    run(tuple(args.sizes), dataset=args.dataset)


if __name__ == "__main__":
    main()
