"""In-repo word-level tokenizer (no external vocab files).

Deterministic: lowercases, splits on whitespace and punctuation, builds
the vocab from a corpus pass.  IDs 0..3 are reserved specials.  Used by
the small trained backbone; the full-scale configs only need vocab *sizes*
(dry-run lowers on ShapeDtypeStructs).
"""
from __future__ import annotations

import re
from typing import Iterable, List

PAD, BOS, EOS, UNK = 0, 1, 2, 3
SPECIALS = ["<pad>", "<bos>", "<eos>", "<unk>"]

_TOKEN_RE = re.compile(r"[a-z0-9]+|[^\sa-z0-9]")


def _words(text: str) -> List[str]:
    return _TOKEN_RE.findall(text.lower())


class Tokenizer:
    def __init__(self, vocab: List[str]):
        self.vocab = list(vocab)
        self._ids = {w: i for i, w in enumerate(self.vocab)}

    @staticmethod
    def train(corpus: Iterable[str], max_vocab: int = 8192) -> "Tokenizer":
        counts: dict = {}
        for text in corpus:
            for w in _words(text):
                counts[w] = counts.get(w, 0) + 1
        ordered = sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))
        vocab = SPECIALS + [w for w, _ in ordered[: max_vocab - len(SPECIALS)]]
        return Tokenizer(vocab)

    @property
    def vocab_size(self) -> int:
        return len(self.vocab)

    def encode(self, text: str, *, bos: bool = False, eos: bool = False) -> List[int]:
        ids = [self._ids.get(w, UNK) for w in _words(text)]
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        out = []
        for i in ids:
            if i in (PAD, BOS):
                continue
            if i == EOS:
                break
            out.append(self.vocab[i] if 0 <= i < len(self.vocab) else "<unk>")
        return " ".join(out)
