"""Synthetic OAG-like academic graph (paper App. A.1 statistics).

Heterogeneous textual graph: papers, authors, organizations and fields,
with relations {written by, focuses on, cites, has member}.  Queries are
link prediction: "How is <X> connected to <Y>?" with the relation text as
the answer — exactly the paper's OAG adaptation.

Community structure (papers grouped into topical communities sharing
fields/authors) produces the overlapping retrieved subgraphs the in-batch
setting exploits.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.data.scenegraph import QAItem
from repro.rag.textgraph import TextGraph

TOPIC_WORDS = {
    "artificial intelligence": ["neural", "learning", "agents", "reasoning",
                                "models", "planning"],
    "computer vision": ["video", "image", "surveillance", "detection",
                        "segmentation", "recognition"],
    "databases": ["query", "index", "transactions", "storage", "batch",
                  "processing"],
    "human computer interaction": ["interface", "tabletops", "usability",
                                   "interaction", "design", "users"],
    "networks": ["routing", "wireless", "protocols", "latency", "traffic",
                 "topology"],
    "security": ["encryption", "authentication", "privacy", "attacks",
                 "detection", "trust"],
}
FIRST = ["wei", "maria", "john", "li", "anna", "pedro", "yuki", "omar",
         "ivan", "sara", "chen", "amir"]
LAST = ["zhang", "garcia", "smith", "wang", "novak", "tanaka", "khan",
        "petrov", "rossi", "kim", "mueller", "larsen"]
ORGS = ["university of castilla la mancha", "aalborg university copenhagen",
        "queen mary university of london", "nanyang technological university",
        "eth zurich", "university of tokyo", "mit", "tsinghua university"]


def generate_oag(num_papers: int = 700, num_authors: int = 300,
                 num_queries: int = 3434, seed: int = 1
                 ) -> Tuple[TextGraph, List[QAItem]]:
    rng = np.random.default_rng(seed)
    fields = list(TOPIC_WORDS.keys())
    node_text: List[str] = []

    paper_ids = []
    paper_field = []
    for i in range(num_papers):
        f = fields[int(rng.integers(0, len(fields)))]
        words = TOPIC_WORDS[f]
        n = int(rng.integers(4, 7))
        title = " ".join(str(rng.choice(words)) for _ in range(n)) + f" {i}"
        paper_ids.append(len(node_text))
        paper_field.append(f)
        node_text.append(f"name: {title}")

    author_ids = []
    for i in range(num_authors):
        nm = f"{FIRST[i % len(FIRST)]} {LAST[(i // len(FIRST)) % len(LAST)]} {i}"
        author_ids.append(len(node_text))
        node_text.append(f"name: {nm}")

    org_ids = []
    for o in ORGS:
        org_ids.append(len(node_text))
        node_text.append(f"name: {o}")

    field_ids = {}
    for f in fields:
        field_ids[f] = len(node_text)
        node_text.append(f"name: {f}")

    edges = []
    # community structure: authors specialize in 1-2 fields
    author_fields = {a: rng.choice(fields, size=int(rng.integers(1, 3)),
                                   replace=False).tolist()
                     for a in author_ids}
    field_authors = {f: [a for a in author_ids if f in author_fields[a]]
                     for f in fields}
    for idx, p in enumerate(paper_ids):
        f = paper_field[idx]
        edges.append((p, "focuses on", field_ids[f]))
        pool = field_authors[f] or author_ids
        k = int(rng.integers(1, 4))
        for a in rng.choice(pool, size=min(k, len(pool)), replace=False):
            edges.append((p, "written by", int(a)))
        # citations within the same field mostly
        same = [paper_ids[j] for j in range(idx) if paper_field[j] == f]
        if same and rng.random() < 0.5:
            edges.append((p, "cites", int(rng.choice(same))))
    for a in author_ids:
        if rng.random() < 0.6:
            edges.append((int(rng.choice(org_ids)), "has member", a))

    graph = TextGraph(node_text=node_text, edges=edges)

    # link-prediction queries over existing edges
    queries: List[QAItem] = []
    eidx = rng.permutation(len(edges))
    i = 0
    while len(queries) < num_queries:
        s, r, d = edges[int(eidx[i % len(edges)])]
        i += 1
        sname = node_text[s].removeprefix("name: ")
        dname = node_text[d].removeprefix("name: ")
        queries.append(QAItem(
            question=f'How is "{sname}" connected to "{dname}"?',
            answer=r, anchor_nodes=(s, d)))
    return graph, queries
