"""Synthetic Scene Graph dataset (paper App. A.1 statistics).

One image-level scene graph (default 22 nodes / 147 edges) whose nodes are
objects with attributes (name, color, material, position box) and whose
edges are spatial/possessive relations.  Queries target entity attributes
or relations, with exact ground truth derived from the graph — including
multi-hop forms ("What is the color of the object to the left of X?").

In-batch redundancy arises exactly as in the paper: many queries touch the
same objects, so their retrieved subgraphs overlap heavily.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.rag.textgraph import TextGraph

NAMES = ["man", "woman", "laptop", "screen", "sweater", "jeans", "shirt",
         "pants", "camera", "building", "windows", "cords", "eye glasses",
         "chair", "table", "phone", "bag", "shoes", "hat", "cup", "book",
         "door", "lamp", "keyboard", "jacket", "bottle"]
COLORS = ["black", "blue", "red", "orange", "gray", "white", "green",
          "brown", "purple", "yellow"]
MATERIALS = ["plaid", "glass", "wooden", "metal", "plastic", "leather"]
SPATIAL = ["to the left of", "to the right of", "above", "below", "near"]
POSSESSIVE = ["wearing", "holding", "using", "standing by"]


@dataclasses.dataclass
class QAItem:
    question: str
    answer: str
    anchor_nodes: Tuple[int, ...]       # ground-truth relevant nodes


def generate_scene_graph(num_nodes: int = 22, num_edges: int = 147,
                         num_queries: int = 426, seed: int = 0
                         ) -> Tuple[TextGraph, List[QAItem]]:
    rng = np.random.default_rng(seed)
    names = [NAMES[i % len(NAMES)] for i in range(num_nodes)]
    colors: Dict[int, str] = {}
    node_text = []
    for i in range(num_nodes):
        attrs = [f"name: {names[i]}"]
        if rng.random() < 0.7:
            colors[i] = str(rng.choice(COLORS))
            attrs.append(f"attribute: {colors[i]}")
        if rng.random() < 0.25:
            attrs.append(f"attribute: {rng.choice(MATERIALS)}")
        x, y = rng.integers(0, 400, 2)
        w, h = rng.integers(10, 200, 2)
        attrs.append(f"(x,y,w,h): ({x}, {y}, {w}, {h})")
        node_text.append("; ".join(attrs))

    # unique name lookup for unambiguous questions
    name_count: Dict[str, int] = {}
    for n in names:
        name_count[n] = name_count.get(n, 0) + 1

    edges = []
    seen = set()
    rel_of: Dict[Tuple[int, str], int] = {}
    person_idx = [i for i, n in enumerate(names) if n in ("man", "woman")]
    tries = 0
    while len(edges) < num_edges and tries < num_edges * 50:
        tries += 1
        s, d = rng.integers(0, num_nodes, 2)
        if s == d:
            continue
        if person_idx and s in person_idx and rng.random() < 0.3:
            r = str(rng.choice(POSSESSIVE))
        else:
            r = str(rng.choice(SPATIAL))
        if (s, r, d) in seen:
            continue
        seen.add((s, r, d))
        edges.append((int(s), r, int(d)))
        rel_of.setdefault((int(s), r), int(d))
    graph = TextGraph(node_text=node_text, edges=edges)

    queries: List[QAItem] = []
    unique_nodes = [i for i in range(num_nodes) if name_count[names[i]] == 1]
    attempts = 0
    while len(queries) < num_queries and attempts < num_queries * 50:
        attempts += 1
        kind = rng.random()
        if kind < 0.45 and unique_nodes:
            # attribute query
            i = int(rng.choice(unique_nodes))
            if i not in colors:
                continue
            queries.append(QAItem(
                question=f"What is the color of the {names[i]}?",
                answer=colors[i], anchor_nodes=(i,)))
        elif kind < 0.8:
            # relation query: what is <rel> <unique node>?
            if not edges:
                continue
            s, r, d = edges[int(rng.integers(0, len(edges)))]
            if name_count[names[d]] != 1 or name_count[names[s]] != 1:
                continue
            # ensure uniqueness of (r, d) as a question target
            cands = [e for e in edges if e[1] == r and e[2] == d]
            if len(cands) != 1:
                continue
            queries.append(QAItem(
                question=f"What is {r} the {names[d]}?",
                answer=names[s], anchor_nodes=(s, d)))
        else:
            # 2-hop: color of the object <rel> <unique node>
            if not edges:
                continue
            s, r, d = edges[int(rng.integers(0, len(edges)))]
            if name_count[names[d]] != 1 or s not in colors:
                continue
            cands = [e for e in edges if e[1] == r and e[2] == d]
            if len(cands) != 1:
                continue
            queries.append(QAItem(
                question=f"What is the color of the object {r} the {names[d]}?",
                answer=colors[s], anchor_nodes=(s, d)))
    return graph, queries
