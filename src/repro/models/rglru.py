"""RG-LRU recurrent block (Griffin / RecurrentGemma family).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          # input gate
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Block structure follows Griffin: two input branches (conv+RG-LRU branch and
a GeLU gate branch) merged multiplicatively, then output projection.

Prefix-state analogue of KV reuse: ``(conv_state, rec_state)`` after the
representative prefix is the cached unit.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d, dense_init, init_conv1d, linear

_C = 8.0  # Griffin's fixed scaling constant


def init_rglru(key, d_model: int, width: int, conv_width: int, dtype) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    # Lambda init so that a ~ U(0.9, 0.999)^c proxy (Griffin appendix).
    u = jax.random.uniform(k5, (width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    return {
        "in_x": dense_init(k1, d_model, width, dtype),
        "in_gate": dense_init(k2, d_model, width, dtype),
        "conv": init_conv1d(k3, width, conv_width, dtype),
        "w_a": dense_init(k4, width, width, dtype),
        "b_a": jnp.zeros((width,), jnp.float32),
        "w_i": dense_init(jax.random.fold_in(k4, 1), width, width, dtype),
        "b_i": jnp.zeros((width,), jnp.float32),
        "lambda": lam,
        "out": dense_init(jax.random.fold_in(k1, 2), width, d_model, dtype),
    }


def init_rglru_cache(batch: int, width: int, conv_width: int, dtype) -> dict:
    return {
        "conv": jnp.zeros((batch, conv_width - 1, width), dtype),
        "state": jnp.zeros((batch, width), jnp.float32),
    }


def _rglru_scan(h0, x, a_log):
    """h0: [B, W]; x (gated input): [B, T, W]; a_log: [B, T, W] (log decay).

    h_t = exp(a_log_t) * h_{t-1} + sqrt(1 - exp(2 a_log_t)) * x_t
    """
    def step(h, inp):
        x_t, al_t = inp
        a = jnp.exp(al_t)
        h = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x_t
        return h, h

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(a_log, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_final


def apply_rglru(p: dict, x: jnp.ndarray, cache: Optional[dict] = None,
                *, impl: str = "xla"):
    """x: [B, T, D_model] -> (out [B, T, D_model], new_cache)."""
    b, t, _ = x.shape
    xi = linear(x, p["in_x"])
    gate = jax.nn.gelu(linear(x, p["in_gate"]).astype(jnp.float32))

    conv_state = cache["conv"] if cache is not None else None
    xi, new_conv = causal_conv1d(p["conv"], xi, conv_state)

    xf = xi.astype(jnp.float32)
    r = jax.nn.sigmoid(linear(xi, p["w_a"]).astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(linear(xi, p["w_i"]).astype(jnp.float32) + p["b_i"])
    a_log = -_C * jax.nn.softplus(p["lambda"]) * r          # [B, T, W], <= 0
    gated_in = i * xf

    h0 = (cache["state"] if cache is not None
          else jnp.zeros((b, xi.shape[-1]), jnp.float32))
    if impl == "pallas":
        from repro.kernels import ops as kops
        ys, h_final = kops.rglru_scan(gated_in, a_log, h0)
    else:
        ys, h_final = _rglru_scan(h0, gated_in, a_log)

    out = linear((ys * gate).astype(x.dtype), p["out"])
    new_cache = {"conv": new_conv, "state": h_final} if cache is not None else None
    return out, new_cache
