"""Model configuration covering every architecture family in the assigned pool.

A single ``ModelConfig`` describes dense / MoE / SSM / hybrid / enc-dec / VLM
decoder stacks.  ``layer_specs()`` expands the config into one ``LayerSpec``
per layer; the model assembly in ``model.py`` is driven purely by that list,
so new families are added by extending the spec vocabulary, not the model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

# Mixer kinds.
ATTN = "attn"                # global causal self attention (GQA)
ATTN_SWA = "attn_swa"        # sliding-window causal self attention
ATTN_LOCAL = "attn_local"    # local attention (hybrid archs; same math as SWA)
MAMBA = "mamba"              # Mamba-1 selective SSM
RGLRU = "rglru"              # RG-LRU recurrent block (Griffin/RecurrentGemma)

# FFN kinds.
MLP = "mlp"                  # SwiGLU MLP
MOE = "moe"                  # top-k routed experts
NONE = "none"                # no channel mixer (Mamba layers)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str                  # one of the mixer kinds above
    ffn: str                    # one of the ffn kinds above
    cross_attn: bool = False    # additionally cross-attend to encoder states


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads

    # --- attention ---
    sliding_window: int = 0           # 0 = full attention
    rope_theta: float = 10_000.0
    use_qkv_bias: bool = False

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    dense_residual_d_ff: int = 0      # arctic-style always-on dense MLP next to MoE

    # --- SSM (Mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0              # 0 -> ceil(d_model / 16)

    # --- hybrid (RecurrentGemma / Griffin) ---
    block_pattern: Tuple[str, ...] = ()   # cycled per-layer mixer pattern
    local_window: int = 0                 # window for ATTN_LOCAL layers
    lru_width: int = 0                    # 0 -> d_model

    # --- encoder-decoder (audio) ---
    num_encoder_layers: int = 0
    encoder_seq: int = 0                  # frames produced by the (stubbed) frontend
    frontend_dim: int = 0                 # dim of stubbed frame/patch embeddings

    # --- VLM ---
    cross_attn_period: int = 0            # every p-th layer gets cross attention
    cross_attn_offset: int = 3            # first cross layer index within period
    num_image_tokens: int = 0

    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    scan_layers: bool = True              # scan over stacked layer params
    remat: bool = False                   # jax.checkpoint each layer (training)
    attention_impl: str = "xla"           # "xla" | "pallas"
    max_target_len: int = 8192            # rope table sizing hint only

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def dt_rank_(self) -> int:
        return self.ssm_dt_rank or int(math.ceil(self.d_model / 16))

    @property
    def d_inner_(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def lru_width_(self) -> int:
        return self.lru_width or self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.num_encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return all(s.mixer in (MAMBA, RGLRU) for s in self.layer_specs())

    @property
    def supports_long_context(self) -> bool:
        """True when decode memory/compute is bounded (sub-quadratic)."""
        return all(
            s.mixer in (MAMBA, RGLRU, ATTN_SWA, ATTN_LOCAL)
            for s in self.layer_specs()
        )

    # ------------------------------------------------------------------
    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        """Expand the config into one LayerSpec per decoder layer."""
        specs = []
        for i in range(self.num_layers):
            # mixer
            if self.block_pattern:
                kind = self.block_pattern[i % len(self.block_pattern)]
            elif self.family == "ssm":
                kind = MAMBA
            elif self.sliding_window:
                kind = ATTN_SWA
            else:
                kind = ATTN
            # ffn
            if kind == MAMBA:
                ffn = NONE
            elif self.num_experts:
                ffn = MOE
            else:
                ffn = MLP
            # cross attention (vlm periodic / encdec every layer)
            cross = False
            if self.cross_attn_period:
                cross = (i % self.cross_attn_period) == self.cross_attn_offset
            elif self.is_encdec:
                cross = kind in (ATTN, ATTN_SWA, ATTN_LOCAL)
            specs.append(LayerSpec(mixer=kind, ffn=ffn, cross_attn=cross))
        return tuple(specs)

    def homogeneous(self) -> bool:
        specs = self.layer_specs()
        return all(s == specs[0] for s in specs)

    def validate(self) -> None:
        assert self.d_model > 0 and self.num_layers > 0
        if any(s.mixer in (ATTN, ATTN_SWA, ATTN_LOCAL) for s in self.layer_specs()):
            assert self.num_heads > 0 and self.num_kv_heads > 0
            assert self.num_heads % self.num_kv_heads == 0
        if self.num_experts:
            assert 0 < self.num_experts_per_tok <= self.num_experts
        if self.block_pattern:
            for k in self.block_pattern:
                assert k in (ATTN, ATTN_SWA, ATTN_LOCAL, MAMBA, RGLRU), k

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs accounting)."""
        d, hd = self.d_model, self.head_dim_
        n = self.vocab_size * d                      # embeddings
        if not self.tie_embeddings:
            n += self.vocab_size * d                 # lm head
        for s in self.layer_specs():
            if s.mixer in (ATTN, ATTN_SWA, ATTN_LOCAL):
                n += d * self.num_heads * hd         # q
                n += 2 * d * self.num_kv_heads * hd  # k, v
                n += self.num_heads * hd * d         # o
            elif s.mixer == MAMBA:
                di, ds, dr = self.d_inner_, self.ssm_state, self.dt_rank_
                n += d * 2 * di + di * self.ssm_conv + di * (dr + 2 * ds)
                n += dr * di + di * ds + 2 * di + di * d
            elif s.mixer == RGLRU:
                w = self.lru_width_
                n += 2 * d * w + w * self.ssm_conv + 3 * w + w * d
            if s.cross_attn:
                n += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                n += self.num_heads * hd * d
            if s.ffn == MLP:
                n += 3 * d * self.d_ff
            elif s.ffn == MOE:
                n += d * self.num_experts                       # router
                n += self.num_experts * 3 * d * self.d_ff       # experts
                if self.dense_residual_d_ff:
                    n += 3 * d * self.dense_residual_d_ff
        if self.is_encdec:
            for _ in range(self.num_encoder_layers):
                n += (2 + 2 * self.num_kv_heads / self.num_heads) * d * d
                n += 3 * d * self.d_ff
            n += (self.frontend_dim or d) * d
        if self.num_image_tokens:
            n += (self.frontend_dim or d) * d
        return int(n)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        n_moe_layers = sum(1 for s in self.layer_specs() if s.ffn == MOE)
        inactive = (self.num_experts - self.num_experts_per_tok) * 3 * d * self.d_ff
        return int(full - n_moe_layers * inactive)
