"""Primitive layers: init helpers, RMSNorm, RoPE, SwiGLU MLP.

Everything is pure-functional: ``init_*`` returns a params dict of jnp
arrays, ``apply`` style functions take ``(params, x, ...)``.  All matmuls
accumulate in float32 and cast back to the activation dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ----------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------------
# ops
# ----------------------------------------------------------------------
def linear(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def init_rms_norm(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """[head_dim//2] inverse frequencies."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate ``x`` of shape [..., T, head_dim] by RoPE at ``positions`` [..., T].

    ``positions`` broadcasts against x's leading dims; typically shape [T]
    or [B, T].
    """
    head_dim = x.shape[-1]
    inv = rope_frequencies(head_dim, theta)                     # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * inv        # [..., T, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # Broadcast cos/sin over any head dims between batch and T.
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# SwiGLU MLP
# ----------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def apply_mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(linear(x, p["w_gate"]).astype(jnp.float32))
    up = linear(x, p["w_up"]).astype(jnp.float32)
    return linear((gate * up).astype(x.dtype), p["w_down"])


# ----------------------------------------------------------------------
# depthwise causal conv1d (Mamba / RG-LRU front conv)
# ----------------------------------------------------------------------
def init_conv1d(key, channels: int, width: int, dtype) -> dict:
    scale = (1.0 / width) ** 0.5
    return {
        "w": (jax.random.normal(key, (width, channels), jnp.float32) * scale).astype(dtype),
        "b": jnp.zeros((channels,), dtype),
    }


def causal_conv1d(p: dict, x: jnp.ndarray, state: jnp.ndarray | None = None):
    """Depthwise causal conv.

    x: [B, T, C];  state: [B, W-1, C] trailing context from previous chunk.
    Returns (y [B, T, C], new_state [B, W-1, C]).
    """
    w = p["w"].astype(jnp.float32)                       # [W, C]
    width = w.shape[0]
    xf = x.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), jnp.float32)
    ctx = jnp.concatenate([state.astype(jnp.float32), xf], axis=1)   # [B, T+W-1, C]
    y = jnp.zeros_like(xf)
    for i in range(width):
        y = y + ctx[:, i:i + x.shape[1], :] * w[i]
    y = y + p["b"].astype(jnp.float32)
    new_state = ctx[:, -(width - 1):, :] if width > 1 else state
    return y.astype(x.dtype), new_state.astype(x.dtype)
