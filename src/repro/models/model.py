"""Model assembly: layer stacks, group-scan, caches, forward modes.

One implementation drives all ten architectures.  ``ModelConfig.layer_specs``
expands the config into per-layer ``LayerSpec``s; layers are grouped into
repeating units of size ``group_period`` (1 for homogeneous stacks, the
pattern length for hybrids, the cross-attention period for VLMs) and the
stack is executed with ``jax.lax.scan`` over stacked group params, with a
small unrolled remainder.  This keeps HLO size O(group) instead of
O(layers), which matters for 88-layer models lowered onto 512 devices.

Forward modes (all the same function):
  train          cache=None                      full causal self-attn
  prefill        cache=zeros, positions=0..T     writes cache
  suffix prefill cache=prefix, positions=P..P+T  ← the SubGCache fast path
  decode         cache=state,  positions=len     T=1, ring buffer optional
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.config import (ATTN, ATTN_LOCAL, ATTN_SWA, MAMBA, MLP, MOE,
                                 NONE, RGLRU, LayerSpec, ModelConfig)
from repro.models.layers import (dense_init, dtype_of, embed_init, init_mlp,
                                 init_rms_norm, apply_mlp, linear, rms_norm)


# ======================================================================
# per-layer init / apply
# ======================================================================
def init_layer(key, cfg: ModelConfig, spec: LayerSpec) -> dict:
    dt = dtype_of(cfg.dtype)
    keys = jax.random.split(key, 4)
    p = {"ln1": init_rms_norm(cfg.d_model, dt)}
    if spec.mixer in (ATTN, ATTN_SWA, ATTN_LOCAL):
        p["mixer"] = attn_lib.init_attention(
            keys[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.head_dim_, dt, cfg.use_qkv_bias)
    elif spec.mixer == MAMBA:
        p["mixer"] = ssm_lib.init_mamba(
            keys[0], cfg.d_model, cfg.d_inner_, cfg.ssm_state,
            cfg.dt_rank_, cfg.ssm_conv, dt)
    elif spec.mixer == RGLRU:
        p["mixer"] = rglru_lib.init_rglru(
            keys[0], cfg.d_model, cfg.lru_width_, cfg.ssm_conv, dt)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        p["ln_cross"] = init_rms_norm(cfg.d_model, dt)
        p["cross"] = attn_lib.init_cross_attention(
            keys[1], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.head_dim_, dt)
    if spec.ffn == MLP:
        p["ln2"] = init_rms_norm(cfg.d_model, dt)
        p["ffn"] = init_mlp(keys[2], cfg.d_model, cfg.d_ff, dt)
    elif spec.ffn == MOE:
        p["ln2"] = init_rms_norm(cfg.d_model, dt)
        p["ffn"] = moe_lib.init_moe(keys[2], cfg.d_model, cfg.d_ff,
                                    cfg.num_experts, dt,
                                    cfg.dense_residual_d_ff)
    return p


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     capacity: int, enc_len: int, dt) -> dict:
    c = {}
    if spec.mixer in (ATTN, ATTN_SWA, ATTN_LOCAL):
        cap = capacity
        if spec.mixer == ATTN_SWA and cfg.sliding_window:
            cap = min(cap, cfg.sliding_window)
        if spec.mixer == ATTN_LOCAL and cfg.local_window:
            cap = min(cap, cfg.local_window)
        c.update(attn_lib.init_kv_cache(batch, cfg.num_kv_heads, cap,
                                        cfg.head_dim_, dt))
    elif spec.mixer == MAMBA:
        c.update(ssm_lib.init_mamba_cache(batch, cfg.d_inner_, cfg.ssm_state,
                                          cfg.ssm_conv, dt))
    elif spec.mixer == RGLRU:
        c.update(rglru_lib.init_rglru_cache(batch, cfg.lru_width_,
                                            cfg.ssm_conv, dt))
    if spec.cross_attn:
        c["cross_k"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads,
                                  cfg.head_dim_), dt)
        c["cross_v"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads,
                                  cfg.head_dim_), dt)
    return c


def apply_layer(p: dict, spec: LayerSpec, cfg: ModelConfig, x: jnp.ndarray,
                cache: Optional[dict], ctx: dict,
                prefix: Optional[dict] = None):
    """Returns (x, new_cache, aux_loss).

    ``prefix`` is this layer's read-only batch-1 shared-prefix state
    (split prefix/suffix serving, DESIGN.md §5); attention mixers run
    cascade attention against it, recurrent mixers cannot split (their
    state is not a set of positional slots) and must use the broadcast
    fallback instead.
    """
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    new_cache = dict(cache) if cache is not None else None

    if spec.mixer in (ATTN, ATTN_SWA, ATTN_LOCAL):
        window = 0
        if spec.mixer == ATTN_SWA:
            window = cfg.sliding_window
        elif spec.mixer == ATTN_LOCAL:
            window = cfg.local_window
        sub = ({k: cache[k] for k in ("k", "v", "pos")}
               if cache is not None else None)
        # prefix may be one batch-1 cache (dense single segment, or the
        # paged decode's read-only arena) or a CHAIN of caches (a
        # tuple, root→leaf): attention folds one partial per segment
        def prefix_keys(src):
            # quantized paged arenas carry int8 K/V + per-block scales
            base = ("k", "v", "pos")
            return base + (("k_scale", "v_scale") if "k_scale" in src
                           else ())
        if prefix is None:
            sub_prefix = None
        elif isinstance(prefix, (list, tuple)):
            sub_prefix = tuple({k: p[k] for k in prefix_keys(p)}
                               for p in prefix)
        else:
            sub_prefix = {k: prefix[k] for k in prefix_keys(prefix)}
        out, sub_new = attn_lib.self_attention(
            p["mixer"], h,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim_, rope_theta=cfg.rope_theta,
            positions=ctx["positions"], cache=sub,
            causal=ctx.get("causal", True), window=window,
            ring=ctx.get("ring", False), valid=ctx.get("valid"),
            impl=cfg.attention_impl, prefix=sub_prefix,
            slot_offset=ctx.get("slot_offset", 0),
            prefix_pages=ctx.get("prefix_pages"),
            suffix_pages=ctx.get("suffix_pages"),
            fused=ctx.get("fused", True),
            prefix_offsets=ctx.get("prefix_offsets"),
            prefix_skips=ctx.get("prefix_skips"))
        if sub_new is not None:
            new_cache.update(sub_new)
    elif spec.mixer == MAMBA:
        if prefix is not None or ctx.get("suffix_pages") is not None:
            raise ValueError(
                "split/paged prefix serving does not cover Mamba mixers; "
                "use PrefixState.broadcast (the engine gates this)")
        sub = ({k: cache[k] for k in ("conv", "state")}
               if cache is not None else None)
        out, sub_new = ssm_lib.apply_mamba(
            p["mixer"], h, sub, d_state=cfg.ssm_state, dt_rank=cfg.dt_rank_,
            impl=cfg.attention_impl)
        if sub_new is not None:
            new_cache.update(sub_new)
    elif spec.mixer == RGLRU:
        if prefix is not None or ctx.get("suffix_pages") is not None:
            raise ValueError(
                "split/paged prefix serving does not cover RG-LRU mixers; "
                "use PrefixState.broadcast (the engine gates this)")
        sub = ({k: cache[k] for k in ("conv", "state")}
               if cache is not None else None)
        out, sub_new = rglru_lib.apply_rglru(p["mixer"], h, sub,
                                             impl=cfg.attention_impl)
        if sub_new is not None:
            new_cache.update(sub_new)
    x = x + out

    if spec.cross_attn:
        h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        enc = ctx.get("enc")
        if enc is not None:
            ekv = attn_lib.cross_attention_kv(
                p["cross"], enc, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.head_dim_)
            if new_cache is not None:
                new_cache["cross_k"], new_cache["cross_v"] = ekv
        elif prefix is not None:
            raise ValueError(
                "split prefix/suffix serving does not cover cross-attention "
                "layers (per-state encoder KV); use PrefixState.broadcast "
                "(the engine gates this)")
        else:
            ekv = (cache["cross_k"], cache["cross_v"])
        out = attn_lib.cross_attention(
            p["cross"], h, ekv, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim_)
        x = x + out

    if spec.ffn == MLP:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + apply_mlp(p["ffn"], h)
    elif spec.ffn == MOE:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        out, moe_aux = moe_lib.apply_moe(
            p["ffn"], h, top_k=cfg.num_experts_per_tok,
            capacity_factor=cfg.moe_capacity_factor)
        x = x + out
        aux = aux + moe_aux
    return x, new_cache, aux


# ======================================================================
# stack grouping
# ======================================================================
def group_period(cfg: ModelConfig) -> int:
    if cfg.cross_attn_period:
        return cfg.cross_attn_period
    if cfg.block_pattern:
        return len(cfg.block_pattern)
    return 1


def stack_layout(cfg: ModelConfig):
    """Returns (period, n_groups, n_rest)."""
    specs = cfg.layer_specs()
    g = group_period(cfg) if cfg.scan_layers else 0
    if g == 0 or len(specs) < 2 * g:
        return 0, 0, len(specs)          # fully unrolled
    n_groups = len(specs) // g
    return g, n_groups, len(specs) - n_groups * g


# ======================================================================
# full model params
# ======================================================================
def init_params(key, cfg: ModelConfig) -> dict:
    cfg.validate()
    dt = dtype_of(cfg.dtype)
    keys = jax.random.split(key, 8)
    specs = cfg.layer_specs()
    period, n_groups, n_rest = stack_layout(cfg)

    params = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
              "final_norm": init_rms_norm(cfg.d_model, dt)}
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dt)
    if cfg.frontend_dim:
        params["frontend_proj"] = dense_init(keys[2], cfg.frontend_dim,
                                             cfg.d_model, dt)

    def group_params(gkey, gspecs):
        gk = jax.random.split(gkey, len(gspecs))
        return {str(j): init_layer(gk[j], cfg, s)
                for j, s in enumerate(gspecs)}

    dec = {}
    if n_groups:
        gkeys = jax.random.split(keys[3], n_groups)
        per_group = [group_params(gkeys[i], specs[i * period:(i + 1) * period])
                     for i in range(n_groups)]
        dec["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
    rest_specs = specs[n_groups * period:]
    if rest_specs:
        rkeys = jax.random.split(keys[4], len(rest_specs))
        dec["rest"] = [init_layer(rkeys[i], cfg, s)
                       for i, s in enumerate(rest_specs)]
    params["dec"] = dec

    if cfg.is_encdec:
        enc_spec = LayerSpec(mixer=ATTN, ffn=MLP, cross_attn=False)
        ekeys = jax.random.split(keys[5], cfg.num_encoder_layers)
        per = [{"0": init_layer(ekeys[i], cfg, enc_spec)}
               for i in range(cfg.num_encoder_layers)]
        params["enc"] = {"groups": jax.tree.map(lambda *xs: jnp.stack(xs), *per),
                         "norm": init_rms_norm(cfg.d_model, dt)}
    return params


def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               enc_len: int = 0) -> dict:
    dt = dtype_of(cfg.dtype)
    specs = cfg.layer_specs()
    period, n_groups, n_rest = stack_layout(cfg)
    cache = {}
    if n_groups:
        one_group = {str(j): init_layer_cache(cfg, specs[j], batch, capacity,
                                              enc_len, dt)
                     for j in range(period)}
        cache["groups"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy(), one_group)
    rest_specs = specs[n_groups * period:]
    if rest_specs:
        cache["rest"] = [init_layer_cache(cfg, s, batch, capacity, enc_len, dt)
                         for s in rest_specs]
    return cache


def init_suffix_cache(cfg: ModelConfig, batch: int,
                      suffix_capacity: int) -> dict:
    """Member-batch suffix+decode cache for split prefix/suffix serving.

    Holds only ``suffix_capacity`` slots per member (suffix prefill +
    decode tail); the shared prefix stays in the batch-1 PrefixState and
    is passed to ``forward`` via ``prefix=`` instead of being broadcast.
    Only valid for attention-only stacks (DESIGN.md §5).
    """
    return init_cache(cfg, batch, suffix_capacity)


def init_block_arena(cfg: ModelConfig, num_blocks: int,
                     block_size: int) -> dict:
    """One [num_blocks, block_size, Hkv, D] K/V block arena per
    attention layer — the physical address space of the paged KV cache
    (DESIGN.md §8).  Structurally identical to ``init_cache`` with
    batch = num_blocks and capacity = block_size, EXCEPT that windowed
    layers are NOT clamped: every block has uniform geometry (a block is
    a unit of allocation, not a per-layer ring), and sliding windows are
    enforced positionally at attention time like every other mask.

    Attention-only stacks only: recurrent / cross-attention state has no
    positional slots to page (the engine keeps those dense behind the
    same request facade).
    """
    dt = dtype_of(cfg.dtype)
    specs = cfg.layer_specs()
    for s in specs:
        if s.mixer not in (ATTN, ATTN_SWA, ATTN_LOCAL) or s.cross_attn:
            raise ValueError(
                "paged KV arenas cover attention-only stacks; "
                f"got mixer {s.mixer} (cross_attn={s.cross_attn})")
    period, n_groups, _ = stack_layout(cfg)

    def one() -> dict:
        return attn_lib.init_kv_cache(num_blocks, cfg.num_kv_heads,
                                      block_size, cfg.head_dim_, dt)

    arena = {}
    if n_groups:
        one_group = {str(j): one() for j in range(period)}
        arena["groups"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy(),
            one_group)
    rest_specs = specs[n_groups * period:]
    if rest_specs:
        arena["rest"] = [one() for _ in rest_specs]
    return arena


# ======================================================================
# forward
# ======================================================================
def _group_body(cfg: ModelConfig, gspecs, ctx):
    from repro.distributed.hints import constrain

    def body(carry, xs):
        x, aux = carry
        gparams, gcache, gprefix = xs
        new_gcache = {} if gcache is not None else None
        for j, spec in enumerate(gspecs):
            lc = gcache[str(j)] if gcache is not None else None
            if gprefix is None:
                lp = None
            elif isinstance(gprefix, (list, tuple)):   # prefix chain
                lp = tuple(gp[str(j)] for gp in gprefix)
            else:
                lp = gprefix[str(j)]
            x, nc, a = apply_layer(gparams[str(j)], spec, cfg, x, lc, ctx, lp)
            x = constrain(x, "layer_boundary")
            aux = aux + a
            if new_gcache is not None:
                new_gcache[str(j)] = nc
        return (x, aux), new_gcache
    return body


def run_stack(params: dict, cfg: ModelConfig, x: jnp.ndarray,
              cache: Optional[dict], ctx: dict, specs=None,
              prefix: Optional[dict] = None):
    """Run the decoder stack.  Returns (x, new_cache, aux).

    ``prefix``: optional batch-1 shared-prefix cache pytree (same
    structure as ``cache``) scanned alongside the layer stack — read,
    never written (split prefix/suffix serving, DESIGN.md §5).
    """
    specs = specs if specs is not None else cfg.layer_specs()
    period, n_groups, _ = stack_layout(cfg)
    aux = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None else None
    # ``prefix`` is deliberately NOT normalized to a tuple here: a tuple
    # is a dense prefix CHAIN (one batch-1 cache per segment), while a
    # bare dict is either the dense single-segment prefix OR the paged
    # decode's read-only block ARENA — which must stay a dict all the
    # way to ``attend_paged`` (wrapping it would chain-ify the arena)
    chain = isinstance(prefix, (list, tuple))

    if n_groups:
        gspecs = specs[:period]
        body = _group_body(cfg, gspecs, ctx)
        if cfg.remat:
            body = jax.checkpoint(body)
        gcaches = cache.get("groups") if cache is not None else None
        if prefix is None:
            gprefix = None
        elif chain:
            gprefix = tuple(p.get("groups") for p in prefix)
        else:
            gprefix = prefix.get("groups")
        if gcaches is None:
            (x, aux), _ = jax.lax.scan(
                lambda c, p: (body((c[0], c[1]), (p, None, None))[0], None),
                (x, aux), params["dec"]["groups"])
        else:
            # None is an empty pytree: scan carries it through untouched.
            (x, aux), new_g = jax.lax.scan(
                body, (x, aux), (params["dec"]["groups"], gcaches, gprefix))
            new_cache["groups"] = new_g

    rest_specs = specs[n_groups * period:]
    for i, spec in enumerate(rest_specs):
        lc = cache["rest"][i] if cache is not None else None
        if prefix is None:
            lp = None
        elif chain:
            lp = tuple(p["rest"][i] for p in prefix)
        else:
            lp = prefix["rest"][i]
        p = params["dec"]["rest"][i]

        def fn(p_, x_, lc_, lp_, _spec=spec):
            from repro.distributed.hints import constrain
            x2, nc_, a_ = apply_layer(p_, _spec, cfg, x_, lc_, ctx, lp_)
            return constrain(x2, "layer_boundary"), nc_, a_
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x, nc, a = fn(p, x, lc, lp)
        aux = aux + a
        if new_cache is not None:
            new_cache.setdefault("rest", []).append(nc)
    return x, new_cache, aux


def run_encoder(params: dict, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: [B, T_enc, F] stubbed frontend embeddings -> [B, T_enc, D]."""
    x = linear(frames, params["frontend_proj"]) if "frontend_proj" in params \
        else frames
    enc_spec = LayerSpec(mixer=ATTN, ffn=MLP, cross_attn=False)
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    ctx = {"positions": positions, "causal": False}
    body = _group_body(cfg, (enc_spec,), ctx)
    if cfg.remat:
        body = jax.checkpoint(body)
    (x, _), _ = jax.lax.scan(
        lambda c, p: (body((c[0], c[1]), (p, None, None))[0], None),
        (x, jnp.zeros((), jnp.float32)), params["enc"]["groups"])
    return rms_norm(x, params["enc"]["norm"], cfg.norm_eps)


def embed_tokens(params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return params["embed"][tokens]


def project_frontend(params: dict, embeds: jnp.ndarray) -> jnp.ndarray:
    """Project stubbed modality embeddings [B, T, F] to d_model."""
    if "frontend_proj" in params:
        return linear(embeds, params["frontend_proj"])
    return embeds


def unembed(params: dict, cfg: ModelConfig, hidden: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(hidden, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jax.lax.dot_general(
        h, w, (((h.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def forward(params: dict, cfg: ModelConfig, embeds: jnp.ndarray,
            positions: jnp.ndarray, cache: Optional[dict] = None,
            enc: Optional[jnp.ndarray] = None,
            valid: Optional[jnp.ndarray] = None, ring: bool = False,
            prefix: Optional[dict] = None, slot_offset=0,
            prefix_pages: Optional[jnp.ndarray] = None,
            suffix_pages: Optional[jnp.ndarray] = None,
            fused: bool = True,
            prefix_offsets: Optional[jnp.ndarray] = None,
            prefix_skips: Optional[jnp.ndarray] = None):
    """Run the decoder stack in any serving mode.

    embeds: [B, T, D] already-embedded inputs; positions: [B, T]
    absolute token positions.  Returns (hidden [B, T, D], new_cache,
    aux_loss).

    Dense split prefix/suffix serving (DESIGN.md §5): pass the batch-1
    shared prefix state as ``prefix`` (read-only) and the prefix length
    as ``slot_offset``; ``cache`` is then the suffix-only cache and
    suffix token P+i is stored at slot i while keeping absolute
    positions.

    Paged serving (DESIGN.md §8): ``cache`` is the block arena
    (``init_block_arena``), ``prefix_pages`` [B, NBP] maps each row to
    its cluster's shared prefix blocks, ``suffix_pages`` [B, NBS] to
    its private suffix blocks, and ``slot_offset`` is per-row [B] (each
    cluster's own prefix length).  One batch mixes members of any
    number of clusters — sharing is a page-table fact, not a tensor
    layout.

    Segment composition (DESIGN.md §14): ``prefix_offsets`` /
    ``prefix_skips`` [Bp, NBP] give each prefix block a read-time
    position delta and a leading-slot skip count — how a segment cached
    at one base position serves a prompt that splices it elsewhere.
    """
    ctx = {"positions": positions, "valid": valid, "ring": ring,
           "enc": enc, "causal": True, "slot_offset": slot_offset,
           "prefix_pages": prefix_pages, "suffix_pages": suffix_pages,
           "fused": fused, "prefix_offsets": prefix_offsets,
           "prefix_skips": prefix_skips}
    return run_stack(params, cfg, embeds, cache, ctx, prefix=prefix)


# ======================================================================
# losses / steps
# ======================================================================
def lm_loss(params: dict, cfg: ModelConfig, logits: jnp.ndarray,
            labels: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """logits [B,T,V] fp32; labels [B,T]; mask [B,T] (1 = contributes).

    Sharding-friendly cross entropy: the label logit is extracted with a
    one-hot contraction (XLA fuses the one-hot; GSPMD turns the
    vocab-sharded reduction into a small all-reduce) instead of
    ``take_along_axis``, which would all-gather the vocab-sharded logits.
    """
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.einsum("btv,btv->bt", logits, onehot)
    ll = label_logit - lse
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return -jnp.sum(ll * mask) / denom


def train_loss(params: dict, cfg: ModelConfig, batch: dict,
               aux_weight: float = 0.01) -> jnp.ndarray:
    """batch: tokens [B,T] (+ optional enc_frames / img_embeds), labels, mask."""
    tokens = batch["tokens"]
    b, t = tokens.shape
    x = embed_tokens(params, tokens)
    enc = None
    if cfg.is_encdec:
        enc = run_encoder(params, cfg, batch["enc_frames"])
    elif cfg.num_image_tokens:
        img = project_frontend(params, batch["img_embeds"])
        enc = img
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    hidden, _, aux = forward(params, cfg, x, positions, enc=enc)
    logits = unembed(params, cfg, hidden)
    loss = lm_loss(params, cfg, logits, batch["labels"], batch["mask"])
    return loss + aux_weight * aux
