"""Attention: GQA self-attention with a unified fixed-capacity KV cache.

Cache semantics (one mechanism covers full attention, sliding-window,
local attention, prefix reuse and ring-buffer long-context decode):

  cache = {"k": [B, Hkv, C, D], "v": [B, Hkv, C, D], "pos": [B, C]}

``pos`` holds the absolute token position stored in each slot, ``-1``
meaning empty.  Keys are RoPE-rotated *at write time* with their absolute
position, so slot order inside the buffer is irrelevant — masking is done
purely on position values.  This makes SubGCache prefix reuse, sliding
windows and wrap-around decode all the same code path.

All masking is positional:
  valid(k)   = k_pos >= 0
  causal     = k_pos <= q_pos
  window(w)  = q_pos - k_pos < w
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, linear

NEG_INF = -1e30


# ----------------------------------------------------------------------
# params
# ----------------------------------------------------------------------
def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype, use_bias: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d_model, num_heads * head_dim, dtype),
        "wk": dense_init(kk, d_model, num_kv_heads * head_dim, dtype),
        "wv": dense_init(kv, d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ko, num_heads * head_dim, d_model, dtype),
    }
    if use_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    return p


def init_kv_cache(batch: int, num_kv_heads: int, capacity: int, head_dim: int,
                  dtype) -> dict:
    """KV cache in write-friendly [B, C, Hkv, D] layout.

    Perf iteration (EXPERIMENTS.md §Perf, decode pair): projected K/V
    arrive as [B, T, H*D]; storing the cache seq-major removes the
    transpose+copy pair that XLA otherwise inserts on every cache update
    (the dominant decode byte traffic after the irreducible KV read)."""
    return {
        "k": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
    }


# ----------------------------------------------------------------------
# core attend
# ----------------------------------------------------------------------
ATTEND_CHUNK = 512       # q-block size for the chunked XLA path
ATTEND_CHUNK_MIN_T = 2048  # chunk only long sequences
UNROLL_CHUNKS = False  # dry-run sets True: exact HLO flop accounting
SCORES_BF16 = False    # store attention probs bf16 (perf-iteration knob;
                       # softmax math stays f32)


def _attend_block(qg, k, v, q_pos, k_pos, *, causal, window, scale):
    """qg: [B, Hkv, G, Tq, D]; k, v: [B, Tk, Hkv, D] (seq-major cache)."""
    scores = jnp.einsum("bhgtd,bshd->bhgts", qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask = k_pos[:, None, :] >= 0                              # [B, 1, Tk]
    if causal:
        mask = mask & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        mask = mask & (q_pos[:, :, None] - k_pos[:, None, :] < window)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    ex = jnp.exp(scores - m)
    if SCORES_BF16:
        ex = ex.astype(jnp.bfloat16)
    denom = jnp.sum(ex.astype(jnp.float32), axis=-1, keepdims=True)
    probs = (ex.astype(jnp.float32) / denom)
    return jnp.einsum("bhgts,bshd->bhgtd", probs.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           q_pos: jnp.ndarray, k_pos: jnp.ndarray,
           *, causal: bool, window: int = 0) -> jnp.ndarray:
    """Masked GQA attention.

    q: [B, Hq, Tq, D]; k, v: [B, Tk, Hkv, D]; q_pos: [B, Tq]; k_pos: [B, Tk].

    Long queries are processed in q-blocks (flash-style chunking on the
    XLA path) so the [Tq, Tk] score matrix never fully materializes —
    this is what makes the 4k/32k shapes fit HBM without the Pallas
    kernel (which is the TPU-target fast path).
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, tq, d)
    scale = d ** -0.5

    if tq >= ATTEND_CHUNK_MIN_T and tq % ATTEND_CHUNK == 0:
        nc = tq // ATTEND_CHUNK
        qc = jnp.moveaxis(
            qg.reshape(b, hkv, g, nc, ATTEND_CHUNK, d), 3, 0)   # [nc,B,H,G,c,D]
        pc = jnp.moveaxis(
            q_pos.reshape(b, nc, ATTEND_CHUNK), 1, 0)           # [nc,B,c]

        def one(args):
            qi, pi = args
            return _attend_block(qi, k, v, pi, k_pos, causal=causal,
                                 window=window, scale=scale)

        if UNROLL_CHUNKS:
            out = jnp.stack([one((qc[i], pc[i])) for i in range(nc)])
        else:
            out = jax.lax.map(one, (qc, pc))                    # [nc,B,H,G,c,D]
        out = jnp.moveaxis(out, 0, 3).reshape(b, hkv, g, tq, d)
    else:
        out = _attend_block(qg, k, v, q_pos, k_pos, causal=causal,
                            window=window, scale=scale)
    return out.reshape(b, hq, tq, d).astype(q.dtype)


def cache_write(cache: dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                positions: jnp.ndarray, *, ring: bool,
                valid: Optional[jnp.ndarray] = None) -> dict:
    """Write [B,T,Hkv,D] keys/values at absolute ``positions`` [B, T].

    Seq-major cache layout: the write is a pure scatter on dim 1 with no
    transpose (decode perf iteration, EXPERIMENTS.md §Perf).
    ``ring=False``: contiguous write at slot = positions (requires
    positions < capacity; used for prefill / suffix prefill).
    ``ring=True``: slot = positions % capacity (long-context decode).
    ``valid`` [B, T]: padded entries get pos = -1 (masked forever).
    """
    cap = cache["k"].shape[1]
    slots = positions % cap if ring else positions             # [B, T]
    b_idx = jnp.arange(cache["k"].shape[0])[:, None]           # [B, 1]
    k = cache["k"].at[b_idx, slots].set(
        k_new.astype(cache["k"].dtype))
    v = cache["v"].at[b_idx, slots].set(
        v_new.astype(cache["v"].dtype))
    written = positions if valid is None else jnp.where(valid, positions, -1)
    pos = cache["pos"].at[b_idx, slots].set(written)
    return {"k": k, "v": v, "pos": pos}


# ----------------------------------------------------------------------
# self attention layer
# ----------------------------------------------------------------------
def self_attention(p: dict, x: jnp.ndarray, *, num_heads: int,
                   num_kv_heads: int, head_dim: int, rope_theta: float,
                   positions: jnp.ndarray, cache: Optional[dict] = None,
                   causal: bool = True, window: int = 0,
                   ring: bool = False, valid: Optional[jnp.ndarray] = None,
                   impl: str = "xla"):
    """x: [B, T, D_model]; positions: [B, T] absolute positions.

    Returns (out [B, T, D_model], new_cache or None).
    ``impl="pallas"`` routes attention through the Pallas kernels
    (prefix_attention / decode_gqa); "xla" uses the jnp reference path.
    """
    if impl == "pallas":
        from repro.kernels import ops as kops

        def _attend(q_, k_, v_, qp_, kp_):
            # kernels take head-major K/V; cache is seq-major
            k_ = k_.transpose(0, 2, 1, 3)
            v_ = v_.transpose(0, 2, 1, 3)
            if q_.shape[2] == 1:        # decode: 1 token vs long cache
                out_ = kops.decode_gqa(q_[:, :, 0], k_, v_, qp_[:, 0], kp_,
                                       window=window)
                return out_[:, :, None]
            return kops.prefix_attention(q_, k_, v_, qp_, kp_,
                                         causal=causal, window=window)
    else:
        def _attend(q_, k_, v_, qp_, kp_):
            return attend(q_, k_, v_, qp_, kp_, causal=causal, window=window)
    b, t, _ = x.shape
    q = linear(x, p["wq"])
    k = linear(x, p["wk"])
    v = linear(x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # q head-major for the MXU attention; k/v stay seq-major (cache layout)
    q = q.reshape(b, t, num_heads, head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, num_kv_heads, head_dim)
    v = v.reshape(b, t, num_kv_heads, head_dim)
    q = apply_rope(q, positions[:, None, :], rope_theta)
    k = apply_rope(k, positions[:, :, None], rope_theta)

    if cache is None:
        self_pos = positions if valid is None else jnp.where(valid, positions, -1)
        out = _attend(q, k, v, positions, self_pos)
        new_cache = None
    elif window and t > 1:
        # Windowed multi-token (prefill / suffix prefill): the ring buffer
        # cannot hold T > capacity fresh tokens at once, so attend over
        # [cached prefix ++ fresh self-KV] and ring-write only the tail.
        cap = cache["k"].shape[1]
        self_pos = positions if valid is None else jnp.where(valid, positions, -1)
        k_all = jnp.concatenate([cache["k"], k.astype(cache["k"].dtype)], axis=1)
        v_all = jnp.concatenate([cache["v"], v.astype(cache["v"].dtype)], axis=1)
        pos_all = jnp.concatenate([cache["pos"], self_pos], axis=1)
        out = _attend(q, k_all, v_all, positions, pos_all)
        tail = min(t, cap)
        new_cache = cache_write(
            cache, k[:, t - tail:], v[:, t - tail:],
            positions[:, t - tail:], ring=True,
            valid=None if valid is None else valid[:, t - tail:])
    else:
        ring_eff = ring or bool(window)
        new_cache = cache_write(cache, k, v, positions, ring=ring_eff,
                                valid=valid)
        out = _attend(q, new_cache["k"], new_cache["v"], positions,
                      new_cache["pos"])
    out = out.transpose(0, 2, 1, 3).reshape(b, t, num_heads * head_dim)
    return linear(out, p["wo"]), new_cache


# ----------------------------------------------------------------------
# cross attention (enc-dec decoder / VLM image layers)
# ----------------------------------------------------------------------
def init_cross_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                         head_dim: int, dtype) -> dict:
    return init_attention(key, d_model, num_heads, num_kv_heads, head_dim, dtype)


def cross_attention_kv(p: dict, enc: jnp.ndarray, *, num_kv_heads: int,
                       head_dim: int):
    """Project encoder states once; reusable across all decode steps.
    Seq-major layout [B, S, Hkv, D], matching the self-attention cache."""
    b, s, _ = enc.shape
    k = linear(enc, p["wk"]).reshape(b, s, num_kv_heads, head_dim)
    v = linear(enc, p["wv"]).reshape(b, s, num_kv_heads, head_dim)
    return k, v


def cross_attention(p: dict, x: jnp.ndarray, enc_kv, *, num_heads: int,
                    num_kv_heads: int, head_dim: int):
    """x: [B, T, D]; enc_kv: (k, v) each [B, S, Hkv, D]."""
    b, t, _ = x.shape
    k, v = enc_kv
    q = linear(x, p["wq"]).reshape(b, t, num_heads, head_dim).transpose(0, 2, 1, 3)
    s = k.shape[1]
    q_pos = jnp.zeros((b, t), jnp.int32)
    k_pos = jnp.zeros((b, s), jnp.int32)
    out = attend(q, k, v, q_pos, k_pos, causal=False)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, num_heads * head_dim)
    return linear(out, p["wo"])
