"""Attention: GQA self-attention with a unified fixed-capacity KV cache.

Cache semantics (one mechanism covers full attention, sliding-window,
local attention, prefix reuse and ring-buffer long-context decode):

  cache = {"k": [B, Hkv, C, D], "v": [B, Hkv, C, D], "pos": [B, C]}

``pos`` holds the absolute token position stored in each slot, ``-1``
meaning empty.  Keys are stored CANONICAL (un-rotated); every read path
applies the RoPE rotation at its *effective* positions just before the
score matmul (DESIGN.md §14).  For the chain path the effective position
is simply the stored position — bitwise what write-time rotation used to
produce, because ``apply_rope`` rounds back to the cache dtype — while
segment COMPOSITION adds a per-prefix-block position offset (a segment
cached at base position P can be spliced at target offset T by rotating
at ``stored_pos + (T - P)``).  Slot order inside the buffer stays
irrelevant — masking is done purely on position values — which keeps
SubGCache prefix reuse, sliding windows, wrap-around decode and spliced
segments all the same code path.

All masking is positional:
  valid(k)   = k_pos >= 0
  causal     = k_pos <= q_pos
  window(w)  = q_pos - k_pos < w
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, linear

NEG_INF = -1e30


# ----------------------------------------------------------------------
# params
# ----------------------------------------------------------------------
def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype, use_bias: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d_model, num_heads * head_dim, dtype),
        "wk": dense_init(kk, d_model, num_kv_heads * head_dim, dtype),
        "wv": dense_init(kv, d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ko, num_heads * head_dim, d_model, dtype),
    }
    if use_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    return p


def init_kv_cache(batch: int, num_kv_heads: int, capacity: int, head_dim: int,
                  dtype) -> dict:
    """KV cache in write-friendly [B, C, Hkv, D] layout.

    Perf iteration (EXPERIMENTS.md §Perf, decode pair): projected K/V
    arrive as [B, T, H*D]; storing the cache seq-major removes the
    transpose+copy pair that XLA otherwise inserts on every cache update
    (the dominant decode byte traffic after the irreducible KV read)."""
    return {
        "k": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
    }




# ----------------------------------------------------------------------
# core attend
# ----------------------------------------------------------------------
ATTEND_CHUNK = 512       # q-block size for the chunked XLA path
ATTEND_CHUNK_MIN_T = 2048  # chunk only long sequences
UNROLL_CHUNKS = False  # dry-run sets True: exact HLO flop accounting
SCORES_BF16 = False    # store attention probs bf16 (perf-iteration knob;
                       # softmax math stays f32)


def _attend_block(qg, k, v, q_pos, k_pos, *, causal, window, scale):
    """qg: [B, Hkv, G, Tq, D]; k, v: [B, Tk, Hkv, D] (seq-major cache)."""
    scores = jnp.einsum("bhgtd,bshd->bhgts", qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask = k_pos[:, None, :] >= 0                              # [B, 1, Tk]
    if causal:
        mask = mask & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        mask = mask & (q_pos[:, :, None] - k_pos[:, None, :] < window)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    ex = jnp.exp(scores - m)
    if SCORES_BF16:
        ex = ex.astype(jnp.bfloat16)
    denom = jnp.sum(ex.astype(jnp.float32), axis=-1, keepdims=True)
    probs = (ex.astype(jnp.float32) / denom)
    return jnp.einsum("bhgts,bshd->bhgtd", probs.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           q_pos: jnp.ndarray, k_pos: jnp.ndarray,
           *, causal: bool, window: int = 0) -> jnp.ndarray:
    """Masked GQA attention.

    q: [B, Hq, Tq, D]; k, v: [B, Tk, Hkv, D]; q_pos: [B, Tq]; k_pos: [B, Tk].

    Long queries are processed in q-blocks (flash-style chunking on the
    XLA path) so the [Tq, Tk] score matrix never fully materializes —
    this is what makes the 4k/32k shapes fit HBM without the Pallas
    kernel (which is the TPU-target fast path).
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, tq, d)
    scale = d ** -0.5

    if tq >= ATTEND_CHUNK_MIN_T and tq % ATTEND_CHUNK == 0:
        out = _map_q_chunks(
            lambda qi, pi: _attend_block(qi, k, v, pi, k_pos, causal=causal,
                                         window=window, scale=scale),
            qg, q_pos)                                          # [nc,B,H,G,c,D]
        out = jnp.moveaxis(out, 0, 3).reshape(b, hkv, g, tq, d)
    else:
        out = _attend_block(qg, k, v, q_pos, k_pos, causal=causal,
                            window=window, scale=scale)
    return out.reshape(b, hq, tq, d).astype(q.dtype)


def _map_q_chunks(block_fn, qg, q_pos):
    """Apply ``block_fn(q_chunk [B,Hkv,G,c,D], q_pos_chunk [B,c])`` over
    ATTEND_CHUNK-sized q-blocks; returns the stacked result pytree with
    a leading chunk dim.  Honors the ``UNROLL_CHUNKS`` dry-run knob
    (exact HLO flop accounting) for every chunked attention variant."""
    b, hkv, g, tq, d = qg.shape
    nc = tq // ATTEND_CHUNK
    qc = jnp.moveaxis(
        qg.reshape(b, hkv, g, nc, ATTEND_CHUNK, d), 3, 0)       # [nc,B,H,G,c,D]
    pc = jnp.moveaxis(q_pos.reshape(b, nc, ATTEND_CHUNK), 1, 0)  # [nc,B,c]

    def one(args):
        return block_fn(args[0], args[1])

    if UNROLL_CHUNKS:
        outs = [one((qc[i], pc[i])) for i in range(nc)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return jax.lax.map(one, (qc, pc))


# ----------------------------------------------------------------------
# shared-prefix cascade attention (split prefix/suffix cache, DESIGN.md §5)
# ----------------------------------------------------------------------
def _attend_partial_block(qg, k, v, q_pos, k_pos, *, causal, window, scale):
    """qg: [B, Hkv, G, Tq, D]; k, v: [Bk, Tk, Hkv, D] seq-major."""
    b, hkv, g, tq, d = qg.shape
    bk = k.shape[0]
    if bk == 1:
        scores = jnp.einsum("bhgtd,shd->bhgts", qg, k[0],
                            preferred_element_type=jnp.float32) * scale
    else:
        scores = jnp.einsum("bhgtd,bshd->bhgts", qg, k,
                            preferred_element_type=jnp.float32) * scale
    mask = k_pos[:, None, :] >= 0                              # [Bk, 1, Tk]
    if causal:
        mask = mask & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        mask = mask & (q_pos[:, :, None] - k_pos[:, None, :] < window)
    mask = jnp.broadcast_to(mask[:, None, None, :, :], scores.shape)
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1)                               # [B,Hkv,G,Tq]
    p = jnp.where(mask, jnp.exp(scores - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    # probs follow _attend_block's PV-input precision convention (cast to
    # v.dtype, f32 accumulation) so XLA split == XLA broadcast at any
    # model dtype; the partial stats (out/m/l) themselves stay f32.
    if bk == 1:
        out = jnp.einsum("bhgts,shd->bhgtd", p.astype(v.dtype), v[0],
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bhgts,bshd->bhgtd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
    out = out / jnp.where(l > 0, l, 1.0)[..., None]
    return (out.reshape(b, hkv * g, tq, d), m.reshape(b, hkv * g, tq),
            l.reshape(b, hkv * g, tq))


def attend_partial(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                   *, causal: bool, window: int = 0):
    """Masked GQA attention in partial (online-softmax) form — XLA path.

    q: [B, Hq, Tq, D]; k, v: [Bk, Tk, Hkv, D] seq-major with ``Bk in
    (1, B)``.  ``Bk == 1`` is the shared-prefix case: the einsum carries
    no member batch dim on the KV side, so XLA reads the prefix KV once
    per kv-head group instead of once per member.

    Long queries are processed in q-blocks (same flash-style chunking
    and thresholds as ``attend``) so the [Tq, Tk] score tensor never
    fully materializes; the partials are per-query-row, so chunks are
    independent.

    Returns ``(out [B,Hq,Tq,D] f32 normalized, m [B,Hq,Tq], l
    [B,Hq,Tq])``; fully-masked rows give out=0, m=NEG_INF, l=0 which
    ``merge_attend`` treats as "no mass".
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, tq, d)
    scale = d ** -0.5

    if tq >= ATTEND_CHUNK_MIN_T and tq % ATTEND_CHUNK == 0:
        o, m, l = _map_q_chunks(
            lambda qi, pi: _attend_partial_block(
                qi, k, v, pi, k_pos, causal=causal, window=window,
                scale=scale),
            qg, q_pos)                                          # [nc,B,Hq,c,*]
        out = jnp.moveaxis(o, 0, 2).reshape(b, hq, tq, d)
        return (out, jnp.moveaxis(m, 0, 2).reshape(b, hq, tq),
                jnp.moveaxis(l, 0, 2).reshape(b, hq, tq))
    return _attend_partial_block(qg, k, v, q_pos, k_pos, causal=causal,
                                 window=window, scale=scale)


def merge_attend(o1, m1, l1, o2, m2, l2):
    """Exact LSE-merge of two attention partials over disjoint key sets:
    softmax over [keys1 ++ keys2] == merge(partial1, partial2).

    Delegates to the kernel oracle so there is exactly one copy of the
    exactness-critical merge math (the Pallas merge kernel is tested
    against the same function)."""
    from repro.kernels.ref import merge_partials_ref
    return merge_partials_ref(o1, m1, l1, o2, m2, l2)


def fold_attend(partials):
    """Associative N-way LSE fold over disjoint key sets — the chain
    cascade (DESIGN.md §10).  Delegates to the kernel oracle."""
    from repro.kernels.ref import fold_partials_ref
    return fold_partials_ref(partials)


def attend_shared(q: jnp.ndarray, q_pos: jnp.ndarray, prefix,
                  k_suf: jnp.ndarray, v_suf: jnp.ndarray,
                  suf_pos: jnp.ndarray, *, window: int = 0,
                  impl: str = "xla",
                  rope_theta: Optional[float] = None) -> jnp.ndarray:
    """Cascade attention over [shared prefix chain ++ per-member suffix].

    q: [B, Hq, Tq, D]; prefix: a {"k","v","pos"} seq-major batch-1
    cache (the live PrefixState buffers, unreplicated) OR a sequence of
    them — a prefix CHAIN in root→leaf order, one partial per segment
    folded by the associative LSE merge (DESIGN.md §10; a 1-tuple is
    exactly the historical 2-level cascade).  k_suf, v_suf:
    [B, Ts, Hkv, D]; suf_pos: [B, Ts].  The prefix side needs no causal
    mask — every cached prefix position is strictly past every query —
    so only validity (pos >= 0) and the optional sliding window apply.
    Numerically exact vs. attending the concatenated KV.

    This is the DENSE cascade (shared prefix segments at batch 1).
    Multi-prefix batches go through the paged path instead
    (``attend_paged``, DESIGN.md §8), where every row walks its own
    page table over the block arena — a chain there is simply a wider
    (concatenated) page walk.
    """
    segments = (tuple(prefix) if isinstance(prefix, (list, tuple))
                else (prefix,))

    def rot(kk, kp):
        # Canonical-K storage: rotate at the stored positions just before
        # attending.  ``apply_rope`` rounds back to the cache dtype, so
        # this is bitwise what write-time rotation used to store.
        if rope_theta is None:
            return kk
        return apply_rope(kk, kp[:, :, None], rope_theta)

    if impl == "pallas":
        from repro.kernels import ops as kops
        sk = rot(k_suf, suf_pos).transpose(0, 2, 1, 3)  # head-major for MXU
        sv = v_suf.transpose(0, 2, 1, 3)
        if q.shape[2] == 1:
            # decode: keep the decode-shaped [group, d] q tiling (one KV
            # stream per kv-head group) instead of 1-row prefill tiles;
            # the elementwise fold stays in XLA (fuses, nothing to tile)
            parts = [kops.decode_gqa_partial(
                q[:, :, 0], rot(p["k"], p["pos"]).transpose(0, 2, 1, 3),
                p["v"].transpose(0, 2, 1, 3), q_pos[:, 0], p["pos"],
                window=window) for p in segments]
            parts.append(kops.decode_gqa_partial(
                q[:, :, 0], sk, sv, q_pos[:, 0], suf_pos, window=window))
            out, _, _ = fold_attend(parts)
            return out[:, :, None].astype(q.dtype)
        parts = [kops.attention_partial(
            q, rot(p["k"], p["pos"]).transpose(0, 2, 1, 3),
            p["v"].transpose(0, 2, 1, 3),
            q_pos, p["pos"], causal=False, window=window)
            for p in segments]
        parts.append(kops.attention_partial(q, sk, sv, q_pos, suf_pos,
                                            causal=True, window=window))
        out, _, _ = kops.fold_partials(parts)
        return out.astype(q.dtype)
    parts = [attend_partial(q, rot(p["k"], p["pos"]), p["v"], q_pos,
                            p["pos"], causal=False, window=window)
             for p in segments]
    parts.append(attend_partial(q, rot(k_suf, suf_pos), v_suf, q_pos,
                                suf_pos, causal=True, window=window))
    out, _, _ = fold_attend(parts)
    return out.astype(q.dtype)


def attend_paged(q: jnp.ndarray, q_pos: jnp.ndarray,
                 prefix_arena: dict, prefix_pages: jnp.ndarray,
                 suffix_arena: dict, suffix_pages: jnp.ndarray,
                 *, window: int = 0, impl: str = "xla",
                 fused: bool = True,
                 rope_theta: Optional[float] = None,
                 prefix_offsets: Optional[jnp.ndarray] = None,
                 prefix_skips: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Cascade attention over a paged KV arena (DESIGN.md §8, §11).

    q: [B, Hq, Tq, D]; prefix_arena / suffix_arena: {"k","v","pos"}
    block-arena leaves (k/v [NB, bs, Hkv, D] seq-major, pos [NB, bs]);
    prefix_pages / suffix_pages: [B or 1, NBP] / [B, NBS] int32 page
    tables (NULL-block padded; a [1, NBP] prefix table is the shared
    walk).  Row ``b`` attends the concatenation of its prefix blocks
    (shared by every member of its cluster — the same physical rows,
    never replicated) and its private suffix blocks.  The prefix side
    needs no causal mask (every prefix position precedes every query);
    the suffix side is causal; the LSE merge makes the cascade exact.
    Rows with an all-NULL prefix table (no cached prefix) degrade to
    pure suffix attention — the masked prefix partial carries no mass.

    ``prefix_arena`` may be a QUANTIZED arena (``KVBlockPool.qarena``):
    int8 k/v plus per-(block, kv-head) f32 ``k_scale``/``v_scale``
    leaves.  Every path dequantizes before use — the fused Pallas
    kernel in-register right after the tile DMA, the others densely.
    The suffix arena is always compute dtype (decode writes it).

    ``fused=True`` (the default) routes the PALLAS branch to the
    single-pass cascade kernels (``kernels/fused_cascade.py``): one
    launch walks both page tables carrying the (o, m, l) accumulator in
    VMEM, instead of one partial launch per segment plus an LSE fold.
    The XLA branch ignores the flag — its "fused" composition IS the
    multi-launch cascade, so on XLA fused and multi-launch are
    bitwise-identical by construction; the Pallas single-pass kernel
    renormalizes incrementally (same keys, same order, different
    rounding) and is gated by oracle-allclose + greedy-token identity.

    The two arenas are usually the SAME object (prefill: one address
    space).  Decode passes the prefix source (main arena, or the int8
    arena when quantizing) as ``prefix_arena`` (a scan invariant —
    prefix blocks are read-only during decode) and a compact extraction
    of the batch's suffix blocks as ``suffix_arena`` (the only blocks
    decode writes; carrying the full arena through the scan would copy
    it per step on backends where donation cannot alias).

    The Pallas path walks the page tables with one-block-per-grid-step
    scalar-prefetch DMA; the XLA path gathers the blocks (exact, and
    what CPU validation runs).

    CANONICAL-K / COMPOSITION (DESIGN.md §14): arenas store un-rotated
    keys; ``rope_theta`` (the serving path always passes it) enables
    read-time rotation at each block's effective positions.
    ``prefix_offsets`` [Bp, NBP] adds a per-prefix-block position delta
    (segment spliced at a new target offset) and ``prefix_skips``
    [Bp, NBP] masks the first N slots of a block (boundary tokens
    recomputed into the suffix stream shadow the cached copies).  With
    ``rope_theta`` set, the prefix partial is CAUSAL on effective
    positions — vacuous for the chain layout (every prefix position
    precedes every query) and required for compositions, where fresh
    gap tokens interleave with cached segment positions.  Legacy calls
    without ``rope_theta`` keep the historical pre-rotated semantics.
    """
    k_scale = prefix_arena.get("k_scale")
    v_scale = prefix_arena.get("v_scale")
    p_causal = rope_theta is not None
    if impl == "pallas":
        from repro.kernels import ops as kops
        pka = prefix_arena["k"].transpose(0, 2, 1, 3)  # head-major (MXU)
        pva = prefix_arena["v"].transpose(0, 2, 1, 3)
        ska = suffix_arena["k"].transpose(0, 2, 1, 3)
        sva = suffix_arena["v"].transpose(0, 2, 1, 3)
        ppos, spos = prefix_arena["pos"], suffix_arena["pos"]
        if fused:
            if q.shape[2] == 1:
                out = kops.fused_paged_decode_gqa(
                    q[:, :, 0], pka, pva, ska, sva, q_pos[:, 0], ppos,
                    spos, prefix_pages, suffix_pages, k_scale, v_scale,
                    window=window, rope_theta=rope_theta,
                    p_off=prefix_offsets, p_skip=prefix_skips)
                return out[:, :, None].astype(q.dtype)
            out = kops.fused_paged_attention(
                q, pka, pva, ska, sva, q_pos, ppos, spos, prefix_pages,
                suffix_pages, k_scale, v_scale, window=window,
                rope_theta=rope_theta, p_off=prefix_offsets,
                p_skip=prefix_skips, prefix_causal=p_causal)
            return out.astype(q.dtype)
        if prefix_offsets is not None or prefix_skips is not None:
            raise NotImplementedError(
                "segment composition needs fused=True or impl='xla'")
        if k_scale is not None:     # multi-launch kernels read raw tiles:
            pka = pka.astype(jnp.float32) * k_scale[:, :, None, None]
            pva = pva.astype(jnp.float32) * v_scale[:, :, None, None]
        if rope_theta is not None:
            # Multi-launch kernels read raw tiles: rotate the whole arena
            # densely (offset-0 chain layout only; CPU-validation path).
            pka = apply_rope(pka, ppos[:, None, :], rope_theta)
            ska = apply_rope(ska, spos[:, None, :], rope_theta)
        if q.shape[2] == 1:
            o1, m1, l1 = kops.paged_decode_gqa_partial(
                q[:, :, 0], pka, pva, q_pos[:, 0], ppos, prefix_pages,
                window=window)
            o2, m2, l2 = kops.paged_decode_gqa_partial(
                q[:, :, 0], ska, sva, q_pos[:, 0], spos, suffix_pages,
                window=window)
            out, _, _ = merge_attend(o1, m1, l1, o2, m2, l2)
            return out[:, :, None].astype(q.dtype)
        o1, m1, l1 = kops.paged_attention_partial(
            q, pka, pva, q_pos, ppos, prefix_pages, causal=p_causal,
            window=window)
        o2, m2, l2 = kops.paged_attention_partial(
            q, ska, sva, q_pos, spos, suffix_pages, causal=True,
            window=window)
        out, _, _ = merge_attend(o1, m1, l1, o2, m2, l2)
        return out.astype(q.dtype)

    def gathered(arena, pages, offsets=None, skips=None):
        kk = arena["k"][pages]                     # [Bk, W, bs, Hkv, D]
        bk, w, bs, hkv, d = kk.shape
        vv = arena["v"][pages]
        if "k_scale" in arena:                     # int8 prefix arena
            ks = arena["k_scale"][pages]           # [Bk, W, Hkv]
            kk = kk.astype(jnp.float32) * ks[:, :, None, :, None]
            vv = vv.astype(jnp.float32) * \
                arena["v_scale"][pages][:, :, None, :, None]
        kk = kk.reshape(bk, w * bs, hkv, d)
        vv = vv.reshape(bk, w * bs, hkv, d)
        pp = arena["pos"][pages].reshape(bk, w * bs)
        if offsets is not None:                    # composition: splice
            off = jnp.repeat(offsets.astype(jnp.int32), bs, axis=1)
            pp = jnp.where(pp >= 0, pp + off, -1)  # effective positions
        if skips is not None:                      # boundary recompute
            slot = jnp.tile(jnp.arange(bs, dtype=jnp.int32), w)[None]
            skip = jnp.repeat(skips.astype(jnp.int32), bs, axis=1)
            pp = jnp.where(slot < skip, -1, pp)
        if rope_theta is not None:
            kk = apply_rope(kk, pp[:, :, None], rope_theta)
        return kk, vv, pp

    pk, pv, pp = gathered(prefix_arena, prefix_pages, prefix_offsets,
                          prefix_skips)
    sk, sv, sp = gathered(suffix_arena, suffix_pages)
    o1, m1, l1 = attend_partial(q, pk, pv, q_pos, pp, causal=p_causal,
                                window=window)
    o2, m2, l2 = attend_partial(q, sk, sv, q_pos, sp, causal=True,
                                window=window)
    out, _, _ = merge_attend(o1, m1, l1, o2, m2, l2)
    return out.astype(q.dtype)


def cache_write(cache: dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                positions: jnp.ndarray, *, ring: bool,
                valid: Optional[jnp.ndarray] = None,
                slot_offset=0,
                keep: Optional[jnp.ndarray] = None) -> dict:
    """Write [B,T,Hkv,D] keys/values at absolute ``positions`` [B, T].

    Seq-major cache layout: the write is a pure scatter on dim 1 with no
    transpose (decode perf iteration, EXPERIMENTS.md §Perf).
    ``ring=False``: contiguous write at slot = positions - slot_offset
    (requires that to be < capacity; used for prefill / suffix prefill).
    ``ring=True``: slot = (positions - slot_offset) % capacity
    (long-context decode).
    ``valid`` [B, T]: padded entries get pos = -1 (masked forever).
    ``slot_offset``: subtracted from positions to get the slot index —
    the split prefix/suffix cache stores suffix token P+i at slot i
    (DESIGN.md §5) while ``pos`` keeps the absolute position, so all
    masking stays purely positional.  A scalar applies to every row; a
    [B] array gives each row its own offset (multi-prefix serving, where
    members of different clusters sit behind different prefix lengths).
    ``keep`` [B, T]: entries marked False are not written AT ALL (their
    slot keeps its previous contents) — ring writes of right-padded
    blocks must drop padding instead of landing it in a wrapped slot
    that a kept token or a still-in-window entry owns.
    """
    cap = cache["k"].shape[1]
    off = jnp.asarray(slot_offset)
    if off.ndim == 1:
        off = off[:, None]                                     # [B, 1]
    rel = positions - off
    slots = rel % cap if ring else rel                         # [B, T]
    b_idx = jnp.arange(cache["k"].shape[0])[:, None]           # [B, 1]
    if keep is not None:
        if valid is not None:
            keep = keep & valid          # never land padding as live keys
        slots = jnp.where(keep, slots, cap)                    # OOB -> drop
        k = cache["k"].at[b_idx, slots].set(
            k_new.astype(cache["k"].dtype), mode="drop")
        v = cache["v"].at[b_idx, slots].set(
            v_new.astype(cache["v"].dtype), mode="drop")
        pos = cache["pos"].at[b_idx, slots].set(positions, mode="drop")
        return {"k": k, "v": v, "pos": pos}
    k = cache["k"].at[b_idx, slots].set(
        k_new.astype(cache["k"].dtype))
    v = cache["v"].at[b_idx, slots].set(
        v_new.astype(cache["v"].dtype))
    written = positions if valid is None else jnp.where(valid, positions, -1)
    pos = cache["pos"].at[b_idx, slots].set(written)
    return {"k": k, "v": v, "pos": pos}


def cache_write_paged(arena: dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                      positions: jnp.ndarray, pages: jnp.ndarray, *,
                      slot_offset=0,
                      valid: Optional[jnp.ndarray] = None) -> dict:
    """Write [B,T,Hkv,D] keys/values into a paged block arena.

    arena: {"k","v","pos"} block-arena leaves (k/v [NB, bs, Hkv, D],
    pos [NB, bs]); pages: [B, NBS] int32 — each row's private suffix
    page table.  Token at absolute position ``p`` lands in block
    ``pages[b, (p - slot_offset) // bs]`` slot ``(p - slot_offset) %
    bs`` — the page-table generalization of the dense split cache's
    "suffix token P+i at slot i" rule, so ``pos`` keeps absolute
    positions and all masking stays positional.  ``slot_offset`` may be
    per-row [B] (each cluster's own prefix length).  Tokens that are
    padding (``valid`` False) or map past the table are NOT written at
    all (OOB-drop scatter): their target slots keep pos = -1 from the
    allocation-time reset, and no row can ever touch another row's
    blocks — page tables are disjoint by construction.
    """
    bs = arena["k"].shape[1]
    off = jnp.asarray(slot_offset)
    if off.ndim == 1:
        off = off[:, None]                                     # [B, 1]
    rel = positions - off                                      # [B, T]
    blk_col = rel // bs
    width = pages.shape[1]
    bid = jnp.take_along_axis(pages, jnp.clip(blk_col, 0, width - 1), axis=1)
    ok = (rel >= 0) & (blk_col < width)
    if valid is not None:
        ok = ok & valid
    slot = jnp.where(ok, rel % bs, bs)                         # OOB -> drop
    k = arena["k"].at[bid, slot].set(
        k_new.astype(arena["k"].dtype), mode="drop")
    v = arena["v"].at[bid, slot].set(
        v_new.astype(arena["v"].dtype), mode="drop")
    pos = arena["pos"].at[bid, slot].set(positions, mode="drop")
    return {"k": k, "v": v, "pos": pos}


def ring_write_window(cache: dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                      positions: jnp.ndarray,
                      valid: Optional[jnp.ndarray],
                      slot_offset=0) -> dict:
    """Ring-write a multi-token block into a window-sized cache, keeping
    each row's LAST min(len, capacity) **valid** tokens.

    A column-tail write (``k_new[:, t-cap:]``) is only correct for
    unpadded rows: with right-padding the tail columns are padding, so
    it would drop real in-window keys and clobber live slots with
    padding.  Masking per row fixes both (dropped columns leave their
    slot untouched)."""
    t = positions.shape[1]
    cap = cache["k"].shape[1]
    col = jnp.arange(t)[None]                                  # [1, T]
    if valid is None:
        keep = jnp.broadcast_to(col >= t - cap, positions.shape)
    else:
        lengths = jnp.sum(valid.astype(jnp.int32), axis=1, keepdims=True)
        keep = valid & (col >= lengths - cap)
    return cache_write(cache, k_new, v_new, positions, ring=True,
                       slot_offset=slot_offset, keep=keep)


# ----------------------------------------------------------------------
# self attention layer
# ----------------------------------------------------------------------
def self_attention(p: dict, x: jnp.ndarray, *, num_heads: int,
                   num_kv_heads: int, head_dim: int, rope_theta: float,
                   positions: jnp.ndarray, cache: Optional[dict] = None,
                   causal: bool = True, window: int = 0,
                   ring: bool = False, valid: Optional[jnp.ndarray] = None,
                   impl: str = "xla", prefix: Optional[dict] = None,
                   slot_offset=0,
                   prefix_pages: Optional[jnp.ndarray] = None,
                   suffix_pages: Optional[jnp.ndarray] = None,
                   fused: bool = True,
                   prefix_offsets: Optional[jnp.ndarray] = None,
                   prefix_skips: Optional[jnp.ndarray] = None):
    """x: [B, T, D_model]; positions: [B, T] absolute positions.

    Returns (out [B, T, D_model], new_cache or None).
    ``impl="pallas"`` routes attention through the Pallas kernels
    (prefix_attention / decode_gqa); "xla" uses the jnp reference path.

    ``prefix`` enables the dense split prefix/suffix cascade
    (DESIGN.md §5): a read-only batch-1 {"k","v","pos"} cache holding
    the shared prefix.  Fresh KV then goes into ``cache`` (the
    suffix-only cache) at slot = position - ``slot_offset``, and
    attention runs as shared-prefix partial + suffix partial + LSE
    merge — exact vs. the broadcast path.

    ``suffix_pages`` [B, NBS] (+ ``prefix_pages`` [B, NBP]) switches to
    the PAGED path (DESIGN.md §8): ``cache`` is then the block arena
    (k/v [NB, bs, Hkv, D]); fresh KV is scattered into each row's
    private suffix blocks at slot = position - ``slot_offset`` (per-row
    [B]), and attention cascades over [prefix blocks ++ suffix blocks].
    A window-sized ring never exists here — suffix pages hold the full
    suffix+decode tail, and sliding windows mask positionally — so the
    windowed-prefill special case of the dense paths disappears.
    """
    if impl == "pallas":
        from repro.kernels import ops as kops

        def _attend(q_, k_, v_, qp_, kp_):
            # kernels take head-major K/V; cache is seq-major
            k_ = k_.transpose(0, 2, 1, 3)
            v_ = v_.transpose(0, 2, 1, 3)
            if q_.shape[2] == 1:        # decode: 1 token vs long cache
                out_ = kops.decode_gqa(q_[:, :, 0], k_, v_, qp_[:, 0], kp_,
                                       window=window)
                return out_[:, :, None]
            return kops.prefix_attention(q_, k_, v_, qp_, kp_,
                                         causal=causal, window=window)
    else:
        def _attend(q_, k_, v_, qp_, kp_):
            return attend(q_, k_, v_, qp_, kp_, causal=causal, window=window)
    b, t, _ = x.shape
    q = linear(x, p["wq"])
    k = linear(x, p["wk"])
    v = linear(x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # q head-major for the MXU attention; k/v stay seq-major (cache layout)
    q = q.reshape(b, t, num_heads, head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, num_kv_heads, head_dim)
    v = v.reshape(b, t, num_kv_heads, head_dim)
    q = apply_rope(q, positions[:, None, :], rope_theta)
    # Keys are written CANONICAL (un-rotated); every branch below rotates
    # at its effective positions just before attending (DESIGN.md §14).

    if cache is None:
        self_pos = positions if valid is None else jnp.where(valid, positions, -1)
        k_r = apply_rope(k, positions[:, :, None], rope_theta)
        out = _attend(q, k_r, v, positions, self_pos)
        new_cache = None
    elif suffix_pages is not None:
        # Paged cascade: fresh KV scatters into the row's private suffix
        # blocks; attention walks the page tables.  ``cache`` is the
        # arena holding the suffix blocks; the prefix blocks live in
        # ``prefix`` when given (decode: the main arena as a read-only
        # scan invariant) or in the same ``cache`` (prefill: one
        # address space).  Rotation happens inside ``attend_paged`` at
        # effective positions (stored pos + per-block composition offset).
        new_cache = cache_write_paged(cache, k, v, positions, suffix_pages,
                                      slot_offset=slot_offset, valid=valid)
        prefix_src = prefix if prefix is not None else new_cache
        out = attend_paged(q, positions, prefix_src, prefix_pages,
                           new_cache, suffix_pages, window=window,
                           impl=impl, fused=fused, rope_theta=rope_theta,
                           prefix_offsets=prefix_offsets,
                           prefix_skips=prefix_skips)
    elif prefix is not None:
        # Split prefix/suffix cascade: fresh KV goes into the suffix-only
        # cache; the shared batch-1 prefix buffers are attended in place
        # (rotated at their stored positions inside ``attend_shared``).
        self_pos = positions if valid is None else jnp.where(valid, positions, -1)
        if window and t > 1:
            # The window-sized suffix ring cannot hold T > capacity fresh
            # tokens at once: attend over [suffix cache ++ fresh self-KV]
            # and ring-write each row's last in-window valid tokens
            # (mirrors the broadcast branch).
            k_all = jnp.concatenate(
                [cache["k"], k.astype(cache["k"].dtype)], axis=1)
            v_all = jnp.concatenate(
                [cache["v"], v.astype(cache["v"].dtype)], axis=1)
            pos_all = jnp.concatenate([cache["pos"], self_pos], axis=1)
            out = attend_shared(q, positions, prefix, k_all, v_all, pos_all,
                                window=window, impl=impl,
                                rope_theta=rope_theta)
            new_cache = ring_write_window(cache, k, v, positions, valid,
                                          slot_offset=slot_offset)
        else:
            ring_eff = ring or bool(window)
            new_cache = cache_write(cache, k, v, positions, ring=ring_eff,
                                    valid=valid, slot_offset=slot_offset)
            out = attend_shared(q, positions, prefix, new_cache["k"],
                                new_cache["v"], new_cache["pos"],
                                window=window, impl=impl,
                                rope_theta=rope_theta)
    elif window and t > 1:
        # Windowed multi-token (prefill / suffix prefill): the ring buffer
        # cannot hold T > capacity fresh tokens at once, so attend over
        # [cached prefix ++ fresh self-KV] and ring-write each row's last
        # in-window valid tokens.
        self_pos = positions if valid is None else jnp.where(valid, positions, -1)
        k_all = jnp.concatenate([cache["k"], k.astype(cache["k"].dtype)], axis=1)
        v_all = jnp.concatenate([cache["v"], v.astype(cache["v"].dtype)], axis=1)
        pos_all = jnp.concatenate([cache["pos"], self_pos], axis=1)
        k_r = apply_rope(k_all, pos_all[:, :, None], rope_theta)
        out = _attend(q, k_r, v_all, positions, pos_all)
        new_cache = ring_write_window(cache, k, v, positions, valid)
    else:
        ring_eff = ring or bool(window)
        new_cache = cache_write(cache, k, v, positions, ring=ring_eff,
                                valid=valid)
        k_r = apply_rope(new_cache["k"], new_cache["pos"][:, :, None],
                         rope_theta)
        out = _attend(q, k_r, new_cache["v"], positions, new_cache["pos"])
    out = out.transpose(0, 2, 1, 3).reshape(b, t, num_heads * head_dim)
    return linear(out, p["wo"]), new_cache


# ----------------------------------------------------------------------
# cross attention (enc-dec decoder / VLM image layers)
# ----------------------------------------------------------------------
def init_cross_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                         head_dim: int, dtype) -> dict:
    return init_attention(key, d_model, num_heads, num_kv_heads, head_dim, dtype)


def cross_attention_kv(p: dict, enc: jnp.ndarray, *, num_kv_heads: int,
                       head_dim: int):
    """Project encoder states once; reusable across all decode steps.
    Seq-major layout [B, S, Hkv, D], matching the self-attention cache."""
    b, s, _ = enc.shape
    k = linear(enc, p["wk"]).reshape(b, s, num_kv_heads, head_dim)
    v = linear(enc, p["wv"]).reshape(b, s, num_kv_heads, head_dim)
    return k, v


def cross_attention(p: dict, x: jnp.ndarray, enc_kv, *, num_heads: int,
                    num_kv_heads: int, head_dim: int):
    """x: [B, T, D]; enc_kv: (k, v) each [B, S, Hkv, D]."""
    b, t, _ = x.shape
    k, v = enc_kv
    q = linear(x, p["wq"]).reshape(b, t, num_heads, head_dim).transpose(0, 2, 1, 3)
    s = k.shape[1]
    q_pos = jnp.zeros((b, t), jnp.int32)
    k_pos = jnp.zeros((b, s), jnp.int32)
    out = attend(q, k, v, q_pos, k_pos, causal=False)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, num_heads * head_dim)
    return linear(out, p["wo"])
