"""Mixture-of-Experts FFN with sort-based capacity dispatch.

TPU adaptation: instead of the dense one-hot dispatch einsum (whose FLOPs
scale as B*S^2*k*D and would swamp the roofline at 32k context) we use a
sort/scatter dispatch: tokens are grouped per expert into a static
``[E, C, D]`` buffer (scatter = memory op, no FLOPs), the expert FFN runs
as a batched matmul over the expert dim (MXU-friendly, shardable over the
``model`` axis for expert parallelism), and outputs are gathered back and
combined with router weights.  Tokens beyond expert capacity are dropped
(standard capacity-factor semantics); the router aux loss penalizes
imbalance during training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.hints import constrain
from repro.models.layers import dense_init, linear


def init_moe(key, d_model: int, d_ff: int, num_experts: int, dtype,
             dense_residual_d_ff: int = 0) -> dict:
    kr, kg, ku, kd, kres = jax.random.split(key, 5)
    scale = (1.0 / d_model) ** 0.5
    p = {
        "router": dense_init(kr, d_model, num_experts, dtype),
        "w_gate": (jax.random.normal(kg, (num_experts, d_model, d_ff), jnp.float32)
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ku, (num_experts, d_model, d_ff), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(kd, (num_experts, d_ff, d_model), jnp.float32)
                   * (1.0 / d_ff) ** 0.5).astype(dtype),
    }
    if dense_residual_d_ff:
        from repro.models.layers import init_mlp
        p["dense_residual"] = init_mlp(kres, d_model, dense_residual_d_ff, dtype)
    return p


def _row_gather(x, idx):
    """x: [B, N, D], idx: [B, M] -> [B, M, D] without index broadcast."""
    return jax.vmap(lambda xi, ii: jnp.take(xi, ii, axis=0))(x, idx)


def expert_capacity(num_tokens: int, num_experts: int, top_k: int,
                    capacity_factor: float) -> int:
    cap = int(num_tokens * top_k * capacity_factor / num_experts)
    return max(8, ((cap + 7) // 8) * 8)      # 8-align for TPU tiling


def apply_moe(p: dict, x: jnp.ndarray, *, top_k: int,
              capacity_factor: float = 1.25):
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar).

    Per-row sort-based dispatch: every batch row sorts ITS tokens into a
    [E, C_row, D] buffer, so the whole dispatch is batched over B and
    GSPMD keeps the data-parallel sharding intact (no global argsort over
    the batch-sharded token dim — that would all-gather activations).
    Expert FFN is a batched matmul over the expert dim, shardable on E
    (expert parallelism, arctic) or on d_ff (TP within expert, mixtral).
    """
    b, s, d = x.shape
    e = p["router"].shape[1]
    sk = s * top_k

    logits = linear(x, p["router"]).astype(jnp.float32)         # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)                  # [B, S, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)      # renormalize

    # ---- load-balance aux loss (Switch-style) ----
    # scatter-add histogram instead of a [B,S,E] one-hot (at E=128 that
    # buffer is ~0.5 TB global; EXPERIMENTS.md §Perf arctic iteration)
    counts = jnp.zeros((e,), jnp.float32).at[top_i[..., 0].reshape(-1)].add(1.0)
    density = counts / (b * s)
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_proxy) * e

    # ---- per-row dispatch ----
    cap = max(top_k, expert_capacity(s, e, top_k, capacity_factor))
    flat_expert = top_i.reshape(b, sk)                          # [B, S*K]
    flat_weight = top_p.reshape(b, sk)
    flat_token = jnp.broadcast_to(
        (jnp.arange(sk) // top_k)[None], (b, sk))               # [B, S*K]

    order = jnp.argsort(flat_expert, axis=1, stable=True)
    sorted_expert = jnp.take_along_axis(flat_expert, order, 1)  # [B, S*K]
    sorted_token = jnp.take_along_axis(flat_token, order, 1)
    sorted_weight = jnp.take_along_axis(flat_weight, order, 1)

    # position within the expert's group, per row: the array is sorted by
    # expert id, so rank = index - first_occurrence(expert).  searchsorted
    # is O(S*K log) and avoids the [B, S*K, E] one-hot cumsum whose bytes
    # dominate at E=128 (EXPERIMENTS.md §Perf arctic iteration).
    first = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e), side="left"))(
        sorted_expert)                                           # [B, E]
    rank = jnp.arange(sk)[None, :] - jnp.take_along_axis(
        first, sorted_expert, 1)
    keep = rank < cap
    slot = jnp.where(keep, sorted_expert * cap + rank, e * cap)  # [B, S*K]

    b_idx = jnp.arange(b)[:, None]
    # vmapped take, NOT take_along_axis: the latter broadcasts its index
    # operand to [B, S*K, D] (112 GiB of u32 at arctic scale) and GSPMD
    # all-gathers it — EXPERIMENTS.md §Perf arctic iteration 3.
    tokens = _row_gather(x, sorted_token)                        # [B, S*K, D]
    tokens = constrain(tokens, "moe_tokens")
    buf = jnp.zeros((b, e * cap + 1, d), x.dtype)
    buf = constrain(buf, "moe_buf")
    buf = buf.at[b_idx, slot].set(tokens.astype(x.dtype))
    buf = constrain(buf, "moe_buf")
    expert_in = buf[:, : e * cap].reshape(b, e, cap, d)
    expert_in = constrain(expert_in, "moe_expert_in")

    # ---- expert FFN (batched over B and E) ----
    gate = jnp.einsum("becd,edf->becf", expert_in, p["w_gate"],
                      preferred_element_type=jnp.float32)
    up = jnp.einsum("becd,edf->becf", expert_in, p["w_up"],
                    preferred_element_type=jnp.float32)
    hidden = (jax.nn.silu(gate) * up).astype(x.dtype)
    expert_out = jnp.einsum("becf,efd->becd", hidden, p["w_down"],
                            preferred_element_type=jnp.float32).astype(x.dtype)
    expert_out = constrain(expert_out, "moe_expert_out")

    # ---- combine ----
    flat_out = expert_out.reshape(b, e * cap, d)
    gathered = _row_gather(flat_out, jnp.clip(slot, 0, e * cap - 1))
    gathered = constrain(jnp.where(keep[..., None], gathered, 0),
                         "moe_tokens")
    combined = constrain(jnp.zeros((b, s, d), jnp.float32), "moe_combine")
    combined = combined.at[b_idx, sorted_token].add(
        gathered.astype(jnp.float32) * sorted_weight[..., None])
    out = constrain(combined.astype(x.dtype), "moe_combine")

    if "dense_residual" in p:
        from repro.models.layers import apply_mlp
        out = out + apply_mlp(p["dense_residual"], x)
    return out, aux


def apply_moe_dense_oracle(p: dict, x: jnp.ndarray, *, top_k: int):
    """Reference: every expert computed for every token (no drops)."""
    b, s, d = x.shape
    logits = linear(x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    e = p["router"].shape[1]
    gate = jnp.einsum("bsd,edf->besf", x, p["w_gate"],
                      preferred_element_type=jnp.float32)
    up = jnp.einsum("bsd,edf->besf", x, p["w_up"],
                    preferred_element_type=jnp.float32)
    hidden = (jax.nn.silu(gate) * up).astype(x.dtype)
    all_out = jnp.einsum("besf,efd->besd", hidden, p["w_down"],
                         preferred_element_type=jnp.float32)    # [B,E,S,D]
    weights = jnp.zeros((b, s, e), jnp.float32)
    bi = jnp.arange(b)[:, None, None]
    si = jnp.arange(s)[None, :, None]
    weights = weights.at[bi, si, top_i].set(top_p)
    out = jnp.einsum("bse,besd->bsd", weights, all_out).astype(x.dtype)
    if "dense_residual" in p:
        from repro.models.layers import apply_mlp
        out = out + apply_mlp(p["dense_residual"], x)
    return out
