"""Mamba-1 selective SSM block (falcon-mamba family).

State-space recurrence per channel c and state dim n:
    h_t = exp(dt_t * A[c, n]) * h_{t-1} + dt_t * B_t[n] * x_t[c]
    y_t[c] = sum_n C_t[n] * h_t[c, n] + D[c] * x_t[c]

The prefix-state analogue of the paper's KV reuse for attention-free archs:
after consuming the representative-subgraph prompt, ``(conv_state,
ssm_state)`` fully summarizes the prefix; member queries resume from it.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d, dense_init, init_conv1d, linear


def init_mamba(key, d_model: int, d_inner: int, d_state: int, dt_rank: int,
               conv_width: int, dtype) -> dict:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    # S4D-real initialization of A.
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, 1))
    dt_bias = jnp.log(jnp.exp(jnp.linspace(1e-3, 1e-1, d_inner)) - 1.0)  # softplus^-1
    return {
        "in_proj": dense_init(k1, d_model, 2 * d_inner, dtype),
        "conv": init_conv1d(k2, d_inner, conv_width, dtype),
        "x_proj": dense_init(k3, d_inner, dt_rank + 2 * d_state, dtype),
        "dt_proj": dense_init(k4, dt_rank, d_inner, dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(a),                       # [d_inner, d_state] fp32
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(k6, d_inner, d_model, dtype),
    }


def init_mamba_cache(batch: int, d_inner: int, d_state: int, conv_width: int,
                     dtype) -> dict:
    return {
        "conv": jnp.zeros((batch, conv_width - 1, d_inner), dtype),
        "state": jnp.zeros((batch, d_inner, d_state), jnp.float32),
    }


def _ssm_scan_ref(x, dt, B, C, A):
    """Sequential selective scan in pure jnp (oracle; used on XLA path).

    x: [Bt, T, Di]; dt: [Bt, T, Di]; B, C: [Bt, T, N]; A: [Di, N].
    Returns (y [Bt, T, Di], final_state [Bt, Di, N]).
    """
    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                        # [Bt,Di],[Bt,Di],[Bt,N],[Bt,N]
        da = jnp.exp(dt_t[..., None] * A)                # [Bt, Di, N]
        db = dt_t[..., None] * b_t[:, None, :]           # [Bt, Di, N]
        h = da * h + db * x_t[..., None]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    bt, t, di = x.shape
    h0 = jnp.zeros((bt, di, A.shape[1]), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_final


def apply_mamba(p: dict, x: jnp.ndarray, cache: Optional[dict] = None,
                *, d_state: int, dt_rank: int, impl: str = "xla"):
    """x: [B, T, D_model] -> (out, new_cache)."""
    b, t, _ = x.shape
    d_inner = p["out_proj"].shape[0]
    xz = linear(x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)                    # [B, T, Di] each

    conv_state = cache["conv"] if cache is not None else None
    xi, new_conv = causal_conv1d(p["conv"], xi, conv_state)
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x.dtype)

    proj = linear(xi, p["x_proj"]).astype(jnp.float32)   # [B, T, dt_rank+2N]
    dt, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt, p["dt_proj"].astype(jnp.float32))
        + p["dt_bias"])                                   # [B, T, Di]
    A = -jnp.exp(p["A_log"])                              # [Di, N]

    if impl == "pallas":
        from repro.kernels import ops as kops
        h0 = cache["state"] if cache is not None else None
        y, h_final = kops.ssm_scan(xi.astype(jnp.float32), dt, Bmat, Cmat, A, h0)
    else:
        xf = xi.astype(jnp.float32)
        if cache is not None:
            # fold initial state in by running scan from cache["state"]
            y, h_final = _ssm_scan_from(cache["state"], xf, dt, Bmat, Cmat, A)
        else:
            y, h_final = _ssm_scan_ref(xf, dt, Bmat, Cmat, A)

    y = y + xf_d(p["D"], xi)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = linear(y.astype(x.dtype), p["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "state": h_final}
    return out, new_cache


def xf_d(D, xi):
    return D * xi.astype(jnp.float32)


def _ssm_scan_from(h0, x, dt, B, C, A):
    """Selective scan starting from carried state ``h0`` [Bt, Di, N]."""
    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * A)
        db = dt_t[..., None] * b_t[:, None, :]
        h = da * h + db * x_t[..., None]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), h_final
