"""Training launcher.

Host mode (default): trains the paper-small backbone on a RAG dataset
(this is the CPU-runnable path used by the benchmarks).

Mesh mode (--dry-run): lowers the full-scale train step for --arch on the
production mesh and prints the memory/cost analysis (no allocation).

  PYTHONPATH=src python -m repro.launch.train --dataset scene --steps 300
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-20b --dry-run
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="scene", choices=["scene", "oag"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        assert args.arch, "--dry-run requires --arch"
        # dryrun module must own process start (device-count env var)
        import os
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", "train_4k"]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.call(cmd, env=os.environ))

    from repro.rag.workbench import build_workbench
    wb = build_workbench(args.dataset, train_steps=args.steps,
                         force_retrain=True)
    print(f"trained + checkpointed backbone for {args.dataset} "
          f"({wb.cfg.param_count()/1e6:.1f}M params)")


if __name__ == "__main__":
    main()
