import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture x input shape) pair, lower + compile the step
function onto the production mesh (single-pod 16x16 = 256 chips and
multi-pod 2x16x16 = 512 chips), and record:

  * memory_analysis  — per-device bytes (proves the config fits),
  * cost_analysis    — HLO FLOPs / bytes for the roofline terms,
  * collective bytes — parsed from the partitioned HLO text,
  * derived roofline terms (compute / memory / collective seconds).

Results append to a JSON file consumed by benchmarks/roofline.py and
EXPERIMENTS.md.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k [--multi-pod] [--out results/dryrun.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import registry as R
from repro.distributed import hints as H
from repro.distributed import sharding as S
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models import attention as attn_mod
from repro.models import model as M
from repro.training import optimizer as opt

SWA_OVERRIDE_WINDOW = 4096
SCAN_LAYERS = True
attn_mod.UNROLL_CHUNKS = False  # toggled by --unroll-chunks

_SHAPE_RE = re.compile(
    r"\b(f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64|f64)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device output bytes of every collective op in the
    partitioned module (all-reduce weighted 2x for ring send+recv)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)", ls)
        if not m:
            continue
        rhs = m.group(1)
        for op in _COLLECTIVES:
            if re.search(rf"\)?\s{op}(-start|-done)?\(", rhs) or \
               rhs.split("(")[0].strip().endswith(op):
                head = rhs.split(f" {op}")[0]
                b = _shape_bytes(head)
                if op == "all-reduce":
                    b *= 2
                out[op] += b
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def build_cfg(arch: str, shape: str, swa_override: int = 0):
    cfg = R.get_config(arch)
    kind = R.INPUT_SHAPES[shape].kind
    cfg = cfg.replace(dtype="bfloat16", remat=(kind == "train"),
                  scan_layers=SCAN_LAYERS)
    if shape == "long_500k" and swa_override and not cfg.supports_long_context:
        cfg = R.apply_swa_override(cfg, swa_override)
    return cfg


def abstract_params(cfg):
    return jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def lower_one(cfg, shape: str, mesh, *, zero_opt: bool = True,
              variant: dict | None = None):
    """Lower + compile one config onto one mesh; returns raw analysis.

    ``variant``: perf-iteration knobs — {"seq_shard_boundary": bool,
    "zero": bool, "remat": bool, "attend_chunk": int}."""
    variant = variant or {}
    if "remat" in variant:
        cfg = cfg.replace(remat=variant["remat"])
    if "attend_chunk" in variant:
        attn_mod.ATTEND_CHUNK = variant["attend_chunk"]
    if "scores_bf16" in variant:
        attn_mod.SCORES_BF16 = variant["scores_bf16"]
    if "zero" in variant:
        zero_opt = variant["zero"]
    if "kv_shard" in variant:
        S.KV_SHARD_OVERRIDE = variant["kv_shard"]
    info = R.INPUT_SHAPES[shape]
    params_abs = abstract_params(cfg)
    pspec = S.param_pspecs(cfg, params_abs, mesh,
                           zero=(info.kind == "train" and zero_opt))
    psh = S.named(mesh, pspec)
    specs = R.input_specs(cfg, shape)

    hint = H.make_batch_hint(
        mesh, cfg,
        seq_shard_boundary=variant.get("seq_shard_boundary", False))

    t0 = time.perf_counter()
    if info.kind == "train":
        opt_cfg = opt.AdamWConfig()
        opt_abs = jax.eval_shape(lambda p: opt.init_state(p), params_abs)
        osh = {"m": psh, "v": psh,
               "count": S.named(mesh, jax.sharding.PartitionSpec())}
        bsh = S.named(mesh, S.batch_pspecs(specs, mesh))
        compute_sh = S.named(mesh, S.param_pspecs(cfg, params_abs, mesh,
                                                  zero=False)) \
            if zero_opt else None
        step = make_train_step(cfg, opt_cfg, compute_shardings=compute_sh,
                               storage_shardings=psh if zero_opt else None)
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         donate_argnums=(0, 1))
        with jax.set_mesh(mesh), H.use_hints(hint):
            lowered = jitted.lower(params_abs, opt_abs, specs)
    elif info.kind == "prefill":
        bsh = S.named(mesh, S.batch_pspecs(specs, mesh))
        step = make_prefill_step(cfg, capacity=info.seq_len)
        jitted = jax.jit(step, in_shardings=(psh, bsh))
        with jax.set_mesh(mesh), H.use_hints(hint):
            lowered = jitted.lower(params_abs, specs)
    else:  # decode
        bsh = {
            "token": S.named(mesh, S.batch_pspecs(specs["token"], mesh)),
            "positions": S.named(mesh,
                                 S.batch_pspecs(specs["positions"], mesh)),
            "cache": S.named(mesh, S.cache_pspecs(cfg, specs["cache"], mesh)),
        }
        step = make_decode_step(cfg)
        # donate the cache (arg 1): deployed decode loops update in place;
        # without donation XLA materializes a full cache copy per step
        jitted = jax.jit(step, in_shardings=(psh, bsh), donate_argnums=(1,))
        with jax.set_mesh(mesh), H.use_hints(hint):
            lowered = jitted.lower(params_abs, specs)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())

    mem_fields = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            mem_fields[f] = int(v)

    return {"flops": flops, "bytes": bytes_acc, "coll": coll,
            "memory": mem_fields, "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2)}


def _accounting_cfg(cfg, n_groups: int):
    """Shallow unrolled variant: n_groups repeating units, exact HLO costs."""
    from repro.models.model import group_period
    g = group_period(cfg)
    kw = dict(num_layers=g * n_groups, scan_layers=False)
    if cfg.is_encdec:
        kw["num_encoder_layers"] = n_groups
    return cfg.replace(**kw)


def lower_pair(arch: str, shape: str, *, multi_pod: bool = False,
               swa_override: int = SWA_OVERRIDE_WINDOW,
               zero_opt: bool = True, accounting: bool = True):
    """Full dry-run for one (arch x shape x mesh).

    1. Full-depth lowering with scanned layer stacks: THE compile proof +
       realistic memory analysis (what the deployed executable does).
    2. (single-pod only) Two shallow unrolled lowerings (1 and 2 layer
       groups) give exact per-group HLO flop/byte/collective costs —
       XLA's cost model counts loop bodies once, so scanned modules
       undercount; the two-point depth fit recovers the true totals:
       total = base + per_group * groups_at_full_depth.
    """
    info = R.INPUT_SHAPES[shape]
    cfg = build_cfg(arch, shape, swa_override)
    supported, note = R.shape_supported(R.get_config(arch), shape,
                                        swa_override)
    if not supported:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "note": note}

    mesh = make_production_mesh(multi_pod=multi_pod)
    nchips = mesh.devices.size

    full = lower_one(cfg, shape, mesh, zero_opt=zero_opt)

    out = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "status": "ok", "note": note, "chips": int(nchips),
        "lower_s": full["lower_s"], "compile_s": full["compile_s"],
        "memory": full["memory"],
        "scan_module_flops_per_chip": full["flops"],
    }

    if accounting and not multi_pod:
        from repro.models.model import group_period, stack_layout
        g = group_period(cfg)
        attn_mod.UNROLL_CHUNKS = True
        try:
            a1 = lower_one(_accounting_cfg(cfg, 1), shape, mesh,
                           zero_opt=zero_opt)
            a2 = lower_one(_accounting_cfg(cfg, 2), shape, mesh,
                           zero_opt=zero_opt)
        finally:
            attn_mod.UNROLL_CHUNKS = False
        groups = cfg.num_layers / g

        def fit(k1, k2=None):
            v1 = a1[k1] if k2 is None else a1[k1][k2]
            v2 = a2[k1] if k2 is None else a2[k1][k2]
            per = v2 - v1
            return max(0.0, (v1 - per) + per * groups)

        flops = fit("flops")
        bytes_acc = fit("bytes")
        coll_total = fit("coll", "total")
        coll_by_op = {op: fit("coll", op) for op in _COLLECTIVES}

        t_compute = flops / PEAK_FLOPS_BF16
        t_memory = bytes_acc / HBM_BW
        t_coll = coll_total / ICI_BW
        dominant = max((("compute", t_compute), ("memory", t_memory),
                        ("collective", t_coll)), key=lambda kv: kv[1])[0]
        mf = R.model_flops(cfg, shape) / nchips
        out.update({
            "flops_per_chip": flops, "bytes_per_chip": bytes_acc,
            "collective_bytes_per_chip": coll_total,
            "collectives": coll_by_op,
            "roofline": {
                "compute_s": t_compute, "memory_s": t_memory,
                "collective_s": t_coll, "dominant": dominant,
                "model_flops_per_chip": mf,
                "useful_flops_ratio": (mf / flops) if flops else 0.0,
            },
        })
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--swa-override", type=int, default=SWA_OVERRIDE_WINDOW)
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    pairs = []
    archs = R.ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(R.INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["multi_pod"]) for r in results}

    for a, s, mp in pairs:
        if (a, s, mp) in done:
            print(f"[skip-done] {a} x {s} multi_pod={mp}")
            continue
        print(f"[dryrun] {a} x {s} multi_pod={mp} ...", flush=True)
        try:
            r = lower_pair(a, s, multi_pod=mp, swa_override=args.swa_override)
        except Exception as e:
            traceback.print_exc()
            r = {"arch": a, "shape": s, "multi_pod": mp, "status": "error",
                 "note": f"{type(e).__name__}: {e}"}
        results.append(r)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        if r["status"] == "ok":
            msg = (f"  ok: compile {r['compile_s']}s  mem temp "
                   f"{r['memory'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
            if "roofline" in r:
                rt = r["roofline"]
                msg += (f"  flops/chip {r['flops_per_chip']:.3e}  terms "
                        f"c={rt['compute_s']:.4f}s m={rt['memory_s']:.4f}s "
                        f"coll={rt['collective_s']:.4f}s -> {rt['dominant']}")
            print(msg, flush=True)
        else:
            print(f"  {r['status']}: {r['note']}", flush=True)


if __name__ == "__main__":
    main()
