"""Step functions lowered by the dry-run and launchers.

train_step  — fwd + bwd + AdamW update (remat per layer group).
prefill     — full-prompt prefill writing a fresh cache; returns
              last-token logits + cache (serve_step for prefill shapes).
decode      — ONE new token against a KV/state cache (serve_step for
              decode shapes); ring buffer when capacity < positions.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.training import optimizer as opt


def make_train_step(cfg: ModelConfig, opt_cfg: opt.AdamWConfig,
                    compute_shardings=None,
                    storage_shardings=None) -> Callable:
    """ZeRO gather-at-use: params live 2D-sharded ('data' x 'model', with
    AdamW moments), are all-gathered to the tensor-parallel compute layout
    at step entry, and gradients reduce-scatter back to the storage layout
    before the (fully sharded) optimizer update."""
    def train_step(params, opt_state, batch):
        params_c = params
        if compute_shardings is not None:
            params_c = jax.lax.with_sharding_constraint(params,
                                                        compute_shardings)
        loss, grads = jax.value_and_grad(
            lambda p: M.train_loss(p, cfg, batch))(params_c)
        if storage_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads,
                                                     storage_shardings)
        params, opt_state, metrics = opt.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, capacity: int) -> Callable:
    enc_len = cfg.encoder_seq if cfg.is_encdec else cfg.num_image_tokens

    def prefill(params, batch):
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = M.embed_tokens(params, tokens)
        enc = None
        if cfg.is_encdec:
            enc = M.run_encoder(params, cfg, batch["enc_frames"])
        elif cfg.num_image_tokens:
            enc = M.project_frontend(params, batch["img_embeds"])
        cache = M.init_cache(cfg, b, capacity, enc_len=enc_len)
        hidden, cache, _ = M.forward(params, cfg, x, batch["positions"],
                                     cache=cache, enc=enc,
                                     valid=batch["valid"])
        last = hidden[:, -1]
        logits = M.unembed(params, cfg, last[:, None])[:, 0]
        return logits, cache
    return prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode(params, batch):
        token, positions, cache = batch["token"], batch["positions"], \
            batch["cache"]
        x = M.embed_tokens(params, token)
        hidden, cache, _ = M.forward(params, cfg, x, positions, cache=cache,
                                     ring=True)
        logits = M.unembed(params, cfg, hidden)[:, 0]
        return logits, cache
    return decode


def make_suffix_prefill_step(cfg: ModelConfig) -> Callable:
    """The SubGCache fast path at production scale: member-suffix prefill
    against a shared prefix already resident in the cache."""
    def suffix_prefill(params, batch):
        x = M.embed_tokens(params, batch["tokens"])
        hidden, cache, _ = M.forward(params, cfg, x, batch["positions"],
                                     cache=batch["cache"],
                                     valid=batch["valid"])
        logits = M.unembed(params, cfg, hidden[:, -1][:, None])[:, 0]
        return logits, cache
    return suffix_prefill
