"""Serving launcher: in-batch graph-RAG with SubGCache.

  PYTHONPATH=src python -m repro.launch.serve --dataset scene \
      --num-queries 50 --clusters 2 [--no-subgcache]

Full-scale serve_step lowering for an assigned arch:

  PYTHONPATH=src python -m repro.launch.serve --arch command-r-35b --dry-run
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="scene", choices=["scene", "oag"])
    ap.add_argument("--num-queries", type=int, default=50)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--linkage", default="ward")
    ap.add_argument("--retriever", default="gretriever",
                    choices=["gretriever", "grag"])
    ap.add_argument("--no-subgcache", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    args = ap.parse_args()

    if args.dry_run:
        assert args.arch, "--dry-run requires --arch"
        import os
        import subprocess
        import sys
        raise SystemExit(subprocess.call(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             args.arch, "--shape", args.shape], env=os.environ))

    from repro.rag.workbench import build_workbench, test_items
    wb = build_workbench(args.dataset)
    items = test_items(wb, args.num_queries)
    pipe = wb.pipeline(args.retriever)
    pipe.engine.warmup()
    if args.no_subgcache:
        _, summary = pipe.run_baseline(items)
        print(summary.row())
    else:
        _, summary, plan, stats = pipe.run_subgcache(
            items, num_clusters=args.clusters, linkage=args.linkage)
        print(summary.row())
        print(f"clusters {[len(c.member_indices) for c in plan.clusters]}  "
              f"prefill savings x{stats.prefill_savings:.2f}")


if __name__ == "__main__":
    main()
