"""Production mesh builders (TPU v5e target).

Functions, not module-level constants — importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model);
multi-pod: 2 pods x 256 = 512 chips with a leading pure-data-parallel
"pod" axis (gradient all-reduce crosses the DCN pod boundary once per
step; everything else stays on intra-pod ICI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the same axis names (tests / CPU smoke)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
