"""Pooled, evictable prefix-state cache for online cluster serving.

The offline pipeline (``GraphRAGPipeline.run_subgcache``) keeps exactly
ONE live ``PrefixState`` and serves clusters sequentially — correct for
a closed batch, wasteful under streaming traffic where members of the
same cluster arrive minutes apart.  ``PrefixPool`` instead keeps every
representative-subgraph KV cache alive under an HBM byte budget, the
way RAGCache pools document-chunk KV for RAG serving:

* **admission** — ``put`` always admits the newly prefilled state (it
  is about to be used), then evicts cold states until the pool fits the
  budget again;
* **eviction** — cost-aware, by ``age × prefix_len / hits``: old, long,
  rarely-hit prefixes go first.  Recency alone (LRU) would evict an
  expensive-to-recompute hot prefix to keep a cheap recent one; the
  prefix length is the re-prefill cost and the hit count is the
  expected payoff of keeping it.
* **pinning** — states currently serving a batch are refcounted
  (``pin``/``release`` or the ``using`` context manager) and never
  evicted mid-flight, even if the pool temporarily overshoots the
  budget;
* **accounting** — hits, misses, evictions, and re-prefills land in
  ``CacheStats`` (``pool_*`` counters) so the serving report can show
  the hit rate next to the paper's prefill-savings ratio.

The pool stores states; it does not compute them.  On a miss the caller
(``serving/scheduler.py``) re-prefills the representative prefix and
re-admits it — the pool only remembers that the key was seen before so
the readmission is counted as a re-prefill, the cost signal the byte
budget trades against.

Host tier (``attach_host_tier``; core/tiered.py, DESIGN.md §12): with a
``HostTier`` attached, eviction DEMOTES a paged segment's blocks to
host numpy buffers before releasing them, and ``promote`` turns a later
miss into fresh blocks + an async ``device_put`` instead of a
re-prefill; recompute remains only for double misses.  A demote that
loses a race with a same-key ``get(pin=True)`` aborts — the pin wins
and nothing is copied.

Paged backend (DESIGN.md §8): when ``attach_block_pool`` wires this
pool to the engine's ``KVBlockPool``, entries are thin views over
refcounted block allocations — a resident prefix costs exactly its
blocks (no pad-to-capacity waste), eviction is a refcount drop
(``PrefixState.release``) that cannot recycle blocks under an in-flight
batch, and arena exhaustion reclaims cold entries through the same
eviction scoring before an allocation may fail.

Lifecycle of one entry (DESIGN.md §7):

    prefill -> put (pooled) -> get hit* -> evicted -> get miss
            -> re-prefill -> put (re-admitted, counted) -> ...
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Hashable, List, Optional

import jax

from repro.core.cache import CacheStats, PrefixState
from repro.core.paged import PageTable
from repro.core.tiered import HostSegment, HostTier


def state_bytes(state: PrefixState) -> int:
    """HBM footprint of a PrefixState.

    Paged states cost exactly their blocks (``ceil(P / block_size) ×
    per-block bytes`` — no pad-to-capacity waste) at the layout prefix
    blocks actually occupy: ``prefix_block_bytes`` is the int8+scales
    footprint when the pool quantizes, else the compute dtype — pricing
    at the compute itemsize would make an int8 pool under-report
    occupancy and over-admit.  Dense states cost the sum of their
    cache-pytree leaves (the full capacity bucket)."""
    if state.is_paged:
        return len(state.page.blocks) * state.block_pool.prefix_block_bytes
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state.cache))


@dataclasses.dataclass
class PoolEntry:
    """One pooled PrefixState plus the bookkeeping eviction needs."""
    key: Hashable
    state: PrefixState
    nbytes: int
    prefill_s: float = 0.0      # what a re-prefill costs (diagnostics)
    hits: int = 0
    last_used: int = 0          # logical-clock tick of the latest touch
    refs: int = 0               # in-flight pins; > 0 blocks eviction
    prefetched: bool = False    # admitted by speculative promotion; the
                                # first hit consumes the flag (prefetch
                                # precision accounting, DESIGN.md §12)


class PrefixPool:
    """Capacity-bounded pool of live ``PrefixState``s.

    ``budget_bytes``: HBM the pooled caches may occupy.  States pinned
    by an in-flight batch are never evicted; if pinned states alone
    exceed the budget the pool overshoots until they are released
    (serving correctness beats the budget for the duration of a batch).
    """

    def __init__(self, budget_bytes: int,
                 stats: Optional[CacheStats] = None) -> None:
        assert budget_bytes > 0, budget_bytes
        self.budget_bytes = int(budget_bytes)
        self.stats = stats if stats is not None else CacheStats()
        self._entries: Dict[Hashable, PoolEntry] = {}
        self._seen: set = set()      # keys ever admitted (re-prefill count)
        self._clock = 0
        self.tier: Optional[HostTier] = None
        # fired with the pool key when an entry leaves the pool with NO
        # host-tier copy surviving (hard eviction) — content-addressed
        # indexes layered above the pool (scheduler._seg_registry,
        # DESIGN.md §15) hang their invalidation here; without it a
        # stale registry entry would keep resolving to a key whose
        # blocks were recycled long ago
        self.on_hard_evict = None

    # ------------------------------------------------------------------
    # paged backend wiring
    # ------------------------------------------------------------------
    def attach_block_pool(self, block_pool) -> None:
        """Wire this pool to a ``KVBlockPool``: when the allocator runs
        out of blocks mid-allocation, it asks the pool to evict cold
        (unpinned) prefixes first — admission pressure and HBM pressure
        become the same page-table operation.  Eviction under the paged
        backend is a refcount drop (``PrefixState.release``): blocks
        still walked by an in-flight batch stay alive until that batch
        releases its own references.

        One block pool serves one PrefixPool at a time: attaching a new
        pool (a fresh serving window replacing a discarded scheduler)
        ``clear()``s the previous one — without this, the abandoned
        pool's resident entries would hold their block references
        forever (nothing else ever releases them) and the arena would
        shrink by one working set per replaced pool."""
        import weakref
        prev = getattr(block_pool, "_attached_pool", None)
        prev = prev() if prev is not None else None
        if prev is not None and prev is not self:
            prev.clear()
        block_pool._attached_pool = weakref.ref(self)
        self._block_pool = block_pool
        block_pool.allocator.reclaim_hook = self._reclaim_blocks

    def clear(self) -> None:
        """Drop every entry, releasing paged states' block references
        (no eviction accounting — this is teardown, not budget
        pressure).  Entry-level pins are ignored: they protect against
        *eviction scoring*, while in-flight batches hold their own
        block-level references, so serving correctness is unaffected."""
        for e in self._entries.values():
            e.state.release()
            self._fire_hard_evict(e.key)
        self._entries.clear()

    def attach_host_tier(self, tier: HostTier) -> None:
        """Wire a host-memory tier under this pool (DESIGN.md §12):
        evictions demote through it, ``promote`` re-onboards from it."""
        self.tier = tier
        tier.stats = self.stats

    def _reclaim_blocks(self, n_needed: int) -> None:
        """Evict unpinned entries (worst score first) until the block
        allocator has ``n_needed`` free blocks or nothing is evictable."""
        bp = getattr(self, "_block_pool", None)
        if bp is None:
            return
        while bp.free_blocks < n_needed:
            worst = self._pick_victim()
            if worst is None:
                return
            if not self._evict_entry(worst):
                continue     # demote lost a pin race; victim re-picked

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    @property
    def bytes_in_use(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    @property
    def keys(self) -> List[Hashable]:
        return list(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def entry(self, key: Hashable) -> Optional[PoolEntry]:
        return self._entries.get(key)

    @property
    def tokens_resident(self) -> int:
        """Prefix tokens resident across entries — each pooled SEGMENT
        counted once, so a shared ancestor contributes once however
        many descendant paths reference it (the tree layout's
        byte-budget claim; DESIGN.md §10)."""
        return sum(e.state.segment_len for e in self._entries.values())

    def observe_tree_residency(self) -> None:
        """Push the resident segment/token gauges into CacheStats."""
        self.stats.record_tree_residency(len(self._entries),
                                         self.tokens_resident)

    # ------------------------------------------------------------------
    # lookup / admission
    # ------------------------------------------------------------------
    def get(self, key: Hashable, pin: bool = False) -> Optional[PrefixState]:
        """Return the live state for ``key`` or None (cold or evicted).

        A hit bumps the entry's recency and hit count (both feed the
        eviction score); hit/miss land in ``CacheStats``.  ``pin=True``
        takes an in-flight reference atomically with the lookup, so a
        later admission in the same batch cannot evict this state
        between lookup and use (``release`` when done).
        """
        self._clock += 1
        e = self._entries.get(key)
        if e is None:
            self.stats.record_pool(misses=1)
            return None
        e.hits += 1
        e.last_used = self._clock
        if pin:
            e.refs += 1
        if e.prefetched:     # a speculative promotion paid off
            e.prefetched = False
            self.stats.record_tier(prefetch_hits=1)
        self.stats.record_pool(hits=1)
        return e.state

    def peek(self, key: Hashable) -> Optional[PrefixState]:
        """Lookup WITHOUT hit/miss accounting, recency, or pinning —
        for prefetch probes walking a chain (a probe is not traffic;
        counting it would inflate the hit rate it exists to improve)."""
        e = self._entries.get(key)
        return e.state if e is not None else None

    def put(self, key: Hashable, state: PrefixState,
            prefill_s: float = 0.0, pin: bool = False) -> PrefixState:
        """Admit a freshly prefilled state, then evict down to budget.

        Admission is unconditional — the caller prefilled this state
        because a query needs it right now, so refusing admission would
        only move the memory to an unpooled buffer.  Re-admission of a
        previously evicted key counts as a re-prefill.  ``pin=True``
        admits with an in-flight reference already held, so the
        admission's own eviction pass (or a later one in the same
        batch) can never drop the state the caller is about to serve —
        even when the state alone exceeds the budget.
        """
        self._clock += 1
        if key in self._seen and key not in self._entries:
            self.stats.record_pool(reprefills=1)
        self._seen.add(key)
        old = self._entries.pop(key, None)
        if old is not None and old.state is not state:
            old.state.release()      # replaced entry frees its blocks
        self._entries[key] = PoolEntry(
            key=key, state=state, nbytes=state_bytes(state),
            prefill_s=prefill_s, last_used=self._clock,
            hits=old.hits if old else 0,
            refs=(old.refs if old else 0) + (1 if pin else 0))
        # the just-admitted key is exempt from its own admission's
        # eviction pass: a long fresh prefix would otherwise out-score
        # every resident entry and be dropped moments after it was
        # prefilled ("admitted" must mean it survives to be served)
        self._evict_to_budget(protect=key)
        return state

    # ------------------------------------------------------------------
    # pinning (in-flight protection)
    # ------------------------------------------------------------------
    def pin(self, key: Hashable) -> None:
        self._entries[key].refs += 1

    def release(self, key: Hashable) -> None:
        e = self._entries.get(key)
        if e is not None:
            e.refs = max(0, e.refs - 1)
        self._evict_to_budget()     # deferred evictions may now proceed

    @contextlib.contextmanager
    def using(self, keys):
        """Pin ``keys`` for the duration of a batch; release on exit."""
        keys = list(keys)
        for k in keys:
            self.pin(k)
        try:
            yield
        finally:
            for k in keys:
                self.release(k)

    # ------------------------------------------------------------------
    # eviction
    # ------------------------------------------------------------------
    def _score(self, e: PoolEntry) -> float:
        """Eviction priority: ``age × segment_len / hits`` (RAGCache-
        style cost-aware ranking).  Higher = evict first: stale (age),
        cheap to lose relative to payoff (few hits), and big.  The SIZE
        term is the entry's OWN tokens (``segment_len`` — equal to
        ``prefix_len`` for flat states): that is both the HBM this
        entry holds and the re-prefill its eviction risks.  A chain
        state's cumulative ``prefix_len`` would overstate a small leaf
        extension by its whole path and make the pool churn cheap leaf
        segments while big stale entries squat on the budget."""
        age = max(1, self._clock - e.last_used)
        return age * e.state.segment_len / max(1, e.hits)

    def _live_ancestor_uids(self) -> set:
        """uids of states that are a chain ANCESTOR of some resident
        entry's state (DESIGN.md §10).  Such entries are never eviction
        victims: evicting an ancestor before its descendants would (a)
        invert the tree's reuse economics — the shared segment is
        exactly the content every sibling path re-prefills on a miss —
        and (b) let a later materialization rebuild the ancestor while
        resident descendants still chain to the old blocks.  Eviction
        is therefore leaf-before-ancestor; an ancestor becomes
        evictable the moment its last resident descendant goes (the
        eviction loop re-picks per iteration, so a pressure wave peels
        a path leaf-first in one pass).  Pinned descendants are
        resident too, so an in-flight leaf protects its whole path."""
        out: set = set()
        for e in self._entries.values():
            cur = e.state.parent
            while cur is not None:
                out.add(cur.uid)
                cur = cur.parent
        return out

    def _pick_victim(self, protect: Optional[Hashable] = None
                     ) -> Optional[PoolEntry]:
        """Worst-scored unpinned entry that is not ``protect`` and not
        an ancestor of any resident entry (None when nothing is
        evictable)."""
        anchored = self._live_ancestor_uids()
        victims = [e for e in self._entries.values()
                   if e.refs == 0 and e.key != protect
                   and e.state.uid not in anchored]
        if not victims:
            return None
        return max(victims, key=self._score)

    def _evict_to_budget(self, protect: Optional[Hashable] = None) -> None:
        while self.bytes_in_use > self.budget_bytes:
            worst = self._pick_victim(protect)
            if worst is None:
                return     # everything in flight / protected: overshoot
            if not self._evict_entry(worst):
                continue   # demote lost a pin race; victim re-picked

    def _evict_entry(self, worst: PoolEntry) -> bool:
        """One eviction: demote to the host tier (when attached), then
        release the device blocks.  Returns False — entry untouched,
        nothing copied — when the demotion gather lost a race with a
        same-key pin; the caller re-picks (the now-pinned entry no
        longer qualifies as a victim)."""
        if not self._demote(worst):
            return False
        del self._entries[worst.key]
        # paged backend: eviction is a refcount drop — blocks free
        # now, or when the last in-flight reader releases
        worst.state.release()
        self.stats.record_pool(evictions=1)
        self._fire_hard_evict(worst.key)
        return True

    def _fire_hard_evict(self, key: Hashable) -> None:
        """Notify ``on_hard_evict`` iff no host copy survives: a
        demoted segment is still promotable under the same key, so a
        content index pointing at it stays valid — only a true drop
        must invalidate."""
        if self.on_hard_evict is None:
            return
        if self.tier is not None and self.tier.peek(key) is not None:
            return
        self.on_hard_evict(key)

    def _key_of_state(self, st: PrefixState) -> Optional[Hashable]:
        for k, e in self._entries.items():
            if e.state is st:
                return k
        return None

    def _demote(self, e: PoolEntry) -> bool:
        """Capture an eviction victim's bits into the host tier.  True:
        proceed with the eviction (captured, or nothing to capture);
        False: the gather lost a race with a same-key pin — nothing was
        stored and the entry must stay resident (the pin wins)."""
        tier = self.tier
        bp = getattr(self, "_block_pool", None)
        st = e.state
        if tier is None or bp is None or not st.is_paged \
                or st.block_pool is not bp:
            return True
        parent_key = None
        if st.parent is not None:
            # leaf-before-ancestor eviction guarantees the parent is
            # still resident while this segment demotes — its pool key
            # is what chain-aware promotion re-links through
            parent_key = self._key_of_state(st.parent)
            if parent_key is None:
                return True   # unmapped parent: promotion couldn't link
        host, nbytes, toks = bp.demote_blocks(st.page.blocks)
        if e.refs > 0:        # a pin landed during the gather: it wins
            return False
        seg = HostSegment(
            key=e.key, host=host, block_tokens=toks, nbytes=nbytes,
            prefix_len=st.prefix_len, page_length=st.page.length,
            seg_len=st.seg_len, capacity=st.capacity, enc_len=st.enc_len,
            n_soft=st.n_soft, parent_key=parent_key,
            quantized=bp.quantize_prefix, prefill_s=e.prefill_s,
            hits=e.hits)
        if tier.admit(seg):
            self.stats.record_tier(demotions=1, demoted_bytes=nbytes)
        return True

    def demote_to_host(self, key: Hashable) -> bool:
        """Targeted demote of ONE resident segment — the router's
        migration primitive (DESIGN.md §13): the source replica demotes
        a migrating cluster's chain leaf-first through the SAME host
        round-trip eviction already uses (never a device-to-device copy
        path), the router hands the ``HostSegment`` to the destination
        tier, and the destination promotes lazily on the cluster's next
        hit.  Refuses (False, entry untouched) when the segment is
        pinned (in flight), still anchors a resident descendant (demote
        the descendant first), has no tier to land in, or the demote
        gather loses a pin race.  NOT counted as an eviction — this is
        placement, not budget pressure; callers account it via
        ``CacheStats.record_migration``."""
        e = self._entries.get(key)
        bp = getattr(self, "_block_pool", None)
        if e is None or e.refs > 0 or self.tier is None or bp is None \
                or not e.state.is_paged or e.state.block_pool is not bp:
            return False     # nothing _demote could capture: refuse
        if e.state.uid in self._live_ancestor_uids():
            return False
        if not self._demote(e):
            return False
        del self._entries[key]
        e.state.release()
        self._fire_hard_evict(key)   # no-op when the tier holds a copy
        return True

    # ------------------------------------------------------------------
    # promotion (host tier → device; DESIGN.md §12)
    # ------------------------------------------------------------------
    def promote(self, key: Hashable, *, parent: Optional[PrefixState] = None,
                pin: bool = False,
                prefetched: bool = False) -> Optional[PrefixState]:
        """Re-onboard a demoted segment: fresh prefix blocks, an async
        ``device_put`` + scatter (``KVBlockPool.promote_blocks`` — the
        batch's suffix prefill overlaps the transfer), and re-admission
        under ``key``.  ``parent`` must be the RESIDENT state of the
        segment's recorded chain parent (chain-aware: callers walk
        root→leaf so ancestors are device-resident first); it is pinned
        across the allocation so the alloc's own reclaim pass cannot
        evict it mid-promotion.

        Returns None — and leaves the host copy intact for a recompute
        fallback or retry — on a host miss, a stale chain linkage, or
        any failure during allocation/transfer (``OutOfBlocks``, an
        injected ``device_put`` fault): the unwind drops every
        reference the attempt took, so no phantom refs survive."""
        tier = self.tier
        bp = getattr(self, "_block_pool", None)
        if tier is None or bp is None:
            return None
        hseg = tier.peek(key)
        if hseg is None:
            return None
        if hseg.quantized != bp.quantize_prefix:
            return None      # demoted from a different arena layout
        pe = None
        if hseg.parent_key is not None:
            pe = self._entries.get(hseg.parent_key)
            if parent is None or pe is None or pe.state is not parent \
                    or parent.prefix_len + hseg.page_length \
                    != hseg.prefix_len:
                return None  # stale linkage: fall back to recompute
        elif parent is not None:
            return None
        if pe is not None:
            pe.refs += 1     # hold the parent across our alloc's reclaim
        bids = anc = None
        try:
            bids, transfer = bp.promote_blocks(hseg.host,
                                               hseg.block_tokens)
            if parent is not None:
                anc = list(parent.chain_blocks())
                bp.incref(anc)
        except Exception:
            if bids is not None:
                bp.decref(bids)
            self.stats.record_tier(promotion_failures=1)
            return None
        finally:
            if pe is not None:
                pe.refs = max(0, pe.refs - 1)
        state = PrefixState(
            cache=None, prefix_len=hseg.prefix_len,
            capacity=hseg.capacity, enc_len=hseg.enc_len,
            n_soft=hseg.n_soft,
            page=PageTable(blocks=bids, length=hseg.page_length),
            block_pool=bp, parent=parent, seg_len=hseg.seg_len,
            ancestor_blocks=anc or [])
        tier.pop(key)        # move semantics: commit point
        tier.track_transfer(transfer)
        self.stats.record_tier(promotions=1, promoted_bytes=hseg.nbytes,
                               prefetch_promotions=int(prefetched))
        self.stats.record_host(tier)
        # a promotion is NOT a re-prefill — keep the recompute counter
        # honest by exempting this admission from the _seen check
        self._seen.discard(key)
        self.put(key, state, prefill_s=hseg.prefill_s, pin=pin)
        self._entries[key].prefetched = bool(prefetched)
        return state
