"""Subgraph representation, union-merge, and prompt textualization.

The retrieved unit of graph-based RAG is a subgraph of the textual graph:
a set of node ids plus a set of (src, rel_text, dst) edges.  SubGCache's
representative subgraph for a cluster is the union of its members'
nodes and edges (paper §3.3) — order-normalized so that every member of
a cluster maps to the *identical* prompt prefix (the cached unit).
"""
from __future__ import annotations

import dataclasses
from typing import FrozenSet, Iterable, Optional, Sequence, Tuple

Edge = Tuple[int, str, int]


@dataclasses.dataclass(frozen=True)
class Subgraph:
    nodes: FrozenSet[int]
    edges: FrozenSet[Edge]

    @staticmethod
    def from_lists(nodes: Iterable[int], edges: Iterable[Edge]) -> "Subgraph":
        edges = frozenset((int(s), str(r), int(d)) for s, r, d in edges)
        nodes = frozenset(int(n) for n in nodes) | \
            frozenset(n for s, _, d in edges for n in (s, d))
        return Subgraph(nodes=nodes, edges=edges)

    def union(self, other: "Subgraph") -> "Subgraph":
        return Subgraph(nodes=self.nodes | other.nodes,
                        edges=self.edges | other.edges)

    def intersection(self, other: "Subgraph") -> "Subgraph":
        return Subgraph(nodes=self.nodes & other.nodes,
                        edges=self.edges & other.edges)

    def issubset(self, other: "Subgraph") -> bool:
        return self.nodes <= other.nodes and self.edges <= other.edges

    @property
    def is_empty(self) -> bool:
        return not self.nodes and not self.edges

    @property
    def num_nodes(self) -> int:
        return len(self.nodes)

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    def jaccard(self, other: "Subgraph") -> float:
        """Structural overlap measure (diagnostics / tests)."""
        a = self.nodes | {("e",) + e for e in self.edges}
        b = other.nodes | {("e",) + e for e in other.edges}
        if not a and not b:
            return 1.0
        return len(a & b) / max(1, len(a | b))


def merge_subgraphs(subgraphs: Sequence[Subgraph]) -> Subgraph:
    """Representative subgraph = union of all members (paper §3.3)."""
    assert subgraphs, "cannot merge an empty cluster"
    out = subgraphs[0]
    for sg in subgraphs[1:]:
        out = out.union(sg)
    return out


def intersect_subgraphs(subgraphs: Sequence[Subgraph]) -> Subgraph:
    """Shared structure of a set of subgraphs: the ancestor content of a
    prefix-tree node is the intersection of its children's contents
    (DESIGN.md §10) — the part sibling clusters prefill redundantly
    under the flat layout."""
    assert subgraphs, "cannot intersect an empty set"
    out = subgraphs[0]
    for sg in subgraphs[1:]:
        out = out.intersection(sg)
    return out


def textualize(sg: Subgraph, node_text: Sequence[str]) -> str:
    """Render a subgraph as the prompt prefix (G-Retriever textualization).

    Nodes and edges are emitted in sorted id order so that identical
    subgraphs always produce byte-identical prompts — a precondition for
    prefix-cache hits.
    """
    return textualize_delta(sg, node_text)


def textualize_delta(sg: Subgraph, node_text: Sequence[str],
                     base: Optional[Subgraph] = None) -> str:
    """Render a subgraph SEGMENT: the content of ``sg`` not already in
    ``base`` (``base=None`` renders everything — the historical flat
    ``textualize``, byte-identical).

    This is the textualization of one prefix-chain segment
    (DESIGN.md §10): a path of nested contents C0 ⊆ C1 ⊆ ... ⊆ CL is
    rendered as ``T(C0) ++ T(C1 \\ C0) ++ ...``, so an ancestor's full
    path text is BY CONSTRUCTION a literal string prefix of every
    descendant's — the property that makes an ancestor's KV blocks
    reusable under every descendant chain.

    Order stability: emitted nodes and edges are SORTED inside each
    segment.  Set-difference iteration order (or any dependence on the
    order members were unioned into the representative) must never
    leak into the text — two chains over the same content sets must be
    byte-identical, or the ancestor text silently stops being a token
    prefix of its descendants and chain reuse serves wrong attention
    content (regression: tests/test_prefix_tree.py).
    """
    new_nodes = sg.nodes if base is None else sg.nodes - base.nodes
    new_edges = sg.edges if base is None else sg.edges - base.edges
    if base is not None:
        assert base.issubset(sg), \
            "chain segments require nested content (base ⊆ sg)"
    lines = ["node_id,node_attr"]
    for n in sorted(new_nodes):
        lines.append(f"{n},{node_text[n]}")
    lines.append("src,edge_attr,dst")
    for s, r, d in sorted(new_edges):
        lines.append(f"{s},{r},{d}")
    return "\n".join(lines)
