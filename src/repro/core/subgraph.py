"""Subgraph representation, union-merge, and prompt textualization.

The retrieved unit of graph-based RAG is a subgraph of the textual graph:
a set of node ids plus a set of (src, rel_text, dst) edges.  SubGCache's
representative subgraph for a cluster is the union of its members'
nodes and edges (paper §3.3) — order-normalized so that every member of
a cluster maps to the *identical* prompt prefix (the cached unit).
"""
from __future__ import annotations

import dataclasses
from typing import FrozenSet, Iterable, Sequence, Tuple

Edge = Tuple[int, str, int]


@dataclasses.dataclass(frozen=True)
class Subgraph:
    nodes: FrozenSet[int]
    edges: FrozenSet[Edge]

    @staticmethod
    def from_lists(nodes: Iterable[int], edges: Iterable[Edge]) -> "Subgraph":
        edges = frozenset((int(s), str(r), int(d)) for s, r, d in edges)
        nodes = frozenset(int(n) for n in nodes) | \
            frozenset(n for s, _, d in edges for n in (s, d))
        return Subgraph(nodes=nodes, edges=edges)

    def union(self, other: "Subgraph") -> "Subgraph":
        return Subgraph(nodes=self.nodes | other.nodes,
                        edges=self.edges | other.edges)

    @property
    def num_nodes(self) -> int:
        return len(self.nodes)

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    def jaccard(self, other: "Subgraph") -> float:
        """Structural overlap measure (diagnostics / tests)."""
        a = self.nodes | {("e",) + e for e in self.edges}
        b = other.nodes | {("e",) + e for e in other.edges}
        if not a and not b:
            return 1.0
        return len(a & b) / max(1, len(a | b))


def merge_subgraphs(subgraphs: Sequence[Subgraph]) -> Subgraph:
    """Representative subgraph = union of all members (paper §3.3)."""
    assert subgraphs, "cannot merge an empty cluster"
    out = subgraphs[0]
    for sg in subgraphs[1:]:
        out = out.union(sg)
    return out


def textualize(sg: Subgraph, node_text: Sequence[str]) -> str:
    """Render a subgraph as the prompt prefix (G-Retriever textualization).

    Nodes and edges are emitted in sorted id order so that identical
    subgraphs always produce byte-identical prompts — a precondition for
    prefix-cache hits.
    """
    lines = ["node_id,node_attr"]
    for n in sorted(sg.nodes):
        lines.append(f"{n},{node_text[n]}")
    lines.append("src,edge_attr,dst")
    for s, r, d in sorted(sg.edges):
        lines.append(f"{s},{r},{d}")
    return "\n".join(lines)
