"""Host-memory tier for the prefix cache: HBM → host → recompute
(DESIGN.md §12).

A ``PrefixPool`` eviction used to destroy a segment's blocks outright,
so the next hit on that cluster paid a full re-prefill — the exact miss
penalty SubGCache exists to avoid, and the dominant cost once the arena
budget is tight enough that flat AND tree layouts thrash (ROADMAP open
item 2).  RAGCache's knowledge-cache hierarchy is the precedent: keep
the bits, change the medium.

* **Demotion** — before an eviction releases a segment's device blocks,
  the pool gathers the rows page tables actually reference (compute
  K/V + positions, or int8 K/V + scales + positions for a quantized
  pool) into host ``numpy`` buffers, bitwise
  (``KVBlockPool.demote_blocks``).  The ``HostSegment`` records
  everything promotion needs to rebuild the ``PrefixState`` exactly:
  lengths, capacity, soft-token count, per-block token counts, and the
  POOL KEY of its chain parent (chain-aware promotion re-links through
  keys, not block ids — a recomputed ancestor carries different blocks
  but identical bits).
* **Promotion** — a later pool miss that finds a host segment allocates
  fresh prefix blocks, ``jax.device_put``s the host copy ASYNC, and
  scatters it into the prefix arena (``KVBlockPool.promote_blocks``).
  Nothing blocks: the scatter is ordered behind the transfer by data
  dependency, so the batch's suffix prefill overlaps it for free.  The
  transfer handle is parked here and drained at an explicit sync point
  — the drained block time is the RESIDUAL promotion wait after
  overlap (``CacheStats.tier_promotion_wait_s``).  The host copy is
  dropped only when the promotion commits (move semantics): a
  ``device_put`` failure or ``OutOfBlocks`` mid-promotion unwinds to a
  state where the host copy survives and recompute can take over.
* **Second-level eviction** — the tier has its OWN byte budget and the
  same cost-aware score the pool uses (age × segment tokens / hits);
  a host eviction is a true discard (device → host → gone).  Discards
  peel leaf-first: a segment that is the recorded parent of another
  hosted segment is never victimized while that descendant is hosted,
  mirroring the pool's ancestor-anchoring rule one tier down.

Pin semantics per tier: device entries pin via ``PoolEntry.refs`` (a
pinned entry is never evicted, hence never demoted — a demote that
loses a race with a same-key ``get(pin=True)`` aborts without copying);
host segments have no readers, so nothing pins them — only the
parent-of-hosted rule protects a segment from discard.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple


@dataclasses.dataclass
class HostSegment:
    """One demoted prefix/ancestor segment, keyed like the pool entry
    it was demoted from."""
    key: Any
    host: Any                    # pytree of numpy block rows (row i =
                                 # block i of the segment's page, bitwise)
    block_tokens: List[int]      # per-block stored-token counts
    nbytes: int                  # host buffer bytes (tier budget charge)
    prefix_len: int              # cumulative path tokens through segment
    page_length: int             # tokens in the segment's OWN page
    seg_len: Optional[int]       # segment-owned tokens (None for flat)
    capacity: int
    enc_len: int
    n_soft: int
    parent_key: Optional[Any]    # pool key of the chain parent (None
                                 # for flat / root segments)
    quantized: bool              # demoted from the int8 prefix arena
    prefill_s: float             # original prefill cost (re-admission
                                 # metadata for the pool's cost model)
    hits: int = 0
    last_used: float = dataclasses.field(default_factory=time.monotonic)

    @property
    def n_blocks(self) -> int:
        return len(self.block_tokens)


class HostTier:
    """Budgeted host-RAM store of demoted prefix segments (see module
    docstring).  ``stats`` is attached by the owning ``PrefixPool``."""

    def __init__(self, budget_bytes: int) -> None:
        self.budget_bytes = int(budget_bytes)
        self._segments: Dict[Any, HostSegment] = {}
        self.bytes_in_use = 0
        self.stats = None        # CacheStats, set by PrefixPool.attach
        # in-flight promotion transfers: (device handles, submit time);
        # drained (blocked on) at the scheduler's sync point to measure
        # the residual wait the serving path actually experienced
        self._inflight: List[Tuple[Any, float]] = []

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._segments)

    def __contains__(self, key) -> bool:
        return key in self._segments

    def get(self, key) -> Optional[HostSegment]:
        seg = self._segments.get(key)
        if seg is not None:
            seg.hits += 1
            seg.last_used = time.monotonic()
        return seg

    def peek(self, key) -> Optional[HostSegment]:
        """Lookup without touching recency/hits (prefetch probes)."""
        return self._segments.get(key)

    def pop(self, key) -> Optional[HostSegment]:
        """Remove a segment (promotion commit — move semantics)."""
        seg = self._segments.pop(key, None)
        if seg is not None:
            self.bytes_in_use -= seg.nbytes
        return seg

    def keys(self):
        return self._segments.keys()

    # ------------------------------------------------------------------
    def admit(self, seg: HostSegment) -> bool:
        """Store a demoted segment, discarding colder hosted segments
        to fit the byte budget (leaf-first; see ``_pick_discard``).  A
        segment larger than the whole budget is refused (counted as a
        discard — the content is lost either way)."""
        if seg.nbytes > self.budget_bytes:
            self._count(discards=1)
            return False
        old = self.pop(seg.key)
        if old is not None:      # re-demotion of a re-admitted key
            self._count(discards=1)
        while self.bytes_in_use + seg.nbytes > self.budget_bytes:
            victim = self._pick_discard()
            if victim is None:
                self._count(discards=1)
                return False
            self.pop(victim.key)
            self._count(discards=1)
        self._segments[seg.key] = seg
        self.bytes_in_use += seg.nbytes
        if self.stats is not None:
            self.stats.record_host(self)
        return True

    def _score(self, seg: HostSegment, now: float) -> float:
        """Cost-aware discard score (higher = colder): age × segment
        tokens / hits — the pool's eviction model one tier down."""
        age = max(now - seg.last_used, 1e-9)
        return age * max(1, seg.page_length) / max(1, seg.hits)

    def _pick_discard(self) -> Optional[HostSegment]:
        """Coldest hosted segment that is NOT the recorded parent of
        another hosted segment — discards peel chains leaf-first, so a
        hosted descendant's ancestry is never truncated under it.
        Every parent chain ends in a non-parent (chains are acyclic),
        so a victim exists whenever the tier is non-empty."""
        parents = {s.parent_key for s in self._segments.values()
                   if s.parent_key is not None}
        now = time.monotonic()
        worst, worst_score = None, -1.0
        for seg in self._segments.values():
            if seg.key in parents:
                continue
            sc = self._score(seg, now)
            if sc > worst_score:
                worst, worst_score = seg, sc
        return worst

    def _count(self, **kw) -> None:
        if self.stats is not None:
            self.stats.record_tier(**kw)
            self.stats.record_host(self)

    # ------------------------------------------------------------------
    # promotion transfer bookkeeping
    # ------------------------------------------------------------------
    def track_transfer(self, handle) -> None:
        """Park an in-flight ``device_put`` result for wait accounting."""
        self._inflight.append((handle, time.monotonic()))

    def drain_pending(self) -> float:
        """Block on every parked promotion transfer; returns (and
        records) the residual wall seconds the block actually took —
        ~0 when the transfer already overlapped other dispatched work
        (the async-promotion claim, measured not assumed)."""
        if not self._inflight:
            return 0.0
        import jax
        t0 = time.perf_counter()
        for handle, _ in self._inflight:
            jax.block_until_ready(handle)
        dt = time.perf_counter() - t0
        self._inflight.clear()
        self._count(promotion_wait_s=dt)
        return dt

    def clear(self) -> None:
        self._segments.clear()
        self.bytes_in_use = 0
        self._inflight.clear()
        if self.stats is not None:
            self.stats.record_host(self)
