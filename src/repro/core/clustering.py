"""Agglomerative hierarchical clustering (paper §3.2).

Own implementation (numpy, Lance–Williams recurrences) of the five linkage
strategies the paper ablates: ward (default), single, complete, average,
centroid.  Euclidean metric; the dendrogram is cut at a predefined number
of clusters, exactly as the paper's setup (App. A.2).

O(m^3) naive agglomeration — m is the in-batch query count (<= a few
hundred), so this is host-side noise next to LLM inference; the paper
measures the same (Fig. 4: < 2-6% of end-to-end latency).
"""
from __future__ import annotations

from typing import List

import numpy as np

LINKAGES = ("ward", "single", "complete", "average", "centroid")


def _pairwise_sq(x: np.ndarray) -> np.ndarray:
    n2 = np.sum(x * x, axis=1)
    d2 = n2[:, None] + n2[None, :] - 2.0 * (x @ x.T)
    np.fill_diagonal(d2, np.inf)
    return np.maximum(d2, 0.0)


def hierarchical_clustering(embeddings: np.ndarray, num_clusters: int,
                            linkage: str = "ward") -> np.ndarray:
    """Cluster row-vectors into ``num_clusters`` groups.

    Returns int labels [m] in {0..num_clusters-1}.
    """
    if linkage not in LINKAGES:
        raise ValueError(f"unknown linkage {linkage!r}; options: {LINKAGES}")
    x = np.asarray(embeddings, dtype=np.float64)
    m = x.shape[0]
    num_clusters = max(1, min(num_clusters, m))

    # squared Euclidean for ward/centroid (Lance-Williams exactness),
    # plain Euclidean for single/complete/average.
    d = _pairwise_sq(x)
    if linkage in ("single", "complete", "average"):
        d = np.sqrt(np.where(np.isfinite(d), d, np.inf))
        np.fill_diagonal(d, np.inf)

    active = list(range(m))
    size = np.ones(m)
    members: List[List[int]] = [[i] for i in range(m)]

    while len(active) > num_clusters:
        # find closest active pair
        sub = d[np.ix_(active, active)]
        flat = np.argmin(sub)
        ai, aj = np.unravel_index(flat, sub.shape)
        i, j = active[ai], active[aj]
        if i > j:
            i, j = j, i
        ni, nj, dij = size[i], size[j], d[i, j]

        # Lance-Williams update of d(k, i∪j) for every other active k
        for k in active:
            if k in (i, j):
                continue
            dik, djk, nk = d[i, k], d[j, k], size[k]
            if linkage == "single":
                new = min(dik, djk)
            elif linkage == "complete":
                new = max(dik, djk)
            elif linkage == "average":
                new = (ni * dik + nj * djk) / (ni + nj)
            elif linkage == "centroid":
                new = ((ni * dik + nj * djk) / (ni + nj)
                       - ni * nj * dij / (ni + nj) ** 2)
            else:  # ward
                new = ((ni + nk) * dik + (nj + nk) * djk - nk * dij) \
                    / (ni + nj + nk)
            d[i, k] = d[k, i] = new
        size[i] = ni + nj
        members[i] = members[i] + members[j]
        active.remove(j)
        d[j, :] = np.inf
        d[:, j] = np.inf

    labels = np.zeros(m, dtype=np.int64)
    for c, root in enumerate(active):
        for idx in members[root]:
            labels[idx] = c
    return labels
