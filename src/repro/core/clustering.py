"""Agglomerative hierarchical clustering (paper §3.2) as a reusable
dendrogram.

Own implementation (numpy, Lance–Williams recurrences) of the five
linkage strategies the paper ablates: ward (default), single, complete,
average, centroid.  Euclidean metric.

The agglomeration is GREEDY and target-independent: the first
``m - K`` merges are the same whatever ``K`` the caller eventually
wants, so the expensive O(m^3) part is computed ONCE per batch
(``build_dendrogram``) and every cut — the paper's ``num_clusters``
knob, a cluster sweep, or the multi-level cuts of a prefix tree
(DESIGN.md §10) — is a cheap O(m·merges) replay (``Dendrogram.cut``).
``hierarchical_clustering`` keeps the historical one-shot API as a
build + cut and produces byte-identical labels.

O(m^3) naive agglomeration — m is the in-batch query count (<= a few
hundred), so this is host-side noise next to LLM inference; the paper
measures the same (Fig. 4: < 2-6% of end-to-end latency).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

LINKAGES = ("ward", "single", "complete", "average", "centroid")


def _pairwise_sq(x: np.ndarray) -> np.ndarray:
    n2 = np.sum(x * x, axis=1)
    d2 = n2[:, None] + n2[None, :] - 2.0 * (x @ x.T)
    np.fill_diagonal(d2, np.inf)
    return np.maximum(d2, 0.0)


@dataclasses.dataclass
class Dendrogram:
    """The full agglomerative merge tree over ``m`` leaves.

    ``merges[t] = (i, j, height)``: at step ``t`` cluster slot ``j``
    merged into slot ``i`` (``i < j``; slot ids are original leaf
    indices — the surviving slot keeps its id) at linkage distance
    ``height``.  There are exactly ``m - 1`` merges; cutting after
    ``m - K`` of them leaves ``K`` clusters.  Merge order is what the
    greedy agglomeration chose, so replays are exact — not a
    re-clustering.
    """
    m: int
    linkage: str
    merges: List[Tuple[int, int, float]]

    def cut(self, num_clusters: int) -> np.ndarray:
        """Labels [m] in {0..K-1} for the ``num_clusters`` cut.

        Byte-identical to what the historical one-shot
        ``hierarchical_clustering`` produced: clusters are numbered by
        ascending surviving-slot id.
        """
        k = max(1, min(int(num_clusters), self.m))
        members: List[List[int]] = [[i] for i in range(self.m)]
        alive = [True] * self.m
        for i, j, _ in self.merges[: self.m - k]:
            members[i] = members[i] + members[j]
            alive[j] = False
        labels = np.zeros(self.m, dtype=np.int64)
        c = 0
        for root in range(self.m):
            if not alive[root]:
                continue
            for idx in members[root]:
                labels[idx] = c
            c += 1
        return labels

    def cut_members(self, num_clusters: int) -> List[List[int]]:
        """Member index lists per cluster, in cut-label order."""
        labels = self.cut(num_clusters)
        k = int(labels.max()) + 1 if self.m else 0
        out: List[List[int]] = [[] for _ in range(k)]
        for i, c in enumerate(labels.tolist()):
            out[c].append(i)
        return out


def build_dendrogram(embeddings: np.ndarray,
                     linkage: str = "ward") -> Dendrogram:
    """Run the full O(m^3) agglomeration once, recording every merge.

    Cuts at any ``num_clusters`` are then cheap replays — the cluster
    sweep (``benchmarks/fig3_cluster_sweep.py``) and the multi-level
    prefix-tree cuts (``core/planner.py::plan_prefix_tree``) both reuse
    one dendrogram instead of re-running the agglomeration per point.
    """
    if linkage not in LINKAGES:
        raise ValueError(f"unknown linkage {linkage!r}; options: {LINKAGES}")
    x = np.asarray(embeddings, dtype=np.float64)
    m = x.shape[0]

    # squared Euclidean for ward/centroid (Lance-Williams exactness),
    # plain Euclidean for single/complete/average.
    d = _pairwise_sq(x)
    if linkage in ("single", "complete", "average"):
        d = np.sqrt(np.where(np.isfinite(d), d, np.inf))
        np.fill_diagonal(d, np.inf)

    active = list(range(m))
    size = np.ones(m)
    merges: List[Tuple[int, int, float]] = []

    while len(active) > 1:
        # find closest active pair
        sub = d[np.ix_(active, active)]
        flat = np.argmin(sub)
        ai, aj = np.unravel_index(flat, sub.shape)
        i, j = active[ai], active[aj]
        if i > j:
            i, j = j, i
        ni, nj, dij = size[i], size[j], d[i, j]
        merges.append((i, j, float(dij)))

        # Lance-Williams update of d(k, i∪j) for every other active k
        for k in active:
            if k in (i, j):
                continue
            dik, djk, nk = d[i, k], d[j, k], size[k]
            if linkage == "single":
                new = min(dik, djk)
            elif linkage == "complete":
                new = max(dik, djk)
            elif linkage == "average":
                new = (ni * dik + nj * djk) / (ni + nj)
            elif linkage == "centroid":
                new = ((ni * dik + nj * djk) / (ni + nj)
                       - ni * nj * dij / (ni + nj) ** 2)
            else:  # ward
                new = ((ni + nk) * dik + (nj + nk) * djk - nk * dij) \
                    / (ni + nj + nk)
            d[i, k] = d[k, i] = new
        size[i] = ni + nj
        active.remove(j)
        d[j, :] = np.inf
        d[:, j] = np.inf
    return Dendrogram(m=m, linkage=linkage, merges=merges)


def hierarchical_clustering(embeddings: np.ndarray, num_clusters: int,
                            linkage: str = "ward") -> np.ndarray:
    """Cluster row-vectors into ``num_clusters`` groups.

    Returns int labels [m] in {0..num_clusters-1}.  One-shot facade:
    callers cutting more than once should ``build_dendrogram`` and
    ``cut`` themselves.
    """
    return build_dendrogram(embeddings, linkage).cut(num_clusters)
