"""Subgraph -> embedding via the pipeline's pretrained GNN (paper §3.2)."""
from __future__ import annotations

from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.subgraph import Subgraph
from repro.rag.retriever import RetrieverIndex


def subgraph_tensors(index: RetrieverIndex, sg: Subgraph):
    """Extract (node_feats [n,F], senders [e], receivers [e], edge_feats [e,F])
    with node ids relabelled to 0..n-1.  Self-loops added so isolated nodes
    still receive messages."""
    nodes = sorted(sg.nodes)
    relabel = {n: i for i, n in enumerate(nodes)}
    node_feats = index.node_vecs[nodes]
    edge_pos = {e: i for i, e in enumerate(index.graph.edges)}
    senders, receivers, efeats = [], [], []
    for e in sorted(sg.edges):
        s, _, d = e
        senders.append(relabel[s])
        receivers.append(relabel[d])
        ei = edge_pos.get(e)
        efeats.append(index.edge_vecs[ei] if ei is not None
                      else np.zeros(index.node_vecs.shape[1], np.float32))
    for i in range(len(nodes)):              # self loops
        senders.append(i)
        receivers.append(i)
        efeats.append(np.zeros(index.node_vecs.shape[1], np.float32))
    return (jnp.asarray(node_feats), jnp.asarray(senders, jnp.int32),
            jnp.asarray(receivers, jnp.int32),
            jnp.asarray(np.stack(efeats)))


def embed_subgraphs(index: RetrieverIndex, subgraphs: Sequence[Subgraph],
                    gnn_params: dict,
                    gnn_apply: Callable) -> np.ndarray:
    """Encode each retrieved subgraph with the pretrained GNN; mean-pool."""
    out = []
    for sg in subgraphs:
        x, snd, rcv, ef = subgraph_tensors(index, sg)
        h = gnn_apply(gnn_params, x, snd, rcv, ef)
        out.append(np.asarray(jnp.mean(h, axis=0)))
    return np.stack(out)
