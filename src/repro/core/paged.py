"""Paged KV cache: one block-pool address space for prefixes and
suffixes (DESIGN.md §8).

SubGCache's asset is a representative-prefix KV cache reused across
cluster members.  Through PR 2 that asset lived in three incompatible
layouts (live batch-1 buffers, broadcast copies, a padded [NP, ...]
stacked pool) plus a fourth contiguous per-request suffix cache.  This
module collapses them into ONE block-granular, reference-counted
address space, the way RAGCache pools document-chunk KV:

* ``KVBlockPool`` — the physical arena: per attention layer one
  ``[num_blocks, block_size, Hkv, D]`` K/V buffer (plus a
  ``[num_blocks, block_size]`` position buffer) under a fixed HBM byte
  budget.  Block 0 is the permanently-empty NULL block (positions -1,
  refcount pinned) — page tables pad with it, so out-of-range table
  entries are masked by the same positional rule as every other empty
  slot.
* ``BlockAllocator`` — host-side free list + per-block reference
  counts.  A prefix shared by a whole cluster is one set of blocks with
  refcount = (pool resident) + (in-flight readers); eviction and batch
  completion are ``decref``s, and a block returns to the free list only
  when the last reference drops — an evicted-but-in-flight prefix can
  never be reallocated under a running batch.
* ``PageTable`` — a request's logical->physical map: an ordered block
  list plus the token length.  Every member of a cluster maps the SAME
  representative-prefix blocks (sharing is free); only suffix blocks
  are private.
* **Copy-on-write** — ``KVBlockPool.cow`` returns a block safe to
  write: the block itself when uniquely referenced, otherwise a fresh
  copy (refcount on the original dropped by one).  Writers (prefix
  extension, re-prefill into a partially shared run) never mutate KV
  that another page table still reads.

The pool stores and copies KV; it never computes attention.  The
compute side is ``models/attention.py`` (``attend_paged`` /
``cache_write_paged``) and the paged Pallas kernels in
``kernels/shared_prefix.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.bucketing import blocks_for

NULL_BLOCK = 0


class OutOfBlocks(RuntimeError):
    """The arena has no free blocks left (after any reclaim attempt)."""


# ======================================================================
# host-side allocation
# ======================================================================
class BlockAllocator:
    """Free-list block allocator with per-block reference counts.

    Block ``NULL_BLOCK`` (= 0) is reserved and permanently referenced.
    ``reclaim_hook(n)`` — optionally installed by ``PrefixPool`` — is
    called when an allocation finds fewer than ``n`` free blocks; it
    should evict cold pooled prefixes (dropping their references) and
    return, after which the allocation retries once.
    """

    def __init__(self, num_blocks: int) -> None:
        assert num_blocks >= 2, "need at least the null block + one usable"
        self.num_blocks = int(num_blocks)
        self._refs = np.zeros(num_blocks, np.int32)
        self._refs[NULL_BLOCK] = 1          # never allocatable
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self.reclaim_hook: Optional[Callable[[int], None]] = None

    # ------------------------------------------------------------------
    @property
    def num_usable(self) -> int:
        return self.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.num_usable - len(self._free)

    def refcount(self, bid: int) -> int:
        return int(self._refs[bid])

    # ------------------------------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Take ``n`` blocks (refcount 1 each).  On shortage, asks the
        ``reclaim_hook`` to evict pooled prefixes once, then raises
        ``OutOfBlocks`` if still short — the caller sized the arena."""
        if len(self._free) < n and self.reclaim_hook is not None:
            self.reclaim_hook(n)
        if len(self._free) < n:
            raise OutOfBlocks(
                f"need {n} blocks, {len(self._free)} free of "
                f"{self.num_usable} (evicted-but-in-flight blocks free "
                "when their batch releases; raise arena_blocks otherwise)")
        out = [self._free.pop() for _ in range(n)]
        self._refs[out] = 1
        return out

    def incref(self, bids: Sequence[int]) -> None:
        for b in bids:
            assert self._refs[b] > 0, f"incref on free block {b}"
            self._refs[b] += 1

    def decref(self, bids: Sequence[int]) -> List[int]:
        """Drop one reference per block; blocks reaching zero return to
        the free list.  Returns the freed block ids."""
        freed = []
        for b in bids:
            assert b != NULL_BLOCK and self._refs[b] > 0, \
                f"decref on {'null' if b == NULL_BLOCK else 'free'} block {b}"
            self._refs[b] -= 1
            if self._refs[b] == 0:
                self._free.append(b)
                freed.append(b)
        return freed


# ======================================================================
# page tables
# ======================================================================
@dataclasses.dataclass
class ComposedRow:
    """One request's pinned prefix-row layout under a composition plan
    (``KVBlockPool.compose``, DESIGN.md §14): parallel per-block lists —
    the page walk, each block's position re-base delta, and the leading
    slots masked because their tokens are recomputed fresh.  ``pinned``
    is what the caller must ``decref`` when serving completes."""
    blocks: List[int]
    offsets: List[int]
    skips: List[int]
    pinned: List[int]


@dataclasses.dataclass
class PageTable:
    """One request's logical->physical block map.

    ``blocks[i]`` holds tokens ``[i * block_size, (i+1) * block_size)``
    of the sequence this table describes; ``length`` is the number of
    tokens actually stored.  ``row(width)`` pads with the NULL block —
    masked positionally, never read as live KV.
    """
    blocks: List[int]
    length: int

    def row(self, width: int) -> np.ndarray:
        assert len(self.blocks) <= width, (len(self.blocks), width)
        out = np.full(width, NULL_BLOCK, np.int32)
        out[:len(self.blocks)] = self.blocks
        return out


# ======================================================================
# device arena
# ======================================================================
def _leaf_axes(path) -> tuple:
    """(seq_axis, block_axis) for an arena/cache leaf (negative; leading
    scanned-group dims allowed)."""
    key = getattr(path[-1], "key", None) if path else None
    if key in ("k", "v"):
        return -3, -4
    if key == "pos":
        return -1, -2
    if key in ("k_scale", "v_scale"):   # quantized arena [.., NB, Hkv]
        return None, -2
    raise ValueError(f"paged arenas hold attention KV only; got {path}")


def _tree_get(tree, path):
    """Navigate a pytree by a tree_map_with_path key path."""
    cur = tree
    for p in path:
        cur = cur[p.key if hasattr(p, "key") else p.idx]
    return cur


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("n", "block_size"))
def _scatter_prefix(arena, dense, bids, *, n: int, block_size: int):
    """Copy the first ``n * block_size`` sequence slots of a batch-1
    dense cache into arena blocks ``bids`` (donated, in place)."""
    want = n * block_size

    def scat(path, a, d):
        seq_ax, blk_ax = _leaf_axes(path)
        d = jnp.moveaxis(d, blk_ax, 0)[0]   # drop batch-1 dim (seq_ax holds)
        d = jnp.moveaxis(d, seq_ax, 0)      # seq to front
        if d.shape[0] < want:               # windowed dense cache is shorter
            fill = -1 if getattr(path[-1], "key", None) == "pos" else 0
            pad = [(0, want - d.shape[0])] + [(0, 0)] * (d.ndim - 1)
            d = jnp.pad(d, pad, constant_values=fill)
        d = d[:want].reshape((n, block_size) + d.shape[1:])
        d = jnp.moveaxis(d, 1, seq_ax)      # in-block slots at the seq axis
        a = jnp.moveaxis(a, blk_ax, 0)
        a = a.at[bids].set(d.astype(a.dtype))
        return jnp.moveaxis(a, 0, blk_ax)
    return jax.tree_util.tree_map_with_path(scat, arena, dense)


@functools.partial(jax.jit, donate_argnums=(0,))
def _reset_pos(arena, bids):
    """Mark blocks ``bids`` empty (pos = -1).  Freed blocks are recycled
    with stale contents; resetting positions is what guarantees a fresh
    suffix allocation exposes no previous request's keys."""
    def f(path, x):
        if getattr(path[-1], "key", None) != "pos":
            return x
        _, blk_ax = _leaf_axes(path)
        x = jnp.moveaxis(x, blk_ax, 0)
        x = x.at[bids].set(-1)
        return jnp.moveaxis(x, 0, blk_ax)
    return jax.tree_util.tree_map_with_path(f, arena)


def reset_pos_rows(arena_like, rows) -> dict:
    """Mark block rows ``rows`` of ``arena_like`` empty (pos = -1;
    donated, in place).  Works on the main arena and on the compact
    decode sub-arenas continuous serving keeps resident
    (``KVBlockPool.sub_arena``): slot reuse is a position reset on the
    retiring tenant's rows, never a reallocation — the arena never
    churns (DESIGN.md §9)."""
    return _reset_pos(arena_like, jnp.asarray(rows, jnp.int32))


@jax.jit
def _extract_blocks(arena, bids):
    """Gather arena rows ``bids`` into a compact sub-arena (read-only;
    see ``KVBlockPool.extract``)."""
    def f(path, x):
        _, blk_ax = _leaf_axes(path)
        xb = jnp.moveaxis(x, blk_ax, 0)[bids]
        return jnp.moveaxis(xb, 0, blk_ax)
    return jax.tree_util.tree_map_with_path(f, arena)


def _qarena_like(node):
    """Mirror an arena pytree into the int8 quantized-prefix layout:
    each attention leaf dict gains per-(block, kv-head) f32
    ``k_scale``/``v_scale`` [.., NB, Hkv] next to int8 K/V and an int32
    position copy.  Positions start at -1 everywhere (including the
    NULL block), so an un-quantized row can never read as live KV."""
    if isinstance(node, dict) and "k" in node and "pos" in node:
        k = node["k"]                      # [.., NB, bs, Hkv, D]
        scale_shape = k.shape[:-3] + (k.shape[-2],)
        return {
            "k": jnp.zeros(k.shape, jnp.int8),
            "v": jnp.zeros(k.shape, jnp.int8),
            "pos": jnp.full(node["pos"].shape, -1, jnp.int32),
            "k_scale": jnp.ones(scale_shape, jnp.float32),
            "v_scale": jnp.ones(scale_shape, jnp.float32),
        }
    if isinstance(node, dict):
        return {kk: _qarena_like(vv) for kk, vv in node.items()}
    if isinstance(node, (list, tuple)):
        return type(node)(_qarena_like(v) for v in node)
    return node


@functools.partial(jax.jit, donate_argnums=(0,))
def _quantize_blocks(qarena, arena, src_bids, dst_bids):
    """Quantize arena rows ``src_bids`` into int8-prefix-arena rows
    ``dst_bids`` (donated, in place): per (block, kv-head) symmetric
    scales ``amax / 127`` over the block's (slot, head_dim) tile,
    values rounded and clipped to [-127, 127]; positions copied
    verbatim.  Zero blocks get scale 1.0 so dequant stays exact.

    ``src`` and ``dst`` are SEPARATE id spaces for a quantized pool:
    compute-dtype staging rows feed int8 prefix rows, and the staging
    rows go back to the suffix free list once the copy commits
    (``KVBlockPool.write_prefix``)."""
    def rows_and_scale(path, which):
        src = _tree_get(arena, path[:-1])[which]       # [.., NB, bs, Hkv, D]
        x = jnp.moveaxis(src, -4, 0)[src_bids].astype(jnp.float32)
        amax = jnp.max(jnp.abs(x), axis=(-3, -1))      # [n, .., Hkv]
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        return x, scale

    def f(path, q):
        key = path[-1].key
        if key in ("k", "v"):
            x, scale = rows_and_scale(path, key)
            qr = jnp.clip(jnp.round(x / scale[..., None, :, None]),
                          -127, 127).astype(jnp.int8)
            q2 = jnp.moveaxis(q, -4, 0).at[dst_bids].set(qr)
            return jnp.moveaxis(q2, 0, -4)
        if key in ("k_scale", "v_scale"):
            _, scale = rows_and_scale(path, key[0])
            q2 = jnp.moveaxis(q, -2, 0).at[dst_bids].set(scale)
            return jnp.moveaxis(q2, 0, -2)
        assert key == "pos", path
        src = jnp.moveaxis(_tree_get(arena, path), -2, 0)[src_bids]
        q2 = jnp.moveaxis(q, -2, 0).at[dst_bids].set(src)
        return jnp.moveaxis(q2, 0, -2)
    return jax.tree_util.tree_map_with_path(f, qarena)


@functools.partial(jax.jit, static_argnames=("start", "n", "n_tokens",
                                             "block_size"))
def _gather_span(arena, rows, *, start: int, n: int, n_tokens: int,
                 block_size: int):
    """Repack a token span living at slot offset ``start`` of arena
    rows ``rows`` into a compact ``n``-row sub-arena aligned at slot 0
    (gap-span capture, DESIGN.md §15).  Non-donating: the arena stays
    live — the caller scatters the result into fresh blocks.  Tail
    slots past ``n_tokens`` get position -1 (the source rows may hold a
    neighboring span's tokens there; positional masking must never
    expose them under the captured segment)."""
    want = n * block_size

    def f(path, x):
        seq_ax, blk_ax = _leaf_axes(path)
        is_pos = getattr(path[-1], "key", None) == "pos"
        xb = jnp.moveaxis(x, blk_ax, 0)[rows]        # [R, .., bs, tail..]
        lead_seq = xb.ndim + seq_ax                  # slot axis, absolute
        xb = jnp.moveaxis(xb, lead_seq, 1)           # [R, bs, lead.., tail..]
        xb = xb.reshape((xb.shape[0] * block_size,) + xb.shape[2:])
        pad = [(0, want)] + [(0, 0)] * (xb.ndim - 1)
        xb = jnp.pad(xb, pad, constant_values=-1 if is_pos else 0)
        xb = xb[start:start + want]
        if is_pos:
            live = jnp.arange(want) < n_tokens
            xb = jnp.where(live.reshape((want,) + (1,) * (xb.ndim - 1)),
                           xb, -1)
        xb = xb.reshape((n, block_size) + xb.shape[1:])
        xb = jnp.moveaxis(xb, 1, lead_seq)           # slots back at seq_ax
        return jnp.moveaxis(xb, 0, blk_ax)
    return jax.tree_util.tree_map_with_path(f, arena)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_blocks(arena, sub, bids):
    """Scatter a compact sub-arena (row i = block ``bids[i]``) back
    into arena rows ``bids`` (donated, in place) — the inverse of
    ``_extract_blocks``, and the device half of host-tier promotion:
    ``sub`` is the freshly ``device_put`` copy of a demoted segment.
    Same-dtype leaves make the round trip bitwise."""
    def f(path, a, s):
        _, blk_ax = _leaf_axes(path)
        a2 = jnp.moveaxis(a, blk_ax, 0)
        s2 = jnp.moveaxis(s, blk_ax, 0)
        a2 = a2.at[bids].set(s2.astype(a2.dtype))
        return jnp.moveaxis(a2, 0, blk_ax)
    return jax.tree_util.tree_map_with_path(f, arena, sub)


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_block(arena, src, dst):
    """Duplicate one block row (copy-on-write)."""
    def f(path, x):
        _, blk_ax = _leaf_axes(path)
        x = jnp.moveaxis(x, blk_ax, 0)
        x = x.at[dst].set(x[src])
        return jnp.moveaxis(x, 0, blk_ax)
    return jax.tree_util.tree_map_with_path(f, arena)


class KVBlockPool:
    """The paged-KV physical address space for one model (see module
    docstring).  Attention-only stacks; ``arena`` leaves are
    ``init_block_arena`` shapes and flow through ``forward`` exactly
    like a dense cache whose batch dim is ``num_blocks`` and capacity is
    ``block_size`` — jits donate it, callers reassign ``pool.arena``.

    With ``quantize_prefix=True`` the pool runs TWO id spaces:
    ``allocator`` addresses int8 ``qarena`` rows (prefix blocks — what
    budgets price and page tables reference), and ``suffix_allocator``
    addresses compute-dtype ``arena`` rows (suffix/decode KV plus
    transient prefill staging).  ``write_prefix`` stages through arena
    rows and returns them to the suffix free list once the int8 copy
    commits, and the two arenas are sized SEPARATELY
    (``suffix_blocks``): prefix residency never allocates matching
    compute-dtype rows, so a quantized pool's device footprint is the
    priced int8 layout plus an independently sized suffix working set —
    not a dead full-precision shadow of the prefix arena (the ROADMAP
    "dead device storage" debt).  Without quantization both names alias
    ONE allocator — the single address space of DESIGN.md §8,
    unchanged.
    """

    def __init__(self, cfg, num_blocks: int, block_size: int, *,
                 quantize_prefix: bool = False,
                 suffix_blocks: Optional[int] = None) -> None:
        from repro.models import model as M
        assert num_blocks >= 2 and block_size >= 1
        self.cfg = cfg
        self.block_size = int(block_size)
        self.num_blocks = int(num_blocks)
        self.quantize_prefix = bool(quantize_prefix)
        if not quantize_prefix:
            assert suffix_blocks is None or suffix_blocks == num_blocks, \
                "one address space: suffix_blocks only splits a " \
                "quantized pool"
            suffix_blocks = num_blocks
        elif suffix_blocks is None:
            suffix_blocks = num_blocks
        assert suffix_blocks >= 2
        self.suffix_blocks = int(suffix_blocks)
        # compute-dtype arena: the ONLY arena (and the prefix home) when
        # unquantized; the suffix/staging space (suffix_blocks rows)
        # when quantized
        self.arena = M.init_block_arena(cfg, suffix_blocks, block_size)
        # int8 prefix arena + per-(block, kv-head) f32 scales, populated
        # at write_prefix / quantize_blocks time (DESIGN.md §11); None
        # when quantization is off.  Built from an eval_shape template
        # at num_blocks rows — its row count is independent of the
        # compute arena's.
        if quantize_prefix:
            template = jax.eval_shape(
                lambda: M.init_block_arena(cfg, num_blocks, block_size))
            self.qarena = _qarena_like(template)
        else:
            self.qarena = None
        self.allocator = BlockAllocator(num_blocks)
        self.suffix_allocator = (BlockAllocator(suffix_blocks)
                                 if quantize_prefix else self.allocator)
        # tokens actually stored per block (internal-fragmentation stat)
        self._block_tokens = np.zeros(num_blocks, np.int64)
        self._sfx_tokens = (np.zeros(suffix_blocks, np.int64)
                            if quantize_prefix else self._block_tokens)

    # ------------------------------------------------------------------
    # geometry / accounting
    # ------------------------------------------------------------------
    @staticmethod
    def block_bytes_for(cfg, block_size: int, *, kv_itemsize=None,
                        scale_bytes: int = 0) -> int:
        """HBM bytes one block costs across all attention layers.

        Defaults to the compute dtype's itemsize; pass ``kv_itemsize``
        (and per-block ``scale_bytes``) to price a different arena
        layout — byte accounting must reflect the dtype of the arena a
        block actually resides in, or an int8 pool under-reports
        occupancy and over-admits."""
        from repro.models.layers import dtype_of
        itemsize = (jnp.dtype(dtype_of(cfg.dtype)).itemsize
                    if kv_itemsize is None else int(kv_itemsize))
        n_attn = len(cfg.layer_specs())
        kv = 2 * block_size * cfg.num_kv_heads * cfg.head_dim_ * itemsize
        pos = block_size * 4
        return n_attn * (kv + pos + scale_bytes)

    @classmethod
    def prefix_block_bytes_for(cls, cfg, block_size: int, *,
                               quantize_prefix: bool = False) -> int:
        """Bytes one PREFIX-resident block costs: the int8 layout
        (1-byte K/V + two f32 scales per kv-head) when quantized, else
        the compute-dtype layout."""
        if not quantize_prefix:
            return cls.block_bytes_for(cfg, block_size)
        return cls.block_bytes_for(cfg, block_size, kv_itemsize=1,
                                   scale_bytes=2 * cfg.num_kv_heads * 4)

    @classmethod
    def from_budget(cls, cfg, budget_bytes: int, block_size: int, *,
                    quantize_prefix: bool = False,
                    suffix_blocks: Optional[int] = None) -> "KVBlockPool":
        """Largest arena fitting ``budget_bytes`` (plus the null block).

        The budget prices blocks at their PREFIX-resident layout — int8
        halves the per-block cost, so the same budget holds ~2× the
        blocks (and path tokens); the regression test pins that ratio.

        A quantized pool's compute-dtype SUFFIX arena is sized
        separately: ``suffix_blocks`` when given, else the block count
        the same budget buys at compute dtype (what an unquantized pool
        would have offered suffixes).  The int8 capacity win applies to
        prefix residency only — sizing the suffix space at the doubled
        int8 count would silently allocate ~2× the budget in dead
        full-precision rows (the ROADMAP dead-storage debt)."""
        per = cls.prefix_block_bytes_for(cfg, block_size,
                                         quantize_prefix=quantize_prefix)
        if quantize_prefix and suffix_blocks is None:
            suffix_blocks = max(
                2, budget_bytes // cls.block_bytes_for(cfg, block_size) + 1)
        return cls(cfg, max(2, budget_bytes // per + 1), block_size,
                   quantize_prefix=quantize_prefix,
                   suffix_blocks=suffix_blocks)

    @property
    def block_bytes(self) -> int:
        return self.block_bytes_for(self.cfg, self.block_size)

    @property
    def prefix_block_bytes(self) -> int:
        """Per-block bytes at the layout prefix blocks actually occupy
        (int8 + scales when quantized).  This is what pool budgets and
        ``PrefixPool`` charge — NOT the compute-dtype ``block_bytes``."""
        return self.prefix_block_bytes_for(
            self.cfg, self.block_size, quantize_prefix=self.quantize_prefix)

    @property
    def device_bytes(self) -> int:
        """Total device-resident arena bytes at the layouts actually
        allocated: ``suffix_blocks`` compute-dtype rows plus — when
        quantized — ``num_blocks`` int8+scales prefix rows.  The
        satellite regression pins that this equals the summed leaf
        bytes (no dead full-precision shadow of the prefix arena)."""
        total = self.suffix_blocks * self.block_bytes
        if self.quantize_prefix:
            total += self.num_blocks * self.prefix_block_bytes
        return total

    @property
    def blocks_in_use(self) -> int:
        """In-use blocks across BOTH id spaces (they coincide for an
        unquantized pool)."""
        n = self.allocator.blocks_in_use
        if self.suffix_allocator is not self.allocator:
            n += self.suffix_allocator.blocks_in_use
        return n

    @property
    def prefix_blocks_in_use(self) -> int:
        """Blocks resident in the PREFIX space only — the rows budgets
        price (`prefix_block_bytes` each).  For a quantized pool this
        excludes compute-dtype suffix/staging rows; the satellite-4
        regression pins that this agrees with ``from_budget`` sizing."""
        return self.allocator.blocks_in_use

    @property
    def free_blocks(self) -> int:
        return self.allocator.free_blocks

    @property
    def free_suffix_blocks(self) -> int:
        return self.suffix_allocator.free_blocks

    @property
    def tokens_stored(self) -> int:
        n = int(self._block_tokens.sum())
        if self._sfx_tokens is not self._block_tokens:
            n += int(self._sfx_tokens.sum())
        return n

    @property
    def fragmentation(self) -> float:
        """Fraction of in-use KV slots holding no token (pad waste a
        padded-to-capacity pool would hide inside every entry)."""
        slots = self.blocks_in_use * self.block_size
        return 1.0 - self.tokens_stored / slots if slots else 0.0

    def blocks_needed(self, n_tokens: int) -> int:
        return blocks_for(n_tokens, self.block_size)

    # ------------------------------------------------------------------
    # allocation / sharing
    # ------------------------------------------------------------------
    def alloc(self, n_blocks: int, *, suffix: bool = False) -> List[int]:
        """Take blocks from the prefix space, or — ``suffix=True`` —
        from the suffix space (compute-dtype arena rows; same space
        when quantization is off)."""
        a = self.suffix_allocator if suffix else self.allocator
        return a.alloc(n_blocks)

    def incref(self, bids: Sequence[int]) -> None:
        self.allocator.incref(bids)

    def decref(self, bids: Sequence[int], *,
               suffix: bool = False) -> List[int]:
        a = self.suffix_allocator if suffix else self.allocator
        toks = self._sfx_tokens if suffix else self._block_tokens
        freed = a.decref(bids)
        if freed:
            toks[freed] = 0
        return freed

    def note_tokens(self, bids: Sequence[int], n_tokens: int, *,
                    suffix: bool = False) -> None:
        """Record how many tokens an allocation actually stores (fills
        blocks in order; feeds the fragmentation counter)."""
        toks = self._sfx_tokens if suffix else self._block_tokens
        left = n_tokens
        for b in bids:
            toks[b] = min(left, self.block_size)
            left = max(0, left - self.block_size)

    # ------------------------------------------------------------------
    # device ops
    # ------------------------------------------------------------------
    def write_prefix(self, dense_cache, prefix_len: int) -> PageTable:
        """Copy a batch-1 dense prefix cache into freshly allocated
        prefix blocks; returns the page table (refcount 1,
        caller-owned).

        Quantized pools stage through suffix-space arena rows: scatter
        the dense cache at compute dtype, quantize into fresh int8
        prefix rows, then return the staging rows to the suffix free
        list — the resident prefix occupies ONLY the int8 layout the
        budget priced."""
        n = self.blocks_needed(prefix_len)
        if self.qarena is None:
            bids = self.alloc(n)
            self.arena = _scatter_prefix(self.arena, dense_cache,
                                         jnp.asarray(bids, jnp.int32),
                                         n=n, block_size=self.block_size)
            self.note_tokens(bids, prefix_len)
            return PageTable(blocks=bids, length=prefix_len)
        stage = self.alloc(n, suffix=True)
        try:
            self.arena = _scatter_prefix(self.arena, dense_cache,
                                         jnp.asarray(stage, jnp.int32),
                                         n=n, block_size=self.block_size)
            bids = self.alloc(n)
        except BaseException:
            self.decref(stage, suffix=True)
            raise
        self.quantize_blocks(stage, bids)
        self.decref(stage, suffix=True)
        self.note_tokens(bids, prefix_len)
        return PageTable(blocks=bids, length=prefix_len)

    def quantize_blocks(self, src_bids: Sequence[int],
                        dst_bids: Optional[Sequence[int]] = None) -> None:
        """Quantize arena rows ``src_bids`` into int8 prefix rows
        ``dst_bids`` (no-op when quantization is off).  Called whenever
        tokens become prefix-resident: ``write_prefix`` staging and
        after a prefix-extension prefill writes its tail into staging
        rows.  Suffix blocks are never quantized — decode writes them
        every step and reads them back at compute dtype."""
        if self.qarena is None or not len(src_bids):
            return
        dst = src_bids if dst_bids is None else dst_bids
        self.qarena = _quantize_blocks(self.qarena, self.arena,
                                       jnp.asarray(src_bids, jnp.int32),
                                       jnp.asarray(dst, jnp.int32))

    def compose(self, comp) -> ComposedRow:
        """Pin a ``SegmentComposition``'s cached segments and emit the
        prefix-row layout serving needs: per-block (page id, position
        offset, leading-slot skip) triples (DESIGN.md §14).

        Each spliced segment contributes its OWN page blocks only
        (ancestors are never read); the blocks are ``incref``ed here for
        the serve's duration — the returned ``pinned`` list is the
        caller's to ``decref``, exception-safe like every other pin in
        the engine.  Segments must be paged states of THIS pool."""
        for s in comp.segments:
            st = s.state
            assert st.is_paged and st.block_pool is self, \
                "composition needs page-table states from this pool"
        blocks, offsets, skips = comp.page_plan(self.block_size)
        pinned: List[int] = []
        try:
            for s in comp.segments:
                own = list(s.state.page.blocks)
                self.incref(own)
                pinned.extend(own)
        except BaseException:
            if pinned:
                self.decref(pinned)
            raise
        assert len(pinned) == len(blocks), (len(pinned), len(blocks))
        return ComposedRow(blocks=blocks, offsets=offsets, skips=skips,
                           pinned=pinned)

    def cache_span(self, row_bids: Sequence[int], start_slot: int,
                   n_tokens: int, *, src=None) -> List[int]:
        """Capture a freshly prefilled token span into the prefix space
        (gap-span caching, DESIGN.md §15).

        The span lives at slot offset ``start_slot`` of the suffix rows
        ``row_bids`` (the serving row's suffix table, slot = position -
        slot_off); it is gathered, re-aligned so token ``i`` lands in
        block ``i // block_size`` slot ``i % block_size`` (the layout
        ``SegmentComposition.page_plan`` assumes for cached segments),
        and scattered into ``ceil(n_tokens / block_size)`` freshly
        allocated prefix blocks — positions copied verbatim, so the
        segment's stored (canonical) base position is the span's
        absolute offset in the composition it was prefilled under.
        Quantized pools stage through suffix rows exactly like
        ``write_prefix``.  ``src`` overrides the arena the span is
        gathered FROM (continuous serving's compact decode sub-arena,
        whose rows ``row_bids`` then index; same geometry); the
        captured blocks always land in THIS pool's prefix space.
        Returns the new block ids (refcount 1, caller-owned)."""
        assert n_tokens >= 1
        bs = self.block_size
        n = self.blocks_needed(n_tokens)
        first = start_slot // bs
        last = (start_slot + n_tokens - 1) // bs
        rows = [int(row_bids[i]) for i in range(first, last + 1)]
        sub = _gather_span(self.arena if src is None else src,
                           jnp.asarray(rows, jnp.int32),
                           start=start_slot - first * bs, n=n,
                           n_tokens=n_tokens, block_size=bs)
        if self.qarena is None:
            bids = self.alloc(n)
            try:
                self.arena = _scatter_blocks(self.arena, sub,
                                             jnp.asarray(bids, jnp.int32))
            except BaseException:
                self.decref(bids)
                raise
            self.note_tokens(bids, n_tokens)
            return bids
        stage = self.alloc(n, suffix=True)
        bids: Optional[List[int]] = None
        try:
            self.arena = _scatter_blocks(self.arena, sub,
                                         jnp.asarray(stage, jnp.int32))
            bids = self.alloc(n)
        except BaseException:
            self.decref(stage, suffix=True)
            if bids is not None:
                self.decref(bids)
            raise
        self.quantize_blocks(stage, bids)
        self.decref(stage, suffix=True)
        self.note_tokens(bids, n_tokens)
        return bids

    def prefix_source(self):
        """The arena decode-time readers should pass as the PREFIX
        operand: the int8 quantized arena when quantization is on
        (attention dequantizes — in-register in the fused kernel), else
        the main arena."""
        return self.qarena if self.qarena is not None else self.arena

    def alloc_suffix(self, n_blocks: int) -> List[int]:
        """Fresh private suffix-space blocks for a request's
        suffix+decode tail, positions reset so no stale keys from a
        previous tenant leak."""
        bids = self.alloc(n_blocks, suffix=True)
        self.arena = _reset_pos(self.arena, jnp.asarray(bids, jnp.int32))
        return bids

    def cow(self, bid: int) -> int:
        """Return a PREFIX block safe to WRITE: ``bid`` itself when
        uniquely referenced, else a fresh copy (dropping one reference
        on the original).  Callers holding a shared page table swap the
        copied id into their own table only — other readers are
        untouched.  For a quantized pool the copy runs on the int8
        arena (where prefix rows live); the compute arena is suffix
        space there and holds nothing for ``bid``."""
        if self.allocator.refcount(bid) <= 1:
            return bid
        [new] = self.alloc(1)
        if self.qarena is not None:
            self.qarena = _copy_block(self.qarena, bid, new)
        else:
            self.arena = _copy_block(self.arena, bid, new)
        self._block_tokens[new] = self._block_tokens[bid]
        self.allocator.decref([bid])
        return new

    # ------------------------------------------------------------------
    # host tier (DESIGN.md §12)
    # ------------------------------------------------------------------
    def demote_blocks(self, bids: Sequence[int]):
        """Gather prefix rows ``bids`` (from the arena page tables
        actually reference: int8 qarena when quantized, else the
        compute arena) into host numpy buffers, bitwise.  Returns
        ``(host_pytree, nbytes, per_block_token_counts)`` — everything
        ``promote_blocks`` needs to rebuild the segment exactly."""
        sub = _extract_blocks(self.prefix_source(),
                              jnp.asarray(bids, jnp.int32))
        host = jax.device_get(sub)
        nbytes = int(sum(x.nbytes for x in jax.tree_util.tree_leaves(host)))
        toks = [int(self._block_tokens[b]) for b in bids]
        return host, nbytes, toks

    def promote_blocks(self, host, block_tokens: Sequence[int]):
        """Re-onboard a demoted segment: fresh prefix blocks, an ASYNC
        ``device_put`` of the host copy, and a donated scatter into the
        prefix arena.  Returns ``(bids, transfer)`` without blocking —
        the scatter is ordered behind the transfer by data dependency,
        so downstream prefills overlap it for free; block on
        ``transfer`` only to measure residual promotion wait.  Raises
        ``OutOfBlocks`` (nothing allocated, host copy untouched) when
        the prefix space cannot reclaim enough rows."""
        bids = self.alloc(len(block_tokens))
        try:
            transfer = jax.device_put(host)
            rows = jnp.asarray(bids, jnp.int32)
            if self.qarena is not None:
                self.qarena = _scatter_blocks(self.qarena, transfer, rows)
            else:
                self.arena = _scatter_blocks(self.arena, transfer, rows)
        except BaseException:
            self.decref(bids)
            raise
        for b, t in zip(bids, block_tokens):
            self._block_tokens[b] = t
        return bids, transfer

    def extract(self, bids: Sequence[int]):
        """Compact sub-arena holding just blocks ``bids`` (result row i
        = block ``bids[i]``; same per-layer leaf structure as ``arena``
        with the block dim shrunk to ``len(bids)``).

        Decode-time optimization: the decode scan writes ONLY its
        batch's suffix blocks, so it carries this extraction (plus a
        remapped suffix table) instead of the whole arena — which a
        backend that cannot alias the donated carry would otherwise
        copy once per generated token.  Prefix blocks stay in the main
        arena and are read as a scan invariant.  The extraction is
        discarded after decode (suffix blocks free with the batch), so
        nothing is scattered back."""
        return _extract_blocks(self.arena, jnp.asarray(bids, jnp.int32))

    def sub_arena(self, n_rows: int):
        """A fresh standalone block arena of ``n_rows`` rows with this
        pool's geometry (same per-layer leaf structure, positions -1).

        Continuous serving (``serving/continuous.py``, DESIGN.md §9)
        keeps one of these resident as the decode carry: each in-flight
        slot owns a fixed band of rows for its suffix+decode KV, so the
        chunked decode scan carries only ``slots × blocks`` rows while
        the main arena rides along read-only as the prefix source.
        Rows are REUSED across tenants — retirement frees the slot's
        main-arena reservation (``decref``) and the next admission
        resets the rows' positions (``reset_pos_rows``); the sub-arena
        itself is never reallocated, so slot turnover causes no arena
        churn."""
        from repro.models import model as M
        return M.init_block_arena(self.cfg, n_rows, self.block_size)

    def gather(self, rows: np.ndarray):
        """Densify page-table ``rows`` [B, W] into a [B, W*block_size]
        cache pytree (tests / debugging; serving never materializes
        this — the XLA path gathers inside jit, the Pallas path DMAs
        per block)."""
        rows = jnp.asarray(rows, jnp.int32)
        b, w = rows.shape

        def g(path, x):
            _, blk_ax = _leaf_axes(path)
            lead = x.ndim + blk_ax          # leading scanned-group dims
            xb = jnp.moveaxis(x, blk_ax, 0)[rows]  # [B, W, lead.., bs, tail]
            xb = jnp.moveaxis(xb, 1, 1 + lead)     # W next to the slot dim
            s = list(xb.shape)
            i = 1 + lead
            s[i:i + 2] = [w * self.block_size]
            xb = xb.reshape(s)                     # [B, lead.., W*bs, tail]
            return jnp.moveaxis(xb, 0, lead)       # dense layout: lead, B
        return jax.tree_util.tree_map_with_path(g, self.arena)
