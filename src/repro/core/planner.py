"""SubGCache batch planner: cluster -> representative subgraph -> schedule.

Implements the paper's three-step pipeline (§3.1) as a pure planning
stage, independent of the serving engine that executes it:

  1. cluster in-batch queries on their retrieved-subgraph embeddings,
  2. union-merge each cluster into a representative subgraph,
  3. emit per-cluster execution plans (processed sequentially by the
     engine, which precomputes / reuses / releases the prefix state).

``num_clusters`` is the paper's knob: 1 cluster = maximal reuse,
m clusters = vanilla graph-based RAG (the planner then degenerates to
per-query processing, as noted in the paper's Discussion).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.clustering import hierarchical_clustering
from repro.core.subgraph import Subgraph, merge_subgraphs


@dataclasses.dataclass
class ClusterPlan:
    """One cluster of the batch plan: who belongs to it and the
    union-merged representative subgraph whose textualization becomes
    the shared prompt prefix (paper §3.3)."""
    cluster_id: int
    member_indices: List[int]          # indices into the in-batch query list
    representative: Subgraph


@dataclasses.dataclass
class BatchPlan:
    """Offline execution plan for one in-batch query set.  The engine
    serves ``clusters`` sequentially; the ONLINE path instead seeds an
    ``OnlineClusterAssigner`` from a plan (``from_plan``) or skips the
    planner entirely (serving/scheduler.py)."""
    clusters: List[ClusterPlan]
    cluster_processing_time_s: float   # paper Fig. 4 quantity
    num_queries: int

    @property
    def reuse_factor(self) -> float:
        """Average members per cluster (upper bound on prefill reuse)."""
        return self.num_queries / max(1, len(self.clusters))


def plan_batch(subgraphs: Sequence[Subgraph],
               embeddings: np.ndarray,
               num_clusters: int,
               linkage: str = "ward") -> BatchPlan:
    """Cluster the batch and build representative subgraphs.

    ``embeddings``: [m, dim] GNN subgraph embeddings (paper §3.2 — the same
    pretrained GNN the RAG pipeline uses for soft prompts).
    """
    t0 = time.perf_counter()
    m = len(subgraphs)
    assert embeddings.shape[0] == m
    labels = hierarchical_clustering(embeddings, num_clusters, linkage)
    clusters: List[ClusterPlan] = []
    for c in sorted(set(labels.tolist())):
        idx = [i for i in range(m) if labels[i] == c]
        rep = merge_subgraphs([subgraphs[i] for i in idx])
        clusters.append(ClusterPlan(cluster_id=c, member_indices=idx,
                                    representative=rep))
    dt = time.perf_counter() - t0
    return BatchPlan(clusters=clusters, cluster_processing_time_s=dt,
                     num_queries=m)


def plan_singleton(subgraphs: Sequence[Subgraph]) -> BatchPlan:
    """Degenerate plan: one cluster per query (vanilla graph-based RAG)."""
    clusters = [ClusterPlan(cluster_id=i, member_indices=[i],
                            representative=sg)
                for i, sg in enumerate(subgraphs)]
    return BatchPlan(clusters=clusters, cluster_processing_time_s=0.0,
                     num_queries=len(subgraphs))
