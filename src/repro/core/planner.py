"""SubGCache batch planner: cluster -> representative subgraph -> schedule.

Implements the paper's three-step pipeline (§3.1) as a pure planning
stage, independent of the serving engine that executes it:

  1. cluster in-batch queries on their retrieved-subgraph embeddings,
  2. union-merge each cluster into a representative subgraph,
  3. emit per-cluster execution plans (processed sequentially by the
     engine, which precomputes / reuses / releases the prefix state).

``num_clusters`` is the paper's knob: 1 cluster = maximal reuse,
m clusters = vanilla graph-based RAG (the planner then degenerates to
per-query processing, as noted in the paper's Discussion).

Hierarchical prefix trees (DESIGN.md §10): the clustering dendrogram is
cut at MULTIPLE levels (``plan_prefix_tree``) and each leaf cluster's
prefix becomes a root-to-leaf CHAIN of segments — an ancestor node
holds the content its descendant leaves share (intersection of their
representatives), stored and prefilled once; each leaf extends its
ancestor path with only its own remainder.  ``tree_levels=1``
degenerates to the flat single-cut plan.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cache import ComposedSegment, SegmentComposition
from repro.core.clustering import Dendrogram, build_dendrogram
from repro.core.subgraph import (Subgraph, intersect_subgraphs,
                                 merge_subgraphs)


@dataclasses.dataclass
class ClusterPlan:
    """One cluster of the batch plan: who belongs to it and the
    union-merged representative subgraph whose textualization becomes
    the shared prompt prefix (paper §3.3)."""
    cluster_id: int
    member_indices: List[int]          # indices into the in-batch query list
    representative: Subgraph


@dataclasses.dataclass
class BatchPlan:
    """Offline execution plan for one in-batch query set.  The engine
    serves ``clusters`` sequentially; the ONLINE path instead seeds an
    ``OnlineClusterAssigner`` from a plan (``from_plan``) or skips the
    planner entirely (serving/scheduler.py)."""
    clusters: List[ClusterPlan]
    cluster_processing_time_s: float   # paper Fig. 4 quantity
    num_queries: int

    @property
    def reuse_factor(self) -> float:
        """Average members per cluster (upper bound on prefill reuse)."""
        return self.num_queries / max(1, len(self.clusters))


def plan_batch(subgraphs: Sequence[Subgraph],
               embeddings: np.ndarray,
               num_clusters: int,
               linkage: str = "ward",
               dendrogram: Optional[Dendrogram] = None) -> BatchPlan:
    """Cluster the batch and build representative subgraphs.

    ``embeddings``: [m, dim] GNN subgraph embeddings (paper §3.2 — the same
    pretrained GNN the RAG pipeline uses for soft prompts).

    ``dendrogram``: pass a ``build_dendrogram`` result to make this call
    a cheap cut replay — a cluster sweep re-running the full O(m^3)
    agglomeration per ``num_clusters`` point pays m-fold for the same
    merge tree.
    """
    t0 = time.perf_counter()
    m = len(subgraphs)
    assert embeddings.shape[0] == m
    if dendrogram is None:
        dendrogram = build_dendrogram(embeddings, linkage)
    else:
        assert dendrogram.m == m, (dendrogram.m, m)
    labels = dendrogram.cut(num_clusters)
    clusters: List[ClusterPlan] = []
    for c in sorted(set(labels.tolist())):
        idx = [i for i in range(m) if labels[i] == c]
        rep = merge_subgraphs([subgraphs[i] for i in idx])
        clusters.append(ClusterPlan(cluster_id=c, member_indices=idx,
                                    representative=rep))
    dt = time.perf_counter() - t0
    return BatchPlan(clusters=clusters, cluster_processing_time_s=dt,
                     num_queries=m)


def plan_singleton(subgraphs: Sequence[Subgraph]) -> BatchPlan:
    """Degenerate plan: one cluster per query (vanilla graph-based RAG)."""
    clusters = [ClusterPlan(cluster_id=i, member_indices=[i],
                            representative=sg)
                for i, sg in enumerate(subgraphs)]
    return BatchPlan(clusters=clusters, cluster_processing_time_s=0.0,
                     num_queries=len(subgraphs))


# ======================================================================
# segment composition planning (DESIGN.md §14)
# ======================================================================
def plan_composition(segment_tokens: Sequence[Sequence[int]],
                     lookup: Callable[[Tuple[int, ...]], Optional[object]],
                     recompute_frac: float = 0.0,
                     *, recompute_budget: Optional[int] = None,
                     scorer: Optional[Callable] = None,
                     block_size: int = 0
                     ) -> Optional[SegmentComposition]:
    """Plan a ``SegmentComposition`` for a prompt given as an ordered
    list of SEGMENT token lists (the per-segment ``textualize_delta``
    texts, tokenized).

    ``lookup(tokens)`` maps a segment's token content to a resident
    cached ``PrefixState`` (or None) — content-addressed, NOT
    position-addressed: a segment prefilled under one chain at any base
    position splices into this prompt at its target offset, read-time
    rotation re-basing it (the cross-cluster reuse the dendrogram's
    literal-prefix chains never expressed).  Consecutive misses merge
    into one fresh gap span (per-segment sub-spans kept as
    ``gap_parts`` for the engine's content-addressed gap capture).
    Returns None when NO segment is resident — a composition of pure
    gaps is just a dense prefill, and the caller's chain path both
    serves it and caches its segments for later lookups.

    Drift-scored plans (DESIGN.md §15): with ``recompute_budget`` and
    ``scorer`` both given, ``scorer(comp)`` is called on the
    window-free plan and must return one per-block score array per
    segment; the top-scoring blocks worth ``recompute_budget`` tokens
    per splice are masked for fresh re-prefill
    (``SegmentComposition.apply_drift``), REPLACING the
    ``recompute_frac`` leading window.  ``block_size`` must then be
    the serving pool's block size."""
    segs: List[ComposedSegment] = []
    gaps: List[Tuple[int, List[int]]] = []
    parts: List[Tuple[int, List[int]]] = []
    off = 0
    for toks in segment_tokens:
        toks = list(int(t) for t in toks)
        st = lookup(tuple(toks)) if toks else None
        if st is not None and st.segment_len == len(toks):
            segs.append(ComposedSegment(state=st, target_offset=off,
                                        tokens=tuple(toks)))
        elif toks:
            parts.append((off, list(toks)))
            if gaps and gaps[-1][0] + len(gaps[-1][1]) == off:
                gaps[-1][1].extend(toks)       # merge adjacent misses
            else:
                gaps.append((off, toks))
        off += len(toks)
    if not segs:
        return None
    comp = SegmentComposition(segments=segs, gaps=gaps,
                              recompute_frac=recompute_frac,
                              block_size=block_size, gap_parts=parts)
    if recompute_budget is not None and scorer is not None:
        comp.apply_drift(scorer(comp), recompute_budget)
    return comp


# ======================================================================
# hierarchical prefix trees (DESIGN.md §10)
# ======================================================================
@dataclasses.dataclass
class TreeNode:
    """One node of the representative prefix tree.

    ``content`` is the FULL nested content at this node — a superset of
    its parent's content by construction (parent = intersection of its
    children), so the chain textualization emits each node's DELTA over
    its parent and an ancestor's text is a literal token prefix of
    every descendant's (``core/subgraph.py::textualize_delta``)."""
    node_id: int
    parent: Optional[int]              # node_id, None for a root segment
    level: int                         # depth in the pruned tree (0 = root)
    content: Subgraph
    member_indices: List[int]          # queries assigned here (leaves only)


@dataclasses.dataclass
class ChainSpec:
    """Root→leaf chain of one leaf cluster: pool keys + nested contents
    (what the scheduler materializes segment by segment)."""
    keys: List[int]                    # tree node ids, root first
    contents: List[Subgraph]           # nested: contents[i] ⊆ contents[i+1]


@dataclasses.dataclass
class PrefixTreePlan:
    """Multi-level execution plan: leaf clusters carry members, ancestor
    nodes carry the shared content their descendants reference."""
    nodes: List[TreeNode]              # indexed by node_id
    leaves: List[int]                  # node ids, one per leaf cluster
    level_cuts: List[int]              # dendrogram cuts, coarse → fine
    cluster_processing_time_s: float
    num_queries: int

    @property
    def levels(self) -> int:
        """Longest root→leaf path (1 = flat)."""
        return max((len(self.path(leaf)) for leaf in self.leaves),
                   default=0)

    def path(self, node_id: int) -> List[int]:
        """Node ids root→``node_id`` (inclusive)."""
        out = []
        cur: Optional[int] = node_id
        while cur is not None:
            out.append(cur)
            cur = self.nodes[cur].parent
        return out[::-1]

    def chain(self, leaf_id: int) -> ChainSpec:
        p = self.path(leaf_id)
        return ChainSpec(keys=p, contents=[self.nodes[n].content for n in p])

    @property
    def reuse_factor(self) -> float:
        return self.num_queries / max(1, len(self.leaves))


def default_level_cuts(num_clusters: int, tree_levels: int) -> List[int]:
    """Coarse→fine dendrogram cuts for a ``tree_levels``-deep tree over
    ``num_clusters`` leaf clusters: each ancestor level halves the
    cluster count (K, K/2, K/4, ...), deduplicated."""
    cuts = []
    k = max(1, int(num_clusters))
    for _ in range(max(1, int(tree_levels))):
        if not cuts or k < cuts[0]:
            cuts.insert(0, k)
        k = max(1, k // 2)
        if k == cuts[0]:
            break
    return cuts


def plan_prefix_tree(subgraphs: Sequence[Subgraph],
                     embeddings: np.ndarray,
                     num_clusters: int,
                     tree_levels: int = 2,
                     linkage: str = "ward",
                     dendrogram: Optional[Dendrogram] = None,
                     level_cuts: Optional[Sequence[int]] = None
                     ) -> PrefixTreePlan:
    """Cut the dendrogram at multiple levels into a prefix tree.

    Leaf clusters (the finest cut, ``num_clusters``) keep the flat
    planner's semantics: members + union-merged representative.
    Ancestor nodes take the INTERSECTION of their children's contents —
    the shared structure sibling clusters would otherwise prefill once
    each — so contents nest root→leaf and each leaf's full prefix
    content equals its flat representative exactly (only the token
    ORDER changes: shared content first).

    Pruning: an ancestor that does not actually split (single child) or
    shares nothing (empty intersection) is dropped — its children splice
    up — so every surviving segment carries real reusable content.
    """
    t0 = time.perf_counter()
    m = len(subgraphs)
    assert embeddings.shape[0] == m
    if dendrogram is None:
        dendrogram = build_dendrogram(embeddings, linkage)
    else:
        assert dendrogram.m == m, (dendrogram.m, m)
    if level_cuts is None:
        level_cuts = default_level_cuts(num_clusters, tree_levels)
    cuts = sorted(set(int(c) for c in level_cuts))          # coarse → fine
    assert cuts, "need at least one cut"

    fine = cuts[-1]
    leaf_members: Dict[int, List[int]] = dict(
        enumerate(dendrogram.cut_members(fine)))

    nodes: List[TreeNode] = []
    leaves: List[int] = []
    # leaf nodes first (content = union of members, the flat representative)
    leaf_node_of: Dict[int, int] = {}
    for c in sorted(leaf_members):
        nid = len(nodes)
        nodes.append(TreeNode(
            node_id=nid, parent=None, level=0,
            content=merge_subgraphs([subgraphs[i] for i in leaf_members[c]]),
            member_indices=leaf_members[c]))
        leaf_node_of[c] = nid
        leaves.append(nid)

    # ancestor levels, fine → coarse; children tracked per current root
    current: Dict[int, int] = dict(leaf_node_of)   # leaf label -> root node
    for cut in reversed(cuts[:-1]):
        coarse_labels = dendrogram.cut(cut)
        groups: Dict[int, List[int]] = {}          # coarse label -> node ids
        for leaf_label, nid in current.items():
            anchor = leaf_members[leaf_label][0]   # dendrogram cuts nest
            groups.setdefault(int(coarse_labels[anchor]), []).append(nid)
        nxt: Dict[int, int] = dict(current)        # default: splice through
        for coarse, child_ids in groups.items():
            child_ids = sorted(set(child_ids))
            if len(child_ids) < 2:
                continue                           # no split: prune level
            shared = intersect_subgraphs([nodes[n].content
                                          for n in child_ids])
            if shared.is_empty:
                continue                           # nothing shared: prune
            nid = len(nodes)
            nodes.append(TreeNode(node_id=nid, parent=None, level=0,
                                  content=shared, member_indices=[]))
            for ch in child_ids:
                nodes[ch].parent = nid
            for leaf_label, root in current.items():
                if root in child_ids:
                    nxt[leaf_label] = nid
        current = nxt

    plan = PrefixTreePlan(nodes=nodes, leaves=leaves,
                          level_cuts=list(cuts),
                          cluster_processing_time_s=0.0, num_queries=m)
    for nid in range(len(nodes)):                   # depth from root
        p = plan.path(nid)
        nodes[nid].level = len(p) - 1
    plan.cluster_processing_time_s = time.perf_counter() - t0
    return plan
