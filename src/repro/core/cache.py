"""Cluster-wise prefix-state cache manager (paper §3.4, TPU-adapted).

The paper stores HF ``past_key_values`` for the representative prompt and
frees them after the cluster is served.  TPU adaptation (DESIGN.md §3):

* the cached unit is a generalized **PrefixState** — the model's whole
  sequence state after consuming the representative prompt: attention KV
  buffers, Mamba (conv, ssm) states, RG-LRU states, cross-attention KV.
  This is what lets the technique cover attention-free architectures.
* "release" is buffer reuse: the engine owns one fixed-capacity state of
  ``max_prefix_len`` and each cluster overwrites it (donated arg on TPU),
  so memory is bounded by ONE representative prompt at all times —
  the same bound the paper argues for, without allocator churn.
* member queries run as ONE batched suffix prefill (beyond-paper
  optimization; the paper loops members sequentially).  Attention-only
  stacks keep the prefix at batch=1 end to end: the engine's split
  prefix/suffix cascade (DESIGN.md §5) attends the live buffers in
  place, so ``broadcast`` survives only as the fallback for stateful
  (Mamba / RG-LRU) and cross-attention stacks whose per-member state
  is tiny.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paged import NULL_BLOCK, KVBlockPool, PageTable


_UID = itertools.count()


@dataclasses.dataclass
class PrefixState:
    """Model sequence-state after consuming a shared prefix — or, since
    the prefix-tree refactor (DESIGN.md §10), ONE SEGMENT of a prefix
    CHAIN: a root-to-leaf path of nested segments through the
    representative tree, where every descendant references its
    ancestors' storage instead of replicating it.

    Two storage backends (one API — DESIGN.md §8):

    * **dense** — ``cache`` holds this segment's batch-1 cache pytree
      (split cascade / broadcast fallback serving); a chain is served
      as a tuple of segment caches folded by the N-way LSE cascade.
    * **paged** — ``page`` maps THIS segment's tokens into
      ``block_pool``'s block arena and ``cache`` is None.
      ``ancestor_blocks`` holds the block ids of every ancestor
      segment, root first, increfed for this state's lifetime — the
      full chain walk is ``chain_blocks()`` and an ancestor evicted
      from the pool can never be recycled under a live descendant.
      ``release()`` drops the state's own AND ancestor block
      references (eviction / cluster release); blocks return to the
      free list only when the last reader releases.

    ``prefix_len`` is always the CUMULATIVE path length through this
    segment (so offsets, capacity buckets, and accounting are
    unchanged for chain states); ``seg_len`` is the tokens this
    segment itself owns (flat state: seg_len == prefix_len).
    """
    cache: Any                 # dense cache pytree (None when paged)
    prefix_len: int            # tokens in the cached path (incl. n_soft)
    capacity: int              # allocated / bucketed cache capacity
    enc_len: int = 0           # cross-attention KV length (enc-dec / VLM)
    # soft-prompt embeddings consumed ahead of the prefix text tokens;
    # ALREADY included in prefix_len (the prefill consumed them like any
    # other position) — kept separately so accounting can audit that
    # prompt-token counts cover them (DESIGN.md §6)
    n_soft: int = 0
    page: Optional[PageTable] = None
    block_pool: Optional[KVBlockPool] = None
    # --- prefix-chain fields (DESIGN.md §10) ---
    parent: Optional["PrefixState"] = None   # segment one level up (or None)
    seg_len: Optional[int] = None            # tokens owned by THIS segment
    # ancestor block ids (root first), increfed at creation and decrefed
    # by release(); snapshotted here because an evicted ancestor state
    # drops its own ``page`` while this descendant must keep walking it
    ancestor_blocks: List[int] = dataclasses.field(default_factory=list)
    # process-unique identity: lets caches key on "same state object"
    # without holding a strong reference (id() values are recycled;
    # uids never are)
    uid: int = dataclasses.field(default_factory=_UID.__next__)

    @property
    def is_paged(self) -> bool:
        return self.page is not None

    @property
    def segment_len(self) -> int:
        """Tokens this segment owns (= prefix_len for flat states)."""
        return self.prefix_len if self.seg_len is None else self.seg_len

    @property
    def base_pos(self) -> int:
        """Absolute position of this segment's FIRST token in the chain
        it was prefilled into — the anchor its stored (canonical-K)
        position values count from.  Splicing the segment at
        ``target_offset`` in another prompt reads it rotated by
        ``target_offset - base_pos`` (DESIGN.md §14); a flat state's
        base is 0."""
        return self.prefix_len - self.segment_len

    def chain(self) -> List["PrefixState"]:
        """Segments root→self (a flat state is its own chain)."""
        out: List[PrefixState] = []
        cur: Optional[PrefixState] = self
        while cur is not None:
            out.append(cur)
            cur = cur.parent
        return out[::-1]

    def chain_blocks(self) -> List[int]:
        """Every block of the full root→self path, root first — what
        serving pins and what a prefix page-table row concatenates
        (masking is positional, so block order only needs to be
        deterministic).  Paged states only."""
        own = self.page.blocks if self.page is not None else []
        return list(self.ancestor_blocks) + list(own)

    def page_row(self, width: int) -> np.ndarray:
        """NULL-padded [width] page-table row over the full chain."""
        blocks = self.chain_blocks()
        assert len(blocks) <= width, (len(blocks), width)
        out = np.full(width, NULL_BLOCK, np.int32)
        out[:len(blocks)] = blocks
        return out

    def release(self) -> None:
        """Drop this state's block references — its own segment AND the
        per-lifetime references it holds on its ancestors (idempotent;
        no-op for dense states, which the garbage collector owns).

        With a host tier attached (DESIGN.md §12) an evicting
        ``PrefixPool`` gathers the segment's bits to host BEFORE calling
        this: release ends the state's device life; the ``HostSegment``
        carries the content until promotion rebuilds a fresh state
        through new blocks (bitwise identical) or the tier discards it."""
        if self.block_pool is not None:
            if self.page is not None:
                self.block_pool.decref(self.page.blocks)
                self.page = None
            if self.ancestor_blocks:
                self.block_pool.decref(self.ancestor_blocks)
                self.ancestor_blocks = []

    def broadcast(self, template: Any) -> Any:
        """Broadcast the batch-1 prefix state onto ``template`` shapes
        (the member-batch cache structure, e.g. from ``jax.eval_shape``).

        Fallback path only: attention-only stacks serve members via the
        split/paged cascade without replicating the prefix KV; this
        materialized copy remains for recurrent (Mamba / RG-LRU) and
        cross-attention state, which is O(d_state), not O(prefix_len).

        KV buffers and recurrent states after an identical prefix are
        identical across members, so this is exact, not approximate.
        Works regardless of where the batch dim sits (scanned layer
        stacks put a group dim in front)."""
        assert self.cache is not None, \
            "paged states hold no dense cache to broadcast"

        def bc(x, t):
            if x.shape == t.shape and x.dtype == t.dtype:
                # broadcast_to is a no-op here and would ALIAS the live
                # prefix buffers, which the engine's prefill donates —
                # reuse across clusters requires a real copy.
                return jnp.copy(x)
            # shape or dtype changes: broadcast_to/astype already
            # materialize a fresh buffer — a second copy on top (the
            # pre-fix behavior) doubled the write traffic of every
            # stateful-fallback broadcast for nothing.
            return jnp.broadcast_to(x, t.shape).astype(t.dtype)
        return jax.tree.map(bc, self.cache, template)


def recompute_window(seg_len: int, recompute_frac: float) -> int:
    """Leading tokens of a spliced segment that are prefilled FRESH at
    the target position (their cached copies masked): the boundary
    smoothing knob of DESIGN.md §14.  ``ceil(frac * seg_len)`` clamped
    to the segment — 0.0 is a pure splice, 1.0 degenerates to a dense
    prefill of the whole segment."""
    assert 0.0 <= recompute_frac <= 1.0, recompute_frac
    return min(int(seg_len), math.ceil(recompute_frac * seg_len))


def masked_block_tokens(seg_len: int, blocks, block_size: int) -> int:
    """Tokens covered by the selected block indices of a
    ``seg_len``-token segment (the last block may be partial)."""
    return sum(min(block_size, seg_len - b * block_size) for b in blocks)


def select_drift_blocks(scores, budget_tokens: int, seg_len: int,
                        block_size: int) -> Tuple[int, ...]:
    """Pick the block indices a ``budget_tokens`` recompute budget is
    spent on, highest drift score first (DESIGN.md §15).

    The budget is quantized UP to whole blocks (``ceil(budget / bs)``)
    so the masked-span prefill stays block-aligned and the paged
    scatter dense; ``budget_tokens >= seg_len`` selects every block —
    the exactness anchor (identical to ``recompute_frac=1.0``).  The
    sort key is ``(-score, block_index)`` and the sort is stable, so
    tied scores select LEADING blocks first — the drift mask always
    contains the fixed leading window's tokens at equal budget when
    scores tie."""
    assert budget_tokens >= 0, budget_tokens
    nb = (seg_len + block_size - 1) // block_size
    assert len(scores) == nb, (len(scores), nb)
    n_sel = min(nb, (budget_tokens + block_size - 1) // block_size)
    if budget_tokens >= seg_len:
        n_sel = nb
    if n_sel == 0:
        return ()
    order = sorted(range(nb), key=lambda b: (-float(scores[b]), b))
    return tuple(sorted(order[:n_sel]))


@dataclasses.dataclass(frozen=True)
class ComposedSegment:
    """One cached segment spliced into a composed prompt: the resident
    ``state`` contributes its OWN segment's blocks (ancestors are not
    read — that independence is the point), re-based so its tokens read
    as positions ``[target_offset, target_offset + segment_len)``.
    ``tokens`` are the segment's token ids — needed to RE-prefill the
    leading ``recompute_window`` tokens at the boundary.

    ``recompute_blocks`` (drift-scored selection, DESIGN.md §15)
    REPLACES the leading-window dial for this splice: the listed
    segment-local block indices are re-prefilled fresh (their cached
    copies fully masked via per-block skips) and everything else is
    read from the splice untouched — the recompute spend lands on the
    tokens whose attention actually moved, not on a fixed position
    range.  ``drift_scores`` keeps the per-block scores the selection
    was made from (metrics / replay)."""
    state: PrefixState
    target_offset: int
    tokens: Tuple[int, ...]
    recompute_blocks: Optional[Tuple[int, ...]] = None
    drift_scores: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        object.__setattr__(self, "tokens", tuple(self.tokens))
        assert len(self.tokens) == self.state.segment_len, \
            (len(self.tokens), self.state.segment_len)
        assert self.target_offset >= 0, self.target_offset
        if self.recompute_blocks is not None:
            blocks = tuple(sorted(int(b) for b in self.recompute_blocks))
            assert len(set(blocks)) == len(blocks), blocks
            assert all(b >= 0 for b in blocks), blocks
            object.__setattr__(self, "recompute_blocks", blocks)
        if self.drift_scores is not None:
            object.__setattr__(
                self, "drift_scores",
                tuple(float(s) for s in self.drift_scores))


@dataclasses.dataclass
class SegmentComposition:
    """A position-independent serving plan (DESIGN.md §14): an ordered
    splice of cached segments plus the fresh GAP spans between them,
    tiling the prompt context ``[0, total_len)`` exactly.  The member
    suffix (the query text) follows at ``total_len`` and stays on the
    ``Request``; a prefix CHAIN is the degenerate composition whose
    segments sit at their original offsets with no gaps.

    ``recompute_frac`` re-prefills the leading fraction of every
    spliced segment at its target position (cached copies masked via
    per-block skips) — 0.0 is the pure splice, 1.0 falls back to a
    dense prefill that is token-identical to serving without a cache.
    A segment carrying ``recompute_blocks`` (drift-scored selection,
    DESIGN.md §15) overrides the window with its own block mask;
    ``block_size`` must then match the pool the plan is served from.

    ``gap_parts`` optionally keeps the per-segment sub-spans the
    merged ``gaps`` were built from — the content-addressed units the
    engine's gap-span capture registers (a merged gap's combined token
    span would never match a later single-segment lookup).
    """
    segments: List[ComposedSegment]
    gaps: List[Tuple[int, List[int]]]    # (target_offset, fresh tokens)
    recompute_frac: float = 0.0
    block_size: int = 0
    gap_parts: Optional[List[Tuple[int, List[int]]]] = None

    def __post_init__(self):
        assert 0.0 <= self.recompute_frac <= 1.0, self.recompute_frac
        spans = [(s.target_offset, len(s.tokens)) for s in self.segments]
        spans += [(off, len(toks)) for off, toks in self.gaps]
        spans.sort()
        cur = 0
        for off, ln in spans:
            assert ln > 0, "empty span in composition"
            assert off == cur, \
                f"composition spans must tile [0, total): gap/overlap " \
                f"at {off} (expected {cur})"
            cur += ln
        self._total = cur
        for s in self.segments:
            if s.recompute_blocks is not None:
                assert self.block_size > 0, \
                    "block-masked segments need the pool block_size"
                nb = (len(s.tokens) + self.block_size - 1) // self.block_size
                assert all(b < nb for b in s.recompute_blocks), \
                    (s.recompute_blocks, nb)
        if self.gap_parts is not None:
            by_off = {off: list(toks) for off, toks in self.gap_parts}
            for off, toks in self.gaps:
                # every merged gap must be exactly re-coverable by parts
                cur, end = off, off + len(toks)
                while cur < end:
                    part = by_off.get(cur)
                    assert part is not None, (cur, self.gap_parts)
                    cur += len(part)
                assert cur == end, (off, toks, self.gap_parts)

    @property
    def total_len(self) -> int:
        """Context tokens the composition covers (suffix not included)."""
        return self._total

    def _fresh_runs(self, s: ComposedSegment) -> List[Tuple[int, int]]:
        """Segment-local [lo, hi) token runs this splice re-prefills:
        the drift block mask merged into contiguous block-aligned runs,
        or the single leading ``recompute_frac`` window."""
        if s.recompute_blocks is None:
            w = recompute_window(len(s.tokens), self.recompute_frac)
            return [(0, w)] if w else []
        bs = self.block_size
        runs: List[List[int]] = []
        for b in s.recompute_blocks:
            lo, hi = b * bs, min(len(s.tokens), (b + 1) * bs)
            if runs and runs[-1][1] == lo:
                runs[-1][1] = hi                 # adjacent blocks merge
            else:
                runs.append([lo, hi])
        return [(lo, hi) for lo, hi in runs]

    def fresh_spans(self) -> List[Tuple[int, List[int]]]:
        """The spans a composed prefill must COMPUTE, position-sorted:
        every gap plus each segment's recompute runs (drift-masked
        blocks, or the leading window)."""
        out = [(off, list(toks)) for off, toks in self.gaps]
        for s in self.segments:
            for lo, hi in self._fresh_runs(s):
                out.append((s.target_offset + lo, list(s.tokens[lo:hi])))
        out.sort(key=lambda e: e[0])
        return out

    def page_plan(self, block_size: int
                  ) -> Tuple[List[int], List[int], List[int]]:
        """Per-block prefix-row layout: (block ids, position offsets,
        leading-slot skips), segments in order.  Block ``k`` of a
        segment covers segment-local slots ``[k*bs, (k+1)*bs)``; its
        offset is the uniform re-base delta ``target - base_pos`` and
        its skip masks whatever part of the recompute window falls in
        it (a drift-selected block is masked WHOLE: skip = block_size).
        Fully-masked blocks are kept (NULL-equivalent) so the layout
        stays aligned with ``PageTable.blocks``."""
        assert self.block_size in (0, block_size), \
            (self.block_size, block_size)
        blocks: List[int] = []
        offsets: List[int] = []
        skips: List[int] = []
        for s in self.segments:
            st = s.state
            assert st.is_paged, "composition splices paged segments only"
            delta = int(s.target_offset) - st.base_pos
            mask = (None if s.recompute_blocks is None
                    else set(s.recompute_blocks))
            w = recompute_window(len(s.tokens), self.recompute_frac)
            for k, bid in enumerate(st.page.blocks):
                blocks.append(int(bid))
                offsets.append(delta)
                if mask is None:
                    skips.append(max(0, min(block_size, w - k * block_size)))
                else:
                    skips.append(block_size if k in mask else 0)
        return blocks, offsets, skips

    def recomputed_tokens(self) -> int:
        """Tokens of spliced segments the prefill re-computes fresh
        (drift-masked blocks or leading windows)."""
        return sum(hi - lo for s in self.segments
                   for lo, hi in self._fresh_runs(s))

    def spliced_tokens(self) -> int:
        """Cached context tokens actually read via the splice (segment
        tokens minus their recomputed windows) — the prefill work the
        composition avoids."""
        return (sum(len(s.tokens) for s in self.segments)
                - self.recomputed_tokens())

    def apply_drift(self, scores, budget_tokens: int) -> None:
        """Attach drift-scored block masks (DESIGN.md §15): ``scores``
        holds one per-block score array per segment (same order);
        every segment gets the top-``budget_tokens`` blocks selected by
        ``select_drift_blocks``.  The masks REPLACE the
        ``recompute_frac`` window for these segments."""
        assert self.block_size > 0, \
            "apply_drift needs the pool block_size on the composition"
        assert len(scores) == len(self.segments), \
            (len(scores), len(self.segments))
        self.segments = [
            dataclasses.replace(
                s,
                recompute_blocks=select_drift_blocks(
                    sc, budget_tokens, len(s.tokens), self.block_size),
                drift_scores=tuple(float(x) for x in sc))
            for s, sc in zip(self.segments, scores)]


@dataclasses.dataclass
class CacheStats:
    """Accounting for the paper's efficiency claims.

    ``prefill_tokens_baseline``: tokens the vanilla pipeline would prefill
    (every member re-encodes its own full prompt).
    ``prefill_tokens_cached``: tokens actually prefilled with SubGCache
    (one representative prefix per cluster + per-member suffixes).
    """
    num_queries: int = 0
    num_clusters: int = 0
    clusters_split: int = 0      # clusters served via the cascade (vs broadcast)
    cache_hits: int = 0
    prefill_tokens_baseline: int = 0
    prefill_tokens_cached: int = 0
    prefix_tokens_computed: int = 0
    suffix_tokens_computed: int = 0
    # --- pooled online serving (core/prefix_pool.py, DESIGN.md §7) ---
    pool_hits: int = 0           # get() found a live PrefixState
    pool_misses: int = 0         # get() missed (cold or evicted)
    pool_evictions: int = 0      # states dropped to fit the byte budget
    pool_reprefills: int = 0     # readmissions after an eviction
    # --- paged block pool (core/paged.py, DESIGN.md §8) ---
    blocks_total: int = 0        # usable blocks in the arena
    blocks_in_use: int = 0       # gauge: blocks allocated at last observe
    blocks_peak: int = 0         # high-water mark of blocks_in_use
    block_tokens: int = 0        # tokens stored at last observe
    block_size: int = 0          # slots per block
    block_bytes: int = 0         # per-block bytes at the PREFIX-resident
                                 # layout (int8 + scales when the pool
                                 # quantizes, else compute dtype) — NOT
                                 # hardcoded to the compute itemsize
    block_bytes_in_use: int = 0  # gauge: blocks_in_use * block_bytes
    block_bytes_peak: int = 0    # high-water mark of block_bytes_in_use
    # --- prefix-tree chains (DESIGN.md §10); keyed by chain level,
    # 0 = root segment.  "reused" = the segment was resident when a
    # chain was materialized; "prefilled" = it had to be computed.
    tree_prefill_tokens: Dict[int, int] = dataclasses.field(
        default_factory=dict)
    tree_reused_tokens: Dict[int, int] = dataclasses.field(
        default_factory=dict)
    tree_hits: Dict[int, int] = dataclasses.field(default_factory=dict)
    tree_misses: Dict[int, int] = dataclasses.field(default_factory=dict)
    ancestor_hits: int = 0       # non-leaf segments found resident
    ancestor_misses: int = 0     # non-leaf segments prefilled
    tree_segments_resident: int = 0   # gauge: pooled segments at last observe
    tree_tokens_resident: int = 0     # gauge: pooled prefix tokens (each
                                      # shared segment counted ONCE)
    # --- host tier (core/tiered.py, DESIGN.md §12) ---
    tier_demotions: int = 0      # pool evictions captured to host buffers
    tier_promotions: int = 0     # host segments re-onboarded to device
    tier_prefetch_promotions: int = 0  # promotions kicked speculatively
                                       # at assignment time, pre-queue-front
    tier_prefetch_hits: int = 0  # later pool hit landed on a prefetched entry
    tier_promotion_failures: int = 0   # promotions unwound (device_put /
                                       # OutOfBlocks); host copy survives
    tier_demoted_bytes: int = 0
    tier_promoted_bytes: int = 0
    tier_promotion_wait_s: float = 0.0  # residual blocking on transfers
                                        # AFTER overlap with prefills
    host_discards: int = 0       # host-tier evictions — the true loss tier
    host_segments: int = 0       # gauge: segments host-resident
    host_bytes_in_use: int = 0   # gauge: host buffer bytes
    host_bytes_peak: int = 0     # high-water mark of host_bytes_in_use
    # --- replica router (serving/router.py, DESIGN.md §13) ---
    migrations_out: int = 0      # cluster segments rebalanced AWAY from
                                 # this replica (demote leg)
    migrations_in: int = 0       # cluster segments adopted FROM another
                                 # replica (host-tier handoff leg)
    # --- segment composition (DESIGN.md §14) ---
    compose_requests: int = 0    # rows served through a composition plan
    compose_segments: int = 0    # cached segments spliced (re-based)
    compose_spliced_tokens: int = 0     # cached tokens read via splice
                                        # (prefill work avoided)
    compose_recomputed_tokens: int = 0  # boundary-window / drift-mask
                                        # tokens re-prefilled
    # --- drift-scored recomputation + admission (DESIGN.md §15) ---
    compose_declines: int = 0    # engages the admission cost model
                                 # refused (served chained instead)
    compose_drift_splices: int = 0      # splices carrying a drift mask
    compose_drift_tokens: int = 0       # tokens recomputed via drift
                                        # masks (subset of recomputed)
    compose_drift_score: float = 0.0    # summed drift score (attention
                                        # mass) of the SELECTED blocks —
                                        # what the budget paid down
    gap_spans_cached: int = 0    # composition gap spans captured into
                                 # the registry (repeat traffic hits)
    gap_tokens_cached: int = 0   # tokens those captured spans hold
    # per-cluster arrival counts — what the composition-aware admission
    # cost model reads as its repeat-rate signal (DESIGN.md §15)
    cluster_arrivals: Dict[Any, int] = dataclasses.field(
        default_factory=dict)

    @property
    def prefill_savings(self) -> float:
        if self.prefill_tokens_cached == 0:
            return 1.0
        return self.prefill_tokens_baseline / self.prefill_tokens_cached

    def record_prefix(self, prefix_len: int, split: bool = False) -> None:
        """One representative-prefix prefill (call when the prefix is
        COMPUTED, not when it is served: a state reused across several
        serve calls still cost one prefill)."""
        self.num_clusters += 1
        self.prefix_tokens_computed += prefix_len
        if split:
            self.clusters_split += 1

    def record_served(self, n_members: int) -> None:
        self.num_queries += n_members
        self.cache_hits += n_members

    def record_cluster(self, prefix_len: int, n_members: int,
                       split: bool = False) -> None:
        self.record_prefix(prefix_len, split=split)
        self.record_served(n_members)

    def record_member(self, member_prompt_len: int, suffix_len: int) -> None:
        self.prefill_tokens_baseline += member_prompt_len
        self.suffix_tokens_computed += suffix_len

    def record_pool(self, *, hits: int = 0, misses: int = 0,
                    evictions: int = 0, reprefills: int = 0) -> None:
        """Pooled-serving accounting (called by ``PrefixPool``)."""
        self.pool_hits += hits
        self.pool_misses += misses
        self.pool_evictions += evictions
        self.pool_reprefills += reprefills

    @property
    def pool_hit_rate(self) -> float:
        total = self.pool_hits + self.pool_misses
        return self.pool_hits / total if total else 0.0

    def record_tree_segment(self, level: int, tokens: int, *, hit: bool,
                            leaf: bool) -> None:
        """One segment touched while materializing a prefix chain
        (DESIGN.md §10): either found resident (``hit`` — its tokens
        were REUSED across sibling paths) or prefilled.  ``level`` is
        the chain depth (0 = root); ``leaf`` marks the path's last
        segment so the ancestor-hit rate — the tree layout's whole
        claim — is auditable separately from ordinary leaf pool hits."""
        level = int(level)
        if hit:
            self.tree_hits[level] = self.tree_hits.get(level, 0) + 1
            self.tree_reused_tokens[level] = \
                self.tree_reused_tokens.get(level, 0) + int(tokens)
        else:
            self.tree_misses[level] = self.tree_misses.get(level, 0) + 1
            self.tree_prefill_tokens[level] = \
                self.tree_prefill_tokens.get(level, 0) + int(tokens)
        if not leaf:
            if hit:
                self.ancestor_hits += 1
            else:
                self.ancestor_misses += 1

    @property
    def ancestor_hit_rate(self) -> float:
        """How often a non-leaf segment was already resident when a
        chain was materialized (the tree layout's reuse claim)."""
        total = self.ancestor_hits + self.ancestor_misses
        return self.ancestor_hits / total if total else 0.0

    def record_tier(self, *, demotions: int = 0, promotions: int = 0,
                    prefetch_promotions: int = 0, prefetch_hits: int = 0,
                    promotion_failures: int = 0, demoted_bytes: int = 0,
                    promoted_bytes: int = 0, promotion_wait_s: float = 0.0,
                    discards: int = 0) -> None:
        """Host-tier accounting (called by ``PrefixPool``/``HostTier``;
        DESIGN.md §12)."""
        self.tier_demotions += demotions
        self.tier_promotions += promotions
        self.tier_prefetch_promotions += prefetch_promotions
        self.tier_prefetch_hits += prefetch_hits
        self.tier_promotion_failures += promotion_failures
        self.tier_demoted_bytes += demoted_bytes
        self.tier_promoted_bytes += promoted_bytes
        self.tier_promotion_wait_s += promotion_wait_s
        self.host_discards += discards

    def record_compose(self, comp: "SegmentComposition") -> None:
        """One request served through a composition plan (DESIGN.md
        §14).  Spliced tokens are cached context the prefill SKIPPED;
        recomputed tokens are the boundary windows / drift masks it
        paid for — the quality-vs-TTFT sweep reads both.  Drift-masked
        splices additionally record their selected-block score mass
        (DESIGN.md §15) so ``trace_summary`` can report how much
        attention drift the recompute budget actually covered."""
        spliced = comp.spliced_tokens()
        self.compose_requests += 1
        self.compose_segments += len(comp.segments)
        self.compose_spliced_tokens += spliced
        self.compose_recomputed_tokens += (
            sum(len(s.tokens) for s in comp.segments) - spliced)
        for s in comp.segments:
            if s.recompute_blocks is None:
                continue
            self.compose_drift_splices += 1
            self.compose_drift_tokens += masked_block_tokens(
                len(s.tokens), s.recompute_blocks, comp.block_size)
            if s.drift_scores is not None:
                self.compose_drift_score += sum(
                    s.drift_scores[b] for b in s.recompute_blocks)

    def record_compose_decline(self) -> None:
        """The admission cost model (DESIGN.md §15) refused an engage —
        the request was served through its chain instead because repeat
        traffic makes the chain's one-time prefill cheaper than paying
        gap + recompute tokens on every arrival."""
        self.compose_declines += 1

    def record_arrival(self, cluster_id) -> None:
        """One request arrived for ``cluster_id`` — the repeat-rate
        signal the composition-aware admission cost model extrapolates
        from (doubling heuristic: k arrivals seen ⇒ expect ~k more)."""
        self.cluster_arrivals[cluster_id] = \
            self.cluster_arrivals.get(cluster_id, 0) + 1

    def record_gap_cached(self, tokens: int) -> None:
        """One composition gap span captured into content-addressed
        cache blocks (DESIGN.md §15) — repeat traffic over the same
        content will splice it instead of re-prefilling."""
        self.gap_spans_cached += 1
        self.gap_tokens_cached += int(tokens)

    def record_migration(self, *, out: int = 0, into: int = 0) -> None:
        """Cluster-chain segments this replica migrated during router
        rebalancing (DESIGN.md §13) — placement moves, NOT evictions:
        the segment keeps serving, just from a different replica."""
        self.migrations_out += out
        self.migrations_in += into

    def record_host(self, tier) -> None:
        """Observe a ``HostTier``'s residency gauges."""
        self.host_segments = len(tier)
        self.host_bytes_in_use = tier.bytes_in_use
        self.host_bytes_peak = max(self.host_bytes_peak,
                                   tier.bytes_in_use)

    @property
    def tier_promotion_rate(self) -> float:
        """Of the misses that had been evicted before, how many were
        answered from host instead of recomputed (the tier's claim)."""
        total = self.tier_promotions + self.pool_reprefills
        return self.tier_promotions / total if total else 0.0

    @property
    def prefetch_hit_rate(self) -> float:
        """How often a speculative promotion was actually consumed by a
        later pool hit (prefetch precision)."""
        if not self.tier_prefetch_promotions:
            return 0.0
        return self.tier_prefetch_hits / self.tier_prefetch_promotions

    def record_tree_residency(self, segments: int, tokens: int) -> None:
        """Gauge: pooled chain segments / prefix tokens resident (each
        shared ancestor counted once — the byte-budget win vs a flat
        layout storing it per cluster)."""
        self.tree_segments_resident = int(segments)
        self.tree_tokens_resident = int(tokens)

    def record_blocks(self, pool) -> None:
        """Observe a ``KVBlockPool``'s occupancy (called by the engine
        after each paged serve; the peak is the HBM high-water mark)."""
        total = pool.allocator.num_usable
        if pool.suffix_allocator is not pool.allocator:
            total += pool.suffix_allocator.num_usable
        self.blocks_total = total
        self.blocks_in_use = pool.blocks_in_use
        self.blocks_peak = max(self.blocks_peak, pool.blocks_in_use)
        self.block_tokens = pool.tokens_stored
        self.block_size = pool.block_size
        # byte gauges priced at the arena dtype PREFIX blocks actually
        # occupy (int8 + scales under quantize_prefix, whose suffix
        # space is separate compute-dtype working storage), not the
        # compute dtype
        self.block_bytes = pool.prefix_block_bytes
        self.block_bytes_in_use = (pool.prefix_blocks_in_use
                                   * self.block_bytes)
        self.block_bytes_peak = max(self.block_bytes_peak,
                                    self.block_bytes_in_use)

    @property
    def block_occupancy(self) -> float:
        """Fraction of arena blocks allocated at last observation."""
        return self.blocks_in_use / self.blocks_total \
            if self.blocks_total else 0.0

    @property
    def block_fragmentation(self) -> float:
        """Fraction of allocated KV slots holding no token — the waste a
        padded-to-capacity pool would bake into every entry."""
        slots = self.blocks_in_use * self.block_size
        return 1.0 - self.block_tokens / slots if slots else 0.0

    def finalize(self) -> None:
        self.prefill_tokens_cached = (self.prefix_tokens_computed
                                      + self.suffix_tokens_computed)


class ClusterCacheManager:
    """Owns the single live prefix state; enforces precompute->reuse->release.

    The engine calls::

        with manager.cluster(prefix_state) as ps:
            ... serve all member queries against ps ...
        # state released (slot reusable) on exit
    """

    def __init__(self) -> None:
        self._live: Optional[PrefixState] = None
        self.stats = CacheStats()

    def reset_stats(self) -> CacheStats:
        """Start a fresh accounting window (e.g. per benchmark run);
        returns the new live ``CacheStats`` the engine records into."""
        self.stats = CacheStats()
        return self.stats

    def cluster(self, state: PrefixState):
        mgr = self

        class _Ctx:
            def __enter__(self):
                assert mgr._live is None, \
                    "cluster-wise policy violated: previous prefix not released"
                mgr._live = state
                return state

            def __exit__(self, *exc):
                mgr._live = None       # buffer slot reusable by next cluster
                state.release()        # paged blocks back to the free list
                return False

        return _Ctx()

    @property
    def live_state(self) -> Optional[PrefixState]:
        return self._live
