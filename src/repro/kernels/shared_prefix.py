"""Pallas TPU kernels for shared-prefix cascade attention.

SubGCache serves a whole cluster against ONE representative-prefix KV.
The broadcast path replicates that KV over the member batch before
attending; these kernels instead let batched queries ``[B, Hq, Tq, D]``
attend over a **batch-1 shared prefix KV** ``[1, Hkv, P, D]`` directly —
each prefix KV tile is streamed HBM->VMEM once per kv-head group, never
per member.  The result is a *partial* attention ``(out, m, l)`` in
online-softmax form; an LSE merge (``ops.fold_partials``, delegating to
``ref.merge_partials_ref``) combines it with the per-member suffix
partial, which is numerically exact: softmax over ``[prefix ++ suffix]``
equals the LSE-merge of the two partials.  (The paged serving path no
longer merges at all — ``fused_cascade.py`` folds the whole cascade
in-kernel, which is why the old pairwise Pallas merge kernel is gone.)

``attention_partial`` also accepts per-member KV (kv batch == q batch),
so the suffix side of the cascade uses the same kernel.

**Paged serving (DESIGN.md §8):** ``paged_attention_partial`` /
``paged_decode_gqa_partial`` generalize the same scalar-prefetch
mechanism from "which stacked prefix row" to "which block": KV is a
block arena ``[num_blocks, Hkv, block_size, D]`` and a *page table*
``[B, NP] int32`` is prefetched; grid step ``j`` of query row ``b``
DMAs arena block ``page_table[b, j]``.  One KV tile = one block, so the
kernel loop IS the page walk — no gather, no padded stacked pool, and
rows of one cluster walking the same prefix blocks stream the same
tiles (a [1, NP] table is the fully shared walk).  Table rows pad with
the NULL block (positions -1), which the positional mask kills like
any other empty slot.  (The page table generalizes PR 2's
``kv_index`` stacked-pool prefetch from "which stacked prefix row" to
"which block"; the kv_index variants were deleted with the stacked
pool itself.)

Tiling mirrors ``prefix_attention.py``: grid (B, Hq, nq, nk), KV minor,
online-softmax scratch in VMEM persisting across the nk loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _partial_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref,
                    o_ref, m_out_ref, l_out_ref,
                    acc_ref, m_ref, l_ref, *, causal: bool, window: int,
                    nk: int, scale: float):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, d]
    qp = qpos_ref[0]                                     # [bq] int32
    kp = kpos_ref[0]                                     # [bk] int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = kp[None, :] >= 0
    if causal:
        mask = mask & (kp[None, :] <= qp[:, None])
    if window:
        mask = mask & (qp[:, None] - kp[None, :] < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                                 # [bq]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)                          # kill exp(NEG_INF-m)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[:, 0] + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(j == nk - 1)
    def _done():
        l = l_ref[:, 0]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)
        m_out_ref[0, 0] = m_ref[:, 0]
        l_out_ref[0, 0] = l

def _indexed_partial_kernel(idx_ref, *refs, **kw):
    """Scalar-prefetch wrapper: ``idx_ref`` only steers the BlockSpec
    index maps (which KV batch row each query row DMAs); the attention
    math is identical."""
    _partial_kernel(*refs, **kw)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def attention_partial(q, k, v, q_pos, k_pos, *, causal: bool = True,
                      window: int = 0, block_q: int = 128,
                      block_k: int = 128, interpret: bool = True):
    """Partial masked GQA attention in online-softmax form.

    q: [B, Hq, Tq, D]; k, v: [Bk, Hkv, S, D] with ``Bk in (1, B)`` —
    ``Bk == 1`` is the shared-prefix case where every member attends the
    same KV and each KV tile is read once per kv-head group, not once
    per member.  q_pos: [B, Tq]; k_pos: [Bk, S] (-1 marks empty slots).
    (Multi-prefix batches use the paged variant below: page tables over
    the block arena replaced the PR 2 stacked pool.)

    Returns ``(out [B,Hq,Tq,D] f32, m [B,Hq,Tq] f32, l [B,Hq,Tq] f32)``
    where ``out`` is already normalized by ``l`` (zero for fully masked
    rows).  Partials stay f32 so the cascade merge rounds to the model
    dtype exactly once, like single-pass attention; cast after merging.
    """
    b, hq, tq, d = q.shape
    bk_b, hkv, s_len = k.shape[0], k.shape[1], k.shape[2]
    assert bk_b in (1, b), (bk_b, b)
    shared = bk_b == 1
    group = hq // hkv
    scale = d ** -0.5

    bq = min(block_q, tq)
    bk = min(block_k, s_len)
    tq_p = ((tq + bq - 1) // bq) * bq
    s_p = ((s_len + bk - 1) // bk) * bk
    if tq_p != tq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, tq_p - tq), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, tq_p - tq)), constant_values=0)
    if s_p != s_len:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, s_p - s_len), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, s_p - s_len), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, s_p - s_len)), constant_values=-1)

    nq, nk = tq_p // bq, s_p // bk
    grid = (b, hq, nq, nk)
    out_shape = [
        jax.ShapeDtypeStruct((b, hq, tq_p, d), jnp.float32),
        jax.ShapeDtypeStruct((b, hq, tq_p), jnp.float32),
        jax.ShapeDtypeStruct((b, hq, tq_p), jnp.float32),
    ]
    scratch_shapes = [
        pltpu.VMEM((bq, d), jnp.float32),     # acc
        pltpu.VMEM((bq, 1), jnp.float32),     # m
        pltpu.VMEM((bq, 1), jnp.float32),     # l
    ]
    kern = functools.partial(_partial_kernel, causal=causal, window=window,
                             nk=nk, scale=scale)

    kv_b = (lambda b_: 0) if shared else (lambda b_: b_)
    out, m, l = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq), lambda b_, h, i, j: (b_, i)),          # q_pos
            pl.BlockSpec((1, bk), lambda b_, h, i, j: (kv_b(b_), j)),    # k_pos
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j: (kv_b(b_), h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j: (kv_b(b_), h // group, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, i, j: (b_, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, i, j: (b_, h, i)),
        ],
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(q_pos, k_pos, q, k, v)
    return out[:, :, :tq, :], m[:, :, :tq], l[:, :, :tq]


def _decode_partial_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref,
                           o_ref, m_out_ref, l_out_ref,
                           acc_ref, m_ref, l_ref, *, window: int, nk: int,
                           scale: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # [g, d]
    k = k_ref[0, 0].astype(jnp.float32)                    # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)
    qp = qpos_ref[0, 0]                                    # scalar int32
    kp = kpos_ref[0]                                       # [bk]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = (kp >= 0) & (kp <= qp)
    if window:
        mask = mask & (qp - kp < window)
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(mask[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = alpha * l_ref[:, 0] + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new

    @pl.when(j == nk - 1)
    def _done():
        l = l_ref[:, 0]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = acc_ref[...] / safe[:, None]
        m_out_ref[0, 0] = m_ref[:, 0]
        l_out_ref[0, 0] = l


def _indexed_decode_partial_kernel(idx_ref, *refs, **kw):
    """Scalar-prefetch wrapper for multi-prefix decode (see
    ``_indexed_partial_kernel``)."""
    _decode_partial_kernel(*refs, **kw)


@functools.partial(jax.jit, static_argnames=("window", "block_k",
                                             "interpret"))
def decode_gqa_partial(q, k, v, q_pos, k_pos, *, window: int = 0,
                       block_k: int = 128, interpret: bool = True):
    """Single-token GQA decode attention in partial form.

    Same decode-shaped tiling as ``decode_gqa`` — grid (B, Hkv, nk) with
    a [group, d] q tile so the whole q-head group shares one KV stream —
    but emitting ``(out [B,Hq,D] f32, m [B,Hq], l [B,Hq])`` for the
    cascade merge.  k, v: [Bk, Hkv, S, D] with ``Bk in (1, B)``;
    ``Bk == 1`` is the shared prefix (read once per kv-head, not per
    member; multi-prefix batches use ``paged_decode_gqa_partial``).
    Causal masking is always applied (a decode query is at or past
    every cached position, so it is correct for both sides).
    """
    b, hq, d = q.shape
    bk_b, hkv, s_len = k.shape[0], k.shape[1], k.shape[2]
    assert bk_b in (1, b), (bk_b, b)
    shared = bk_b == 1
    group = hq // hkv
    scale = d ** -0.5

    bk = min(block_k, s_len)
    s_p = ((s_len + bk - 1) // bk) * bk
    if s_p != s_len:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, s_p - s_len), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, s_p - s_len), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, s_p - s_len)), constant_values=-1)
    nk = s_p // bk

    qg = q.reshape(b, hkv, group, d)
    qp2 = q_pos.reshape(b, 1).astype(jnp.int32)
    out_shape = [
        jax.ShapeDtypeStruct((b, hkv, group, d), jnp.float32),
        jax.ShapeDtypeStruct((b, hkv, group), jnp.float32),
        jax.ShapeDtypeStruct((b, hkv, group), jnp.float32),
    ]
    scratch_shapes = [
        pltpu.VMEM((group, d), jnp.float32),
        pltpu.VMEM((group, 1), jnp.float32),
        pltpu.VMEM((group, 1), jnp.float32),
    ]

    kv_b = (lambda b_: 0) if shared else (lambda b_: b_)
    out, m, l = pl.pallas_call(
        functools.partial(_decode_partial_kernel, window=window, nk=nk,
                          scale=scale),
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h, j: (b_, 0)),            # q_pos
            pl.BlockSpec((1, bk), lambda b_, h, j: (kv_b(b_), j)),     # k_pos
            pl.BlockSpec((1, 1, group, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, j: (kv_b(b_), h, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, j: (kv_b(b_), h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, group, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, group), lambda b_, h, j: (b_, h, 0)),
            pl.BlockSpec((1, 1, group), lambda b_, h, j: (b_, h, 0)),
        ],
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(qp2, k_pos, qg, k, v)
    return (out.reshape(b, hq, d), m.reshape(b, hq), l.reshape(b, hq))


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "interpret"))
def paged_attention_partial(q, k, v, q_pos, k_pos, page_table, *,
                            causal: bool = False, window: int = 0,
                            block_q: int = 128, interpret: bool = True):
    """Partial masked GQA attention over a paged KV arena.

    q: [B, Hq, Tq, D]; k, v: [NB, Hkv, bs, D] — the block arena, one
    row per physical block of ``bs`` slots; k_pos: [NB, bs] absolute
    positions (-1 = empty slot); page_table: [B, NP] int32 — query row
    ``b``'s sequence is the concatenation of blocks
    ``page_table[b, 0..NP)``, short rows padded with the NULL block.
    A [1, NP] table is the SHARED walk (single-cluster batch): every
    query row walks the same blocks, so each tile is streamed once per
    kv-head group, never per member — the paged twin of the batch-1
    dense cascade.

    The page table is scalar-prefetched; grid step ``j`` DMAs block
    ``page_table[b, j]``, so the KV-minor loop walks the page table and
    the attention math is byte-identical to the dense cascade over the
    gathered sequence.  Returns ``(out [B,Hq,Tq,D] f32 normalized,
    m [B,Hq,Tq], l [B,Hq,Tq])`` for the LSE merge/fold.
    """
    b, hq, tq, d = q.shape
    hkv, bs = k.shape[1], k.shape[2]
    tb, n_pages = page_table.shape
    assert tb in (1, b), (page_table.shape, b)
    row = (lambda b_: 0) if tb == 1 else (lambda b_: b_)
    group = hq // hkv
    scale = d ** -0.5

    bq = min(block_q, tq)
    tq_p = ((tq + bq - 1) // bq) * bq
    if tq_p != tq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, tq_p - tq), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, tq_p - tq)), constant_values=0)
    nq = tq_p // bq

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hq, nq, n_pages),
        in_specs=[
            pl.BlockSpec((1, bq), lambda b_, h, i, j, pt: (b_, i)),
            pl.BlockSpec((1, bs),
                         lambda b_, h, i, j, pt: (pt[row(b_), j], 0)),
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h, i, j, pt: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h, i, j, pt: (pt[row(b_), j],
                                                  h // group, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h, i, j, pt: (pt[row(b_), j],
                                                  h // group, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h, i, j, pt: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, i, j, pt: (b_, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, i, j, pt: (b_, h, i)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
    )
    out, m, l = pl.pallas_call(
        functools.partial(_indexed_partial_kernel, causal=causal,
                          window=window, nk=n_pages, scale=scale),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, tq_p, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, tq_p), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, tq_p), jnp.float32),
        ],
        interpret=interpret,
    )(page_table.astype(jnp.int32), q_pos, k_pos, q, k, v)
    return out[:, :, :tq, :], m[:, :, :tq], l[:, :, :tq]


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_gqa_partial(q, k, v, q_pos, k_pos, page_table, *,
                             window: int = 0, interpret: bool = True):
    """Single-token GQA decode attention over a paged KV arena.

    Decode-shaped tiling (grid (B, Hkv, NP), [group, d] q tile) like
    ``decode_gqa_partial``, but the KV-minor loop walks the
    scalar-prefetched ``page_table`` [B, NP]: step ``j`` DMAs arena
    block ``page_table[b, j]`` from k, v [NB, Hkv, bs, D].  A [1, NP]
    table is the SHARED walk (every row reads the same blocks once per
    kv-head group).  Causal masking always applies (a decode query is
    at or past every cached position).  Returns ``(out [B,Hq,D] f32,
    m [B,Hq], l [B,Hq])``.
    """
    b, hq, d = q.shape
    hkv, bs = k.shape[1], k.shape[2]
    tb, n_pages = page_table.shape
    assert tb in (1, b), (page_table.shape, b)
    row = (lambda b_: 0) if tb == 1 else (lambda b_: b_)
    group = hq // hkv
    scale = d ** -0.5

    qg = q.reshape(b, hkv, group, d)
    qp2 = q_pos.reshape(b, 1).astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h, j, pt: (b_, 0)),
            pl.BlockSpec((1, bs),
                         lambda b_, h, j, pt: (pt[row(b_), j], 0)),
            pl.BlockSpec((1, 1, group, d),
                         lambda b_, h, j, pt: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h, j, pt: (pt[row(b_), j], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h, j, pt: (pt[row(b_), j], h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, group, d),
                         lambda b_, h, j, pt: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, group), lambda b_, h, j, pt: (b_, h, 0)),
            pl.BlockSpec((1, 1, group), lambda b_, h, j, pt: (b_, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    out, m, l = pl.pallas_call(
        functools.partial(_indexed_decode_partial_kernel, window=window,
                          nk=n_pages, scale=scale),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, group, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, group), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, group), jnp.float32),
        ],
        interpret=interpret,
    )(page_table.astype(jnp.int32), qp2, k_pos, qg, k, v)
    return (out.reshape(b, hq, d), m.reshape(b, hq), l.reshape(b, hq))
