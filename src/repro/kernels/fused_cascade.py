"""Fused single-pass cascade serving kernels (DESIGN.md §11, §14).

Through PR 5 the paged serving hot path launched one partial-attention
kernel per chain segment group (prefix walk, suffix walk) plus a
separate pairwise LSE-merge op.  Each launch re-streams its query tile
and round-trips its (o, m, l) partial through HBM; the merge is one
more elementwise pass over the partials.  These kernels fuse the WHOLE
root-to-leaf cascade into one ``pallas_call``:

* BOTH page tables — the concatenated prefix-chain walk ``[Bp, NPP]``
  and the private suffix walk ``[B, NPS]`` — are scalar-prefetched
  together with the per-prefix-block position OFFSET and SKIP tables
  (``num_scalar_prefetch=4``); grid step ``j`` DMAs prefix block
  ``ppt[row, j]`` while ``j < NPP`` and suffix block
  ``spt[b, j - NPP]`` after, so the kernel loop IS the full
  concatenated page walk.
* The running online-softmax accumulator (acc, m, l) lives in VMEM
  scratch across ALL segments — no per-segment partials ever
  materialize in HBM and the separate ``merge_partials`` /
  ``fold_partials`` op disappears (the two-way Pallas merge kernel was
  deleted with it; ``kernels.ref.fold_partials_ref`` survives as the
  oracle).
* Index maps clamp the inactive table (``min(j, NPP-1)`` /
  ``max(j - NPP, 0)``): Pallas skips the re-DMA when a block index is
  unchanged between steps, so the idle side costs no extra HBM traffic.
* **int8 prefix blocks** (quantized KV arena, ``core/paged.py``): when
  per-block per-kv-head f32 scales are passed, the prefix K/V tiles
  arrive int8 and are dequantized IN REGISTER right after DMA
  (``tile.astype(f32) * scale``) — resident prefix bytes halve vs bf16
  while every matmul stays f32.  Suffix tiles are always compute-dtype
  (decode writes them every step; quantizing the write path would put
  a round-trip quantization error inside the autoregressive loop).
* **Canonical-K read-time RoPE** (``rope_theta`` set; DESIGN.md §14):
  the arenas store UN-ROTATED keys.  Each DMA'd K tile is rotated
  in-register at its *effective* positions — stored position plus the
  scalar-prefetched per-prefix-block offset ``p_off[row, j]`` — right
  before the score matmul, and the first ``p_skip[row, j]`` slots of a
  prefix block are masked (boundary tokens recomputed into the suffix
  stream shadow their cached copies).  This is what makes a segment
  cached at base position P spliceable at any target offset T (delta =
  T - P) with zero copies: the page walk and the offset table are the
  whole composition.  On the non-quantized path the rotated tile is
  rounded back to the arena dtype before the dot so the kernel sees
  bitwise the same K bits as the XLA / multi-launch paths (which rotate
  via ``apply_rope``, rounding to the cache dtype); the int8 path
  rotates the dequantized f32 tile directly, exactly like its oracle.

Exactness: the single-pass accumulator is mathematically identical to
the multi-launch cascade + LSE fold but NOT bitwise (``exp(s - m)`` vs
``exp(s - m_seg) * exp(m_seg - m)`` round differently), so the fused
Pallas kernels are gated by allclose against
``kernels.ref.fused_paged_*_ref`` — which IS the multi-launch
composition — plus end-to-end greedy-token identity (tests).  The XLA
serving path under ``fused=True`` runs the composition itself and is
therefore bitwise-identical to multi-launch by construction.

Masking is purely positional like every kernel in this repo, on the
EFFECTIVE positions: valid ``kp >= 0``, causal ``kp <= qp`` (suffix
side always; prefix side of the prefill kernel only under
``prefix_causal`` — vacuous for the chain layout where every prefix
position precedes every query, required for compositions where fresh
gap tokens interleave with spliced segment positions), window
``qp - kp < w`` on both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _accum(s_mask, s, acc_ref, m_ref, l_ref, v):
    """One online-softmax update of the VMEM (acc, m, l) scratch with a
    masked score tile ``s`` [rows, bk] and value tile ``v`` [bk, d]."""
    s = jnp.where(s_mask, s, NEG_INF)
    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(s_mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = alpha * l_ref[:, 0] + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new


def _rot_tile(k, eff, inv_ref, store_dtype):
    """RoPE-rotate a [rows, d] f32 K tile in-register at effective
    positions ``eff`` [rows] (canonical-K read-time rotation).

    The angle math mirrors ``models.layers.apply_rope`` exactly:
    ``ang = eff_f32[:, None] * inv_freq``, halves rotated as
    ``(k1 cos - k2 sin) ++ (k1 sin + k2 cos)``.  ``store_dtype`` (the
    arena dtype; None on the dequantized-int8 path) rounds the rotated
    tile back before the dot so the kernel attends bitwise the same K
    bits as the XLA path's ``apply_rope`` (which rounds to the cache
    dtype).  Rotation at ``eff == -1`` lands on masked lanes only.
    """
    inv = inv_ref[0]                                       # [d/2]
    ang = eff.astype(jnp.float32)[:, None] * inv[None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    d2 = k.shape[-1] // 2
    k1, k2 = k[:, :d2], k[:, d2:]
    out = jnp.concatenate([k1 * cos - k2 * sin, k1 * sin + k2 * cos],
                          axis=-1)
    if store_dtype is not None:
        out = out.astype(store_dtype).astype(jnp.float32)
    return out


def _prefix_eff(pp, poff_ref, pskip_ref, row, j):
    """Effective positions of a prefix K tile: stored positions plus the
    block's composition offset, with the block's first ``skip`` slots
    and empty slots folded to -1 (masked)."""
    bs = pp.shape[0]
    off = poff_ref[row, j]
    skip = pskip_ref[row, j]
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    eff = jnp.where(pp >= 0, pp + off, -1)
    return jnp.where(slot < skip, -1, eff)


def _fused_decode_kernel(ppt_ref, spt_ref, poff_ref, pskip_ref, *refs,
                         window: int, npp: int, n_total: int, scale: float,
                         quantized: bool, rope: bool, shared_p: bool):
    """Grid (B, Hkv, NPP + NPS); one [group, d] q tile rides the whole
    concatenated walk.  Steps j < npp stream (and optionally dequantize
    + rotate) prefix blocks; later steps stream suffix blocks.  Causal
    masking always applies on effective positions — a decode query is
    at or past every cached position, same as the multi-launch decode
    partials."""
    if quantized:
        (qpos_ref, pkpos_ref, skpos_ref, inv_ref, q_ref, pk_ref, pv_ref,
         sk_ref, sv_ref, ks_ref, vs_ref, o_ref,
         acc_ref, m_ref, l_ref) = refs
    else:
        (qpos_ref, pkpos_ref, skpos_ref, inv_ref, q_ref, pk_ref, pv_ref,
         sk_ref, sv_ref, o_ref, acc_ref, m_ref, l_ref) = refs
    b_ = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # [g, d]
    qp = qpos_ref[0, 0]                                    # scalar int32

    def step(k, v, kp):
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = (kp >= 0) & (kp <= qp)
        if window:
            mask = mask & (qp - kp < window)
        _accum(mask[None, :], s, acc_ref, m_ref, l_ref, v)

    @pl.when(j < npp)
    def _prefix():
        k = pk_ref[0, 0].astype(jnp.float32)               # [bs, d]
        v = pv_ref[0, 0].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, 0]                           # in-register dequant
            v = v * vs_ref[0, 0]
        row = 0 if shared_p else b_
        eff = _prefix_eff(pkpos_ref[0], poff_ref, pskip_ref, row, j)
        if rope:
            k = _rot_tile(k, eff, inv_ref,
                          None if quantized else pk_ref.dtype)
        step(k, v, eff)

    @pl.when(j >= npp)
    def _suffix():
        k = sk_ref[0, 0].astype(jnp.float32)
        v = sv_ref[0, 0].astype(jnp.float32)
        kp = skpos_ref[0]
        if rope:
            k = _rot_tile(k, kp, inv_ref, sk_ref.dtype)
        step(k, v, kp)

    @pl.when(j == n_total - 1)
    def _done():
        l = l_ref[:, 0]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = acc_ref[...] / safe[:, None]


def _inv_freq_arg(d: int, rope_theta):
    """The [1, d/2] f32 inverse-frequency operand (zeros when rotation is
    off — the operand is always passed so kernel arity is static)."""
    if rope_theta is None:
        return jnp.zeros((1, d // 2), jnp.float32)
    from repro.models.layers import rope_frequencies
    return rope_frequencies(d, rope_theta).reshape(1, -1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("window", "interpret",
                                             "rope_theta"))
def fused_paged_decode_gqa(q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos,
                           prefix_table, suffix_table, k_scale=None,
                           v_scale=None, p_off=None, p_skip=None, *,
                           window: int = 0, rope_theta=None,
                           interpret: bool = True):
    """Single-token fused-cascade GQA decode over a paged KV arena.

    q: [B, Hq, D]; pk, pv: [NBp, Hkv, bs, D] prefix arena (int8 when
    ``k_scale``/``v_scale`` [NBp, Hkv] f32 are given, else compute
    dtype); sk, sv: [NBs, Hkv, bs, D] suffix arena (always compute
    dtype); p_kpos/s_kpos: [NB*, bs]; prefix_table: [Bp in (1, B), NPP]
    (a [1, NPP] table is the shared cluster walk); suffix_table:
    [B or 1, NPS].  ``rope_theta`` enables canonical-K read-time
    rotation; ``p_off``/``p_skip`` [Bp, NPP] are the per-prefix-block
    composition offset/skip tables (zeros = the degenerate chain).
    Returns the NORMALIZED output [B, Hq, D] f32 — no (m, l) escapes,
    nothing merges after.
    """
    b, hq, d = q.shape
    hkv, bs = pk.shape[1], pk.shape[2]
    assert sk.shape[2] == bs, (sk.shape, bs)
    assert d % 2 == 0, d
    pb, npp = prefix_table.shape
    sb, nps = suffix_table.shape
    assert pb in (1, b) and sb in (1, b), (prefix_table.shape,
                                           suffix_table.shape, b)
    assert npp >= 1 and nps >= 1, (npp, nps)
    quantized = k_scale is not None
    prow = (lambda b_: 0) if pb == 1 else (lambda b_: b_)
    srow = (lambda b_: 0) if sb == 1 else (lambda b_: b_)
    group = hq // hkv
    scale = d ** -0.5
    n_total = npp + nps

    qg = q.reshape(b, hkv, group, d)
    qp2 = q_pos.reshape(b, 1).astype(jnp.int32)
    if p_off is None:
        p_off = jnp.zeros(prefix_table.shape, jnp.int32)
    if p_skip is None:
        p_skip = jnp.zeros(prefix_table.shape, jnp.int32)
    inv = _inv_freq_arg(d, rope_theta)

    # the inactive table's index is CLAMPED to its last/first block so
    # Pallas sees an unchanged index and skips the re-DMA
    def jp(j):
        return jnp.minimum(j, npp - 1)

    def js(j):
        return jnp.maximum(j - npp, 0)

    in_specs = [
        pl.BlockSpec((1, 1), lambda b_, h, j, ppt, spt, *_: (b_, 0)),
        pl.BlockSpec((1, bs),
                     lambda b_, h, j, ppt, spt, *_: (ppt[prow(b_), jp(j)],
                                                     0)),
        pl.BlockSpec((1, bs),
                     lambda b_, h, j, ppt, spt, *_: (spt[srow(b_), js(j)],
                                                     0)),
        pl.BlockSpec((1, d // 2), lambda b_, h, j, ppt, spt, *_: (0, 0)),
        pl.BlockSpec((1, 1, group, d),
                     lambda b_, h, j, ppt, spt, *_: (b_, h, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, j, ppt, spt, *_: (ppt[prow(b_), jp(j)],
                                                     h, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, j, ppt, spt, *_: (ppt[prow(b_), jp(j)],
                                                     h, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, j, ppt, spt, *_: (spt[srow(b_), js(j)],
                                                     h, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, j, ppt, spt, *_: (spt[srow(b_), js(j)],
                                                     h, 0, 0)),
    ]
    args = [qp2, p_kpos, s_kpos, inv, qg, pk, pv, sk, sv]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1),
                         lambda b_, h, j, ppt, spt, *_:
                         (ppt[prow(b_), jp(j)], h)),
            pl.BlockSpec((1, 1),
                         lambda b_, h, j, ppt, spt, *_:
                         (ppt[prow(b_), jp(j)], h)),
        ]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b, hkv, n_total),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, group, d),
                         lambda b_, h, j, ppt, spt, *_: (b_, h, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    [out] = pl.pallas_call(
        functools.partial(_fused_decode_kernel, window=window, npp=npp,
                          n_total=n_total, scale=scale, quantized=quantized,
                          rope=rope_theta is not None, shared_p=pb == 1),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, hkv, group, d), jnp.float32)],
        interpret=interpret,
    )(prefix_table.astype(jnp.int32), suffix_table.astype(jnp.int32),
      p_off.astype(jnp.int32), p_skip.astype(jnp.int32), *args)
    return out.reshape(b, hq, d)


def _fused_prefill_kernel(ppt_ref, spt_ref, poff_ref, pskip_ref, *refs,
                          causal: bool, window: int, npp: int, n_total: int,
                          scale: float, quantized: bool, rope: bool,
                          shared_p: bool, prefix_causal: bool):
    """Grid (B, Hq, nq, NPP + NPS); prefill-shaped [bq, d] q tiles.
    Prefix steps use the multi-launch prefix mask (validity + window +
    ``prefix_causal`` on effective positions); suffix steps apply the
    causal mask."""
    if quantized:
        (qpos_ref, pkpos_ref, skpos_ref, inv_ref, q_ref, pk_ref, pv_ref,
         sk_ref, sv_ref, ks_ref, vs_ref, o_ref,
         acc_ref, m_ref, l_ref) = refs
    else:
        (qpos_ref, pkpos_ref, skpos_ref, inv_ref, q_ref, pk_ref, pv_ref,
         sk_ref, sv_ref, o_ref, acc_ref, m_ref, l_ref) = refs
    b_ = pl.program_id(0)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # [bq, d]
    qp = qpos_ref[0]                                       # [bq]

    def step(k, v, kp, seg_causal):
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = kp[None, :] >= 0
        if seg_causal:
            mask = mask & (kp[None, :] <= qp[:, None])
        if window:
            mask = mask & (qp[:, None] - kp[None, :] < window)
        _accum(mask, s, acc_ref, m_ref, l_ref, v)

    @pl.when(j < npp)
    def _prefix():
        k = pk_ref[0, 0].astype(jnp.float32)
        v = pv_ref[0, 0].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        row = 0 if shared_p else b_
        eff = _prefix_eff(pkpos_ref[0], poff_ref, pskip_ref, row, j)
        if rope:
            k = _rot_tile(k, eff, inv_ref,
                          None if quantized else pk_ref.dtype)
        step(k, v, eff, prefix_causal)

    @pl.when(j >= npp)
    def _suffix():
        k = sk_ref[0, 0].astype(jnp.float32)
        v = sv_ref[0, 0].astype(jnp.float32)
        kp = skpos_ref[0]
        if rope:
            k = _rot_tile(k, kp, inv_ref, sk_ref.dtype)
        step(k, v, kp, causal)

    @pl.when(j == n_total - 1)
    def _done():
        l = l_ref[:, 0]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = acc_ref[...] / safe[:, None]


def _drift_probe_kernel(qpos_ref, kpos_ref, q_ref, k_ref, o_ref,
                        m_ref, l_ref, *, nkb: int, scale: float):
    """Two-phase in-kernel drift-score accumulation (DESIGN.md §15),
    grid (Hkv, 2 * nkb).  Phase A (j < nkb) streams the key blocks once
    and folds them into the per-query online-softmax (m, l) VMEM
    scratch — the same accumulator discipline as the fused cascade.
    Phase B (j >= nkb) revisits each block (its tile re-DMA'd by the
    clamped index map) and emits the per-key probability mass
    ``sum_rows(exp(s - m) / l)`` now that the FULL normalizer is known.
    The phase-A visit writes zeros to the output block so every HBM
    flush is deterministic; the phase-B overwrite is the final value.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                       # [rows, d]
    qp = qpos_ref[0]                                       # [rows]
    k = k_ref[0].astype(jnp.float32)                       # [bk, d]
    kp = kpos_ref[0]                                       # [bk]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = (kp[None, :] >= 0) & (qp[:, None] >= 0) \
        & (kp[None, :] <= qp[:, None])

    @pl.when(j < nkb)
    def _scan():
        s_m = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s_m, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[:, 0] = jnp.exp(m_prev - m_new) * l_ref[:, 0] \
            + jnp.sum(p, axis=-1)
        m_ref[:, 0] = m_new
        o_ref[0] = jnp.zeros_like(o_ref[0])

    @pl.when(j >= nkb)
    def _emit():
        p = jnp.where(mask, jnp.exp(s - m_ref[:, 0][:, None]), 0.0)
        l = l_ref[:, 0]
        p = p / jnp.where(l > 0, l, 1.0)[:, None]
        o_ref[0] = jnp.sum(p, axis=0)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def drift_probe(q, k, q_pos, k_pos, *, block_k: int = 128,
                interpret: bool = True):
    """Per-key causal attention mass from probe queries — the Pallas
    companion of ``kernels.ref.drift_mass_ref`` (DESIGN.md §15).

    q: [Hq, Tq, D] probe queries (pre-rotated at their positions);
    k: [Hkv, S, D] composed keys (pre-rotated); q_pos: [Tq];
    k_pos: [S] (-1 = padding).  Returns [S] float32: softmax mass each
    key draws from the probe set, summed over heads and queries.  The
    score pass runs in-kernel with the online-softmax scratch
    discipline of the fused cascade (two-phase: normalize, then emit) —
    gated allclose against the oracle, not bitwise (the two-phase
    normalizer rounds differently than the dense softmax)."""
    hq, tq, d = q.shape
    hkv, s_len = k.shape[0], k.shape[1]
    g = hq // hkv
    assert g * hkv == hq, (hq, hkv)
    bk = min(block_k, max(1, s_len))
    s_pad = ((s_len + bk - 1) // bk) * bk
    if s_pad != s_len:
        k = jnp.pad(k, ((0, 0), (0, s_pad - s_len), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, s_pad - s_len), constant_values=-1)
    nkb = s_pad // bk
    rows = g * tq
    qr = q.reshape(hkv, g, tq, d).reshape(hkv, rows, d)
    qp = jnp.tile(q_pos.astype(jnp.int32), g).reshape(1, rows)
    kp = k_pos.astype(jnp.int32).reshape(1, s_pad)

    def jk(j):
        return jnp.where(j < nkb, j, j - nkb)

    [out] = pl.pallas_call(
        functools.partial(_drift_probe_kernel, nkb=nkb, scale=d ** -0.5),
        grid=(hkv, 2 * nkb),
        in_specs=[
            pl.BlockSpec((1, rows), lambda h, j: (0, 0)),
            pl.BlockSpec((1, bk), lambda h, j: (0, jk(j))),
            pl.BlockSpec((1, rows, d), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j: (h, jk(j), 0)),
        ],
        out_specs=[pl.BlockSpec((1, bk), lambda h, j: (h, jk(j)))],
        out_shape=[jax.ShapeDtypeStruct((hkv, s_pad), jnp.float32)],
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, qr, k)
    return jnp.sum(out, axis=0)[:s_len]


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "interpret", "rope_theta",
                                             "prefix_causal"))
def fused_paged_attention(q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos,
                          prefix_table, suffix_table, k_scale=None,
                          v_scale=None, p_off=None, p_skip=None, *,
                          causal: bool = True, window: int = 0,
                          block_q: int = 128, rope_theta=None,
                          prefix_causal: bool = False,
                          interpret: bool = True):
    """Fused-cascade masked GQA prefill over a paged KV arena.

    q: [B, Hq, Tq, D]; arenas / tables / scales as in
    ``fused_paged_decode_gqa`` but with prefill q tiling (grid
    (B, Hq, nq, NPP + NPS)).  ``causal`` applies to the SUFFIX side;
    ``prefix_causal`` (on effective positions) is what compositions
    need — vacuous under the chain layout.  ``rope_theta`` enables
    canonical-K read-time rotation; ``p_off``/``p_skip`` [Bp, NPP] are
    the per-prefix-block composition offset/skip tables.  Returns the
    normalized output [B, Hq, Tq, D] f32.
    """
    b, hq, tq, d = q.shape
    hkv, bs = pk.shape[1], pk.shape[2]
    assert sk.shape[2] == bs, (sk.shape, bs)
    assert d % 2 == 0, d
    pb, npp = prefix_table.shape
    sb, nps = suffix_table.shape
    assert pb in (1, b) and sb in (1, b), (prefix_table.shape,
                                           suffix_table.shape, b)
    assert npp >= 1 and nps >= 1, (npp, nps)
    quantized = k_scale is not None
    prow = (lambda b_: 0) if pb == 1 else (lambda b_: b_)
    srow = (lambda b_: 0) if sb == 1 else (lambda b_: b_)
    group = hq // hkv
    scale = d ** -0.5
    n_total = npp + nps

    bq = min(block_q, tq)
    tq_p = ((tq + bq - 1) // bq) * bq
    if tq_p != tq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, tq_p - tq), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, tq_p - tq)), constant_values=0)
    nq = tq_p // bq
    if p_off is None:
        p_off = jnp.zeros(prefix_table.shape, jnp.int32)
    if p_skip is None:
        p_skip = jnp.zeros(prefix_table.shape, jnp.int32)
    inv = _inv_freq_arg(d, rope_theta)

    def jp(j):
        return jnp.minimum(j, npp - 1)

    def js(j):
        return jnp.maximum(j - npp, 0)

    in_specs = [
        pl.BlockSpec((1, bq), lambda b_, h, i, j, ppt, spt, *_: (b_, i)),
        pl.BlockSpec((1, bs),
                     lambda b_, h, i, j, ppt, spt, *_:
                     (ppt[prow(b_), jp(j)], 0)),
        pl.BlockSpec((1, bs),
                     lambda b_, h, i, j, ppt, spt, *_:
                     (spt[srow(b_), js(j)], 0)),
        pl.BlockSpec((1, d // 2), lambda b_, h, i, j, ppt, spt, *_: (0, 0)),
        pl.BlockSpec((1, 1, bq, d),
                     lambda b_, h, i, j, ppt, spt, *_: (b_, h, i, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, i, j, ppt, spt, *_:
                     (ppt[prow(b_), jp(j)], h // group, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, i, j, ppt, spt, *_:
                     (ppt[prow(b_), jp(j)], h // group, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, i, j, ppt, spt, *_:
                     (spt[srow(b_), js(j)], h // group, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, i, j, ppt, spt, *_:
                     (spt[srow(b_), js(j)], h // group, 0, 0)),
    ]
    args = [q_pos, p_kpos, s_kpos, inv, q, pk, pv, sk, sv]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1),
                         lambda b_, h, i, j, ppt, spt, *_:
                         (ppt[prow(b_), jp(j)], h // group)),
            pl.BlockSpec((1, 1),
                         lambda b_, h, i, j, ppt, spt, *_:
                         (ppt[prow(b_), jp(j)], h // group)),
        ]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b, hq, nq, n_total),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h, i, j, ppt, spt, *_: (b_, h, i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
    )
    [out] = pl.pallas_call(
        functools.partial(_fused_prefill_kernel, causal=causal, window=window,
                          npp=npp, n_total=n_total, scale=scale,
                          quantized=quantized, rope=rope_theta is not None,
                          shared_p=pb == 1, prefix_causal=prefix_causal),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, hq, tq_p, d), jnp.float32)],
        interpret=interpret,
    )(prefix_table.astype(jnp.int32), suffix_table.astype(jnp.int32),
      p_off.astype(jnp.int32), p_skip.astype(jnp.int32), *args)
    return out[:, :, :tq, :]
