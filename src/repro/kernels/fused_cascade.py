"""Fused single-pass cascade serving kernels (DESIGN.md §11).

Through PR 5 the paged serving hot path launched one partial-attention
kernel per chain segment group (prefix walk, suffix walk) plus a
separate pairwise LSE-merge op.  Each launch re-streams its query tile
and round-trips its (o, m, l) partial through HBM; the merge is one
more elementwise pass over the partials.  These kernels fuse the WHOLE
root-to-leaf cascade into one ``pallas_call``:

* BOTH page tables — the concatenated prefix-chain walk ``[Bp, NPP]``
  and the private suffix walk ``[B, NPS]`` — are scalar-prefetched
  (``num_scalar_prefetch=2``); grid step ``j`` DMAs prefix block
  ``ppt[row, j]`` while ``j < NPP`` and suffix block
  ``spt[b, j - NPP]`` after, so the kernel loop IS the full
  concatenated page walk.
* The running online-softmax accumulator (acc, m, l) lives in VMEM
  scratch across ALL segments — no per-segment partials ever
  materialize in HBM and the separate ``merge_partials`` /
  ``fold_partials`` op disappears (the two-way Pallas merge kernel was
  deleted with it; ``kernels.ref.fold_partials_ref`` survives as the
  oracle).
* Index maps clamp the inactive table (``min(j, NPP-1)`` /
  ``max(j - NPP, 0)``): Pallas skips the re-DMA when a block index is
  unchanged between steps, so the idle side costs no extra HBM traffic.
* **int8 prefix blocks** (quantized KV arena, ``core/paged.py``): when
  per-block per-kv-head f32 scales are passed, the prefix K/V tiles
  arrive int8 and are dequantized IN REGISTER right after DMA
  (``tile.astype(f32) * scale``) — resident prefix bytes halve vs bf16
  while every matmul stays f32.  Suffix tiles are always compute-dtype
  (decode writes them every step; quantizing the write path would put
  a round-trip quantization error inside the autoregressive loop).

Exactness: the single-pass accumulator is mathematically identical to
the multi-launch cascade + LSE fold but NOT bitwise (``exp(s - m)`` vs
``exp(s - m_seg) * exp(m_seg - m)`` round differently), so the fused
Pallas kernels are gated by allclose against
``kernels.ref.fused_paged_*_ref`` — which IS the multi-launch
composition — plus end-to-end greedy-token identity (tests).  The XLA
serving path under ``fused=True`` runs the composition itself and is
therefore bitwise-identical to multi-launch by construction.

Masking is purely positional like every kernel in this repo: valid
``kp >= 0``, causal ``kp <= qp`` (suffix side; every prefix position
precedes every query so the prefix side matches the multi-launch
``causal=False`` partial exactly), window ``qp - kp < w`` on both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _accum(s_mask, s, acc_ref, m_ref, l_ref, v):
    """One online-softmax update of the VMEM (acc, m, l) scratch with a
    masked score tile ``s`` [rows, bk] and value tile ``v`` [bk, d]."""
    s = jnp.where(s_mask, s, NEG_INF)
    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(s_mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = alpha * l_ref[:, 0] + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new


def _fused_decode_kernel(ppt_ref, spt_ref, *refs, window: int, npp: int,
                         n_total: int, scale: float, quantized: bool):
    """Grid (B, Hkv, NPP + NPS); one [group, d] q tile rides the whole
    concatenated walk.  Steps j < npp stream (and optionally dequantize)
    prefix blocks; later steps stream suffix blocks.  Causal masking
    always applies — a decode query is at or past every cached
    position, same as the multi-launch decode partials."""
    if quantized:
        (qpos_ref, pkpos_ref, skpos_ref, q_ref, pk_ref, pv_ref,
         sk_ref, sv_ref, ks_ref, vs_ref, o_ref,
         acc_ref, m_ref, l_ref) = refs
    else:
        (qpos_ref, pkpos_ref, skpos_ref, q_ref, pk_ref, pv_ref,
         sk_ref, sv_ref, o_ref, acc_ref, m_ref, l_ref) = refs
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # [g, d]
    qp = qpos_ref[0, 0]                                    # scalar int32

    def step(k, v, kp):
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = (kp >= 0) & (kp <= qp)
        if window:
            mask = mask & (qp - kp < window)
        _accum(mask[None, :], s, acc_ref, m_ref, l_ref, v)

    @pl.when(j < npp)
    def _prefix():
        k = pk_ref[0, 0].astype(jnp.float32)               # [bs, d]
        v = pv_ref[0, 0].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, 0]                           # in-register dequant
            v = v * vs_ref[0, 0]
        step(k, v, pkpos_ref[0])

    @pl.when(j >= npp)
    def _suffix():
        step(sk_ref[0, 0].astype(jnp.float32),
             sv_ref[0, 0].astype(jnp.float32), skpos_ref[0])

    @pl.when(j == n_total - 1)
    def _done():
        l = l_ref[:, 0]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = acc_ref[...] / safe[:, None]


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def fused_paged_decode_gqa(q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos,
                           prefix_table, suffix_table, k_scale=None,
                           v_scale=None, *, window: int = 0,
                           interpret: bool = True):
    """Single-token fused-cascade GQA decode over a paged KV arena.

    q: [B, Hq, D]; pk, pv: [NBp, Hkv, bs, D] prefix arena (int8 when
    ``k_scale``/``v_scale`` [NBp, Hkv] f32 are given, else compute
    dtype); sk, sv: [NBs, Hkv, bs, D] suffix arena (always compute
    dtype); p_kpos/s_kpos: [NB*, bs]; prefix_table: [Bp in (1, B), NPP]
    (a [1, NPP] table is the shared cluster walk); suffix_table:
    [B or 1, NPS].  Returns the NORMALIZED output [B, Hq, D] f32 — no
    (m, l) escapes, nothing merges after.
    """
    b, hq, d = q.shape
    hkv, bs = pk.shape[1], pk.shape[2]
    assert sk.shape[2] == bs, (sk.shape, bs)
    pb, npp = prefix_table.shape
    sb, nps = suffix_table.shape
    assert pb in (1, b) and sb in (1, b), (prefix_table.shape,
                                           suffix_table.shape, b)
    assert npp >= 1 and nps >= 1, (npp, nps)
    quantized = k_scale is not None
    prow = (lambda b_: 0) if pb == 1 else (lambda b_: b_)
    srow = (lambda b_: 0) if sb == 1 else (lambda b_: b_)
    group = hq // hkv
    scale = d ** -0.5
    n_total = npp + nps

    qg = q.reshape(b, hkv, group, d)
    qp2 = q_pos.reshape(b, 1).astype(jnp.int32)

    # the inactive table's index is CLAMPED to its last/first block so
    # Pallas sees an unchanged index and skips the re-DMA
    def jp(j):
        return jnp.minimum(j, npp - 1)

    def js(j):
        return jnp.maximum(j - npp, 0)

    in_specs = [
        pl.BlockSpec((1, 1), lambda b_, h, j, ppt, spt: (b_, 0)),
        pl.BlockSpec((1, bs),
                     lambda b_, h, j, ppt, spt: (ppt[prow(b_), jp(j)], 0)),
        pl.BlockSpec((1, bs),
                     lambda b_, h, j, ppt, spt: (spt[srow(b_), js(j)], 0)),
        pl.BlockSpec((1, 1, group, d),
                     lambda b_, h, j, ppt, spt: (b_, h, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, j, ppt, spt: (ppt[prow(b_), jp(j)],
                                                 h, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, j, ppt, spt: (ppt[prow(b_), jp(j)],
                                                 h, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, j, ppt, spt: (spt[srow(b_), js(j)],
                                                 h, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, j, ppt, spt: (spt[srow(b_), js(j)],
                                                 h, 0, 0)),
    ]
    args = [qp2, p_kpos, s_kpos, qg, pk, pv, sk, sv]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1),
                         lambda b_, h, j, ppt, spt: (ppt[prow(b_), jp(j)],
                                                     h)),
            pl.BlockSpec((1, 1),
                         lambda b_, h, j, ppt, spt: (ppt[prow(b_), jp(j)],
                                                     h)),
        ]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_total),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, group, d),
                         lambda b_, h, j, ppt, spt: (b_, h, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    [out] = pl.pallas_call(
        functools.partial(_fused_decode_kernel, window=window, npp=npp,
                          n_total=n_total, scale=scale, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, hkv, group, d), jnp.float32)],
        interpret=interpret,
    )(prefix_table.astype(jnp.int32), suffix_table.astype(jnp.int32), *args)
    return out.reshape(b, hq, d)


def _fused_prefill_kernel(ppt_ref, spt_ref, *refs, causal: bool, window: int,
                          npp: int, n_total: int, scale: float,
                          quantized: bool):
    """Grid (B, Hq, nq, NPP + NPS); prefill-shaped [bq, d] q tiles.
    Prefix steps use the multi-launch prefix mask (validity + window,
    NO causal term — every prefix position precedes every query);
    suffix steps apply the causal mask."""
    if quantized:
        (qpos_ref, pkpos_ref, skpos_ref, q_ref, pk_ref, pv_ref,
         sk_ref, sv_ref, ks_ref, vs_ref, o_ref,
         acc_ref, m_ref, l_ref) = refs
    else:
        (qpos_ref, pkpos_ref, skpos_ref, q_ref, pk_ref, pv_ref,
         sk_ref, sv_ref, o_ref, acc_ref, m_ref, l_ref) = refs
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # [bq, d]
    qp = qpos_ref[0]                                       # [bq]

    def step(k, v, kp, seg_causal):
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = kp[None, :] >= 0
        if seg_causal:
            mask = mask & (kp[None, :] <= qp[:, None])
        if window:
            mask = mask & (qp[:, None] - kp[None, :] < window)
        _accum(mask, s, acc_ref, m_ref, l_ref, v)

    @pl.when(j < npp)
    def _prefix():
        k = pk_ref[0, 0].astype(jnp.float32)
        v = pv_ref[0, 0].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        step(k, v, pkpos_ref[0], False)

    @pl.when(j >= npp)
    def _suffix():
        step(sk_ref[0, 0].astype(jnp.float32),
             sv_ref[0, 0].astype(jnp.float32), skpos_ref[0], causal)

    @pl.when(j == n_total - 1)
    def _done():
        l = l_ref[:, 0]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = acc_ref[...] / safe[:, None]


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "interpret"))
def fused_paged_attention(q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos,
                          prefix_table, suffix_table, k_scale=None,
                          v_scale=None, *, causal: bool = True,
                          window: int = 0, block_q: int = 128,
                          interpret: bool = True):
    """Fused-cascade masked GQA prefill over a paged KV arena.

    q: [B, Hq, Tq, D]; arenas / tables / scales as in
    ``fused_paged_decode_gqa`` but with prefill q tiling (grid
    (B, Hq, nq, NPP + NPS)).  ``causal`` applies to the SUFFIX side
    only (the prefix side replicates the multi-launch ``causal=False``
    prefix partial).  Returns the normalized output [B, Hq, Tq, D] f32.
    """
    b, hq, tq, d = q.shape
    hkv, bs = pk.shape[1], pk.shape[2]
    assert sk.shape[2] == bs, (sk.shape, bs)
    pb, npp = prefix_table.shape
    sb, nps = suffix_table.shape
    assert pb in (1, b) and sb in (1, b), (prefix_table.shape,
                                           suffix_table.shape, b)
    assert npp >= 1 and nps >= 1, (npp, nps)
    quantized = k_scale is not None
    prow = (lambda b_: 0) if pb == 1 else (lambda b_: b_)
    srow = (lambda b_: 0) if sb == 1 else (lambda b_: b_)
    group = hq // hkv
    scale = d ** -0.5
    n_total = npp + nps

    bq = min(block_q, tq)
    tq_p = ((tq + bq - 1) // bq) * bq
    if tq_p != tq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, tq_p - tq), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, tq_p - tq)), constant_values=0)
    nq = tq_p // bq

    def jp(j):
        return jnp.minimum(j, npp - 1)

    def js(j):
        return jnp.maximum(j - npp, 0)

    in_specs = [
        pl.BlockSpec((1, bq), lambda b_, h, i, j, ppt, spt: (b_, i)),
        pl.BlockSpec((1, bs),
                     lambda b_, h, i, j, ppt, spt: (ppt[prow(b_), jp(j)], 0)),
        pl.BlockSpec((1, bs),
                     lambda b_, h, i, j, ppt, spt: (spt[srow(b_), js(j)], 0)),
        pl.BlockSpec((1, 1, bq, d),
                     lambda b_, h, i, j, ppt, spt: (b_, h, i, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, i, j, ppt, spt: (ppt[prow(b_), jp(j)],
                                                    h // group, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, i, j, ppt, spt: (ppt[prow(b_), jp(j)],
                                                    h // group, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, i, j, ppt, spt: (spt[srow(b_), js(j)],
                                                    h // group, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, i, j, ppt, spt: (spt[srow(b_), js(j)],
                                                    h // group, 0, 0)),
    ]
    args = [q_pos, p_kpos, s_kpos, q, pk, pv, sk, sv]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1),
                         lambda b_, h, i, j, ppt, spt: (ppt[prow(b_), jp(j)],
                                                        h // group)),
            pl.BlockSpec((1, 1),
                         lambda b_, h, i, j, ppt, spt: (ppt[prow(b_), jp(j)],
                                                        h // group)),
        ]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hq, nq, n_total),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h, i, j, ppt, spt: (b_, h, i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
    )
    [out] = pl.pallas_call(
        functools.partial(_fused_prefill_kernel, causal=causal, window=window,
                          npp=npp, n_total=n_total, scale=scale,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, hq, tq_p, d), jnp.float32)],
        interpret=interpret,
    )(prefix_table.astype(jnp.int32), suffix_table.astype(jnp.int32), *args)
    return out[:, :, :tq, :]
