"""Pallas TPU kernel: Mamba selective scan.

TPU adaptation of the CUDA selective-scan: channels are tiled into
``block_d`` VMEM-resident stripes (grid dim), time is tiled into
``block_t`` chunks streamed HBM->VMEM with the recurrent state
``[block_d, N]`` carried in VMEM scratch across the (minor, sequential)
time-chunk grid dimension.  Inside a chunk the recurrence runs as a
``fori_loop`` over timesteps on the VPU — the MXU has no role in a
diagonal recurrence; the kernel's job is keeping the state resident and
the x/dt/B/C streams blocked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, hT_ref,
            h_ref, *, nt: int, bt: int):
    t_idx = pl.program_id(2)

    @pl.when(t_idx == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)                    # [bd, N]
    x = x_ref[0].astype(jnp.float32)                      # [bt, bd]
    dt = dt_ref[0].astype(jnp.float32)                    # [bt, bd]
    bm = b_ref[0].astype(jnp.float32)                     # [bt, N]
    cm = c_ref[0].astype(jnp.float32)                     # [bt, N]

    def step(t, carry):
        h, ybuf = carry
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]     # [bd]
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)[0]       # [bd]
        b_t = jax.lax.dynamic_slice_in_dim(bm, t, 1, 0)[0]      # [N]
        c_t = jax.lax.dynamic_slice_in_dim(cm, t, 1, 0)[0]      # [N]
        da = jnp.exp(dt_t[:, None] * a)                          # [bd, N]
        db = dt_t[:, None] * b_t[None, :]
        h = da * h + db * x_t[:, None]
        y_t = jnp.sum(h * c_t[None, :], axis=-1)                 # [bd]
        ybuf = jax.lax.dynamic_update_slice_in_dim(ybuf, y_t[None], t, 0)
        return h, ybuf

    h0 = h_ref[...]
    ybuf0 = jnp.zeros((bt, x.shape[1]), jnp.float32)
    h, ybuf = jax.lax.fori_loop(0, bt, step, (h0, ybuf0))
    h_ref[...] = h
    y_ref[0] = ybuf.astype(y_ref.dtype)

    @pl.when(t_idx == nt - 1)
    def _done():
        hT_ref[0] = h_ref[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "block_t", "interpret"))
def ssm_scan(x, dt, B, C, A, h0=None, *, block_d: int = 256,
             block_t: int = 256, interpret: bool = True):
    """x, dt: [Bt, T, Di]; B, C: [Bt, T, N]; A: [Di, N]; h0: [Bt, Di, N].

    Returns (y [Bt, T, Di] float32, h_final [Bt, Di, N] float32).
    """
    bt_dim, t_len, di = x.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bt_dim, di, n), jnp.float32)

    bd = min(block_d, di)
    btk = min(block_t, t_len)
    assert di % bd == 0, (di, bd)
    t_p = ((t_len + btk - 1) // btk) * btk
    if t_p != t_len:
        pad = ((0, 0), (0, t_p - t_len), (0, 0))
        # padded steps: dt = 0 -> da = 1, db = 0 -> state unchanged; y rows
        # are sliced off below.
        x, dt, B, C = (jnp.pad(arr, pad) for arr in (x, dt, B, C))
    nd, nt = di // bd, t_p // btk

    y, h_final = pl.pallas_call(
        functools.partial(_kernel, nt=nt, bt=btk),
        grid=(bt_dim, nd, nt),
        in_specs=[
            pl.BlockSpec((1, btk, bd), lambda b_, d_, t_: (b_, t_, d_)),  # x
            pl.BlockSpec((1, btk, bd), lambda b_, d_, t_: (b_, t_, d_)),  # dt
            pl.BlockSpec((1, btk, n), lambda b_, d_, t_: (b_, t_, 0)),    # B
            pl.BlockSpec((1, btk, n), lambda b_, d_, t_: (b_, t_, 0)),    # C
            pl.BlockSpec((bd, n), lambda b_, d_, t_: (d_, 0)),            # A
            pl.BlockSpec((1, bd, n), lambda b_, d_, t_: (b_, d_, 0)),     # h0
        ],
        out_specs=[
            pl.BlockSpec((1, btk, bd), lambda b_, d_, t_: (b_, t_, d_)),
            pl.BlockSpec((1, bd, n), lambda b_, d_, t_: (b_, d_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt_dim, t_p, di), jnp.float32),
            jax.ShapeDtypeStruct((bt_dim, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, B, C, A, h0)
    return y[:, :t_len], h_final
