"""Pallas TPU kernel: RG-LRU linear recurrence (Griffin / RecurrentGemma).

Same blocking strategy as ``ssm_scan`` but the state is diagonal per
channel ([block_w] vector instead of [block_d, N]):

    h_t = exp(a_log_t) * h_{t-1} + sqrt(1 - exp(2 a_log_t)) * x_t

Channels tile the width grid dim; time chunks stream with the state in
VMEM scratch across the sequential minor grid dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, h0_ref, y_ref, hT_ref, h_ref, *, nt: int, bt: int):
    t_idx = pl.program_id(2)

    @pl.when(t_idx == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)                      # [bt, bw]
    al = a_ref[0].astype(jnp.float32)                     # [bt, bw]

    def step(t, carry):
        h, ybuf = carry                                    # h: [1, bw]
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)     # [1, bw]
        a_t = jnp.exp(jax.lax.dynamic_slice_in_dim(al, t, 1, 0))
        h = a_t * h + jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 1e-12)) * x_t
        ybuf = jax.lax.dynamic_update_slice_in_dim(ybuf, h, t, 0)
        return h, ybuf

    ybuf0 = jnp.zeros((bt, x.shape[1]), jnp.float32)
    h, ybuf = jax.lax.fori_loop(0, bt, step, (h_ref[...], ybuf0))
    h_ref[...] = h
    y_ref[0] = ybuf.astype(y_ref.dtype)

    @pl.when(t_idx == nt - 1)
    def _done():
        hT_ref[...] = h_ref[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_w", "block_t", "interpret"))
def rglru_scan(x, a_log, h0=None, *, block_w: int = 512, block_t: int = 256,
               interpret: bool = True):
    """x, a_log: [B, T, W]; h0: [B, W].  Returns (y [B,T,W] f32, hT [B,W] f32)."""
    b, t_len, w = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)

    bw = min(block_w, w)
    btk = min(block_t, t_len)
    assert w % bw == 0, (w, bw)
    t_p = ((t_len + btk - 1) // btk) * btk
    if t_p != t_len:
        pad = ((0, 0), (0, t_p - t_len), (0, 0))
        x = jnp.pad(x, pad)
        # padded steps: a_log = big negative -> a ~ 0... that would reset h!
        # use a_log = 0 -> a = 1, sqrt(1-1) = 0 -> state unchanged.
        a_log = jnp.pad(a_log, pad, constant_values=0.0)
    nw, nt = w // bw, t_p // btk

    y, h_final = pl.pallas_call(
        functools.partial(_kernel, nt=nt, bt=btk),
        grid=(b, nw, nt),
        in_specs=[
            pl.BlockSpec((1, btk, bw), lambda b_, w_, t_: (b_, t_, w_)),
            pl.BlockSpec((1, btk, bw), lambda b_, w_, t_: (b_, t_, w_)),
            pl.BlockSpec((1, bw), lambda b_, w_, t_: (b_, w_)),
        ],
        out_specs=[
            pl.BlockSpec((1, btk, bw), lambda b_, w_, t_: (b_, t_, w_)),
            pl.BlockSpec((1, bw), lambda b_, w_, t_: (b_, w_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t_p, w), jnp.float32),
            jax.ShapeDtypeStruct((b, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(x, a_log, h0)
    return y[:, :t_len], h_final
