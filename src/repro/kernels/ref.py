"""Pure-jnp oracles for every Pallas kernel.

These are the single source of truth for kernel semantics; the kernel tests
sweep shapes/dtypes and assert allclose against these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def prefix_attention_ref(q, k, v, q_pos, k_pos, *, causal: bool = True,
                         window: int = 0):
    """Masked GQA flash-attention oracle.

    q: [B, Hq, Tq, D]; k, v: [B, Hkv, S, D]; q_pos: [B, Tq]; k_pos: [B, S]
    (k_pos == -1 marks invalid slots).  Covers full prefill, SubGCache
    suffix prefill over a cached prefix, and sliding-window attention.
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, tq, d).astype(jnp.float32)
    scores = jnp.einsum("bhgtd,bhsd->bhgts", qg, k.astype(jnp.float32))
    scores = scores * (d ** -0.5)
    mask = k_pos[:, None, :] >= 0
    if causal:
        mask = mask & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        mask = mask & (q_pos[:, :, None] - k_pos[:, None, :] < window)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    # fully-masked query rows (padding) -> zero output, not NaN
    any_valid = jnp.any(mask, axis=-1)                         # [B, Tq]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgts,bhsd->bhgtd", probs, v.astype(jnp.float32))
    out = out.reshape(b, hq, tq, d)
    out = jnp.where(any_valid[:, None, :, None], out, 0.0)
    return out.astype(q.dtype)


def attention_partial_ref(q, k, v, q_pos, k_pos, *,
                          causal: bool = True, window: int = 0):
    """Partial masked GQA attention in online-softmax form (oracle).

    q: [B, Hq, Tq, D]; k, v: [Bk, Hkv, S, D] with Bk in (1, B) — Bk == 1
    is the SubGCache shared-prefix case (every member attends the same
    representative KV); q_pos: [B, Tq]; k_pos: [Bk, S] (-1 = empty slot).
    Paged multi-prefix batches use ``paged_attention_partial_ref``.

    Returns (out [B,Hq,Tq,D] f32 normalized, m [B,Hq,Tq], l [B,Hq,Tq])
    such that ``merge_partials_ref`` over disjoint key sets reproduces
    full softmax attention exactly.  Partials stay f32 (one rounding to
    the model dtype, after the merge).  Fully-masked rows give out=0,
    m=NEG_INF, l=0.
    """
    b, hq, tq, d = q.shape
    bk, hkv = k.shape[0], k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, tq, d).astype(jnp.float32)
    if bk == 1:          # shared KV: contract against the single batch row
        scores = jnp.einsum("bhgtd,hsd->bhgts", qg, k[0].astype(jnp.float32))
    else:
        scores = jnp.einsum("bhgtd,bhsd->bhgts", qg, k.astype(jnp.float32))
    scores = scores * (d ** -0.5)
    mask = k_pos[:, None, :] >= 0                        # [Bk, 1, S]
    if causal:
        mask = mask & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        mask = mask & (q_pos[:, :, None] - k_pos[:, None, :] < window)
    mask = jnp.broadcast_to(mask[:, None, None, :, :],
                            scores.shape)                # [B,Hkv,G,Tq,S]
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1)                         # [B,Hkv,G,Tq]
    p = jnp.where(mask, jnp.exp(scores - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    vv = v.astype(jnp.float32)
    if bk == 1:
        out = jnp.einsum("bhgts,hsd->bhgtd", p, vv[0])
    else:
        out = jnp.einsum("bhgts,bhsd->bhgtd", p, vv)
    out = out / jnp.where(l > 0, l, 1.0)[..., None]
    return (out.reshape(b, hq, tq, d),
            m.reshape(b, hq, tq), l.reshape(b, hq, tq))


def paged_attention_partial_ref(q, k, v, q_pos, k_pos, page_table, *,
                                causal: bool = False, window: int = 0,
                                rope_theta=None, offsets=None, skips=None):
    """Partial masked GQA attention over a paged KV arena (oracle).

    q: [B, Hq, Tq, D]; k, v: [NB, Hkv, bs, D] block arena; k_pos:
    [NB, bs]; page_table: [B, NP] int32 (NULL-block padded).  The
    oracle gathers each row's blocks into a dense [Tb, Hkv, NP*bs, D]
    sequence and delegates to ``attention_partial_ref`` — the kernel
    walks the table with per-block DMA instead.  Key order is
    page-table order, so kernel and oracle see identical sequences.
    A [1, NP] table is the shared walk (every query row attends the
    same blocks; the dense delegate's Bk == 1 branch).

    Canonical-K composition (DESIGN.md §14): ``offsets`` [Tb, NP] adds a
    per-block position delta to the stored positions (segment spliced at
    a new target offset), ``skips`` [Tb, NP] masks the first N slots of
    each block (boundary tokens recomputed into the suffix stream shadow
    the cached copies), and ``rope_theta`` rotates the gathered keys at
    the resulting *effective* positions — the arena stores un-rotated
    keys.  All masking downstream uses the effective positions.
    """
    tb, np_ = page_table.shape
    hkv, bs, d = k.shape[1], k.shape[2], k.shape[3]
    kk = jnp.moveaxis(k[page_table], 1, 2).reshape(tb, hkv, np_ * bs, d)
    vv = jnp.moveaxis(v[page_table], 1, 2).reshape(tb, hkv, np_ * bs, d)
    kp = k_pos[page_table].reshape(tb, np_ * bs)
    if offsets is not None:
        off = jnp.repeat(offsets.astype(jnp.int32), bs, axis=1)
        kp = jnp.where(kp >= 0, kp + off, -1)
    if skips is not None:
        slot = jnp.tile(jnp.arange(bs, dtype=jnp.int32), np_)[None]
        skip = jnp.repeat(skips.astype(jnp.int32), bs, axis=1)
        kp = jnp.where(slot < skip, -1, kp)
    if rope_theta is not None:
        from repro.models.layers import apply_rope
        kk = apply_rope(kk, kp[:, None, :], rope_theta)
    return attention_partial_ref(q, kk, vv, q_pos, kp, causal=causal,
                                 window=window)


def paged_decode_gqa_partial_ref(q, k, v, q_pos, k_pos, page_table, *,
                                 window: int = 0, rope_theta=None,
                                 offsets=None, skips=None):
    """Single-token paged GQA decode partial (oracle): gather the page
    walk dense, then the causal decode partial.  q: [B, Hq, D]."""
    out, m, l = paged_attention_partial_ref(
        q[:, :, None, :], k, v, q_pos[:, None], k_pos, page_table,
        causal=True, window=window, rope_theta=rope_theta, offsets=offsets,
        skips=skips)
    return out[:, :, 0, :], m[:, :, 0], l[:, :, 0]


def merge_partials_ref(o1, m1, l1, o2, m2, l2):
    """LSE-merge of two online-softmax partials over disjoint key sets.

    o*: [B, Hq, Tq, D] normalized partial outputs; m*, l*: [B, Hq, Tq].
    Returns merged (out, m, l); exact (not approximate) flash-style merge.
    """
    m = jnp.maximum(m1, m2)
    w1 = jnp.exp(m1 - m) * l1
    w2 = jnp.exp(m2 - m) * l2
    l = w1 + w2
    safe = jnp.where(l > 0, l, 1.0)
    out = (o1.astype(jnp.float32) * w1[..., None]
           + o2.astype(jnp.float32) * w2[..., None]) / safe[..., None]
    return out.astype(o1.dtype), m, l


def fold_partials_ref(partials):
    """Associative LSE-fold of N online-softmax partials over pairwise
    disjoint key sets: ``softmax([keys1 ++ ... ++ keysN])`` equals the
    left fold of ``merge_partials_ref`` over the partial list.

    The N-segment prefix-chain cascade (DESIGN.md §10): one partial per
    chain segment plus the suffix partial, folded in path order.  The
    merge is associative (each step is an exact flash-style
    renormalization), so any fold order is mathematically identical;
    the left fold is canonical so kernel and oracle see the same
    floating-point evaluation order.
    """
    assert partials, "need at least one partial"
    o, m, l = partials[0]
    for o2, m2, l2 in partials[1:]:
        o, m, l = merge_partials_ref(o, m, l, o2, m2, l2)
    return o, m, l


def drift_mass_ref(q, k, q_pos, k_pos):
    """Per-key causal attention-mass oracle for drift scoring
    (DESIGN.md §15).

    q: [Hq, Tq, D] probe queries (the composed prompt's FRESH tokens —
    gap spans + the member suffix — already RoPE-rotated at their
    absolute positions); k: [Hkv, S, D] the full composed key set,
    rotated at ``k_pos``; q_pos: [Tq]; k_pos: [S] (-1 = padding).

    Returns [S] float32: the total softmax probability mass the probe
    queries place on each key under the causal mask, summed over heads
    and queries.  Keys of a spliced segment that draw heavy mass from
    the fresh context are the ones whose own KV the frozen cache most
    misrepresents — their blocks are what ``recompute_budget`` should
    spend itself on.  Padding query rows (q_pos == -1) and padding keys
    contribute exactly zero.
    """
    hq, tq, d = q.shape
    hkv = k.shape[0]
    g = hq // hkv
    qg = q.reshape(hkv, g, tq, d).astype(jnp.float32)
    scores = jnp.einsum("hgtd,hsd->hgts", qg, k.astype(jnp.float32))
    scores = scores * (d ** -0.5)
    mask = (k_pos[None, :] >= 0) & (q_pos[:, None] >= 0) \
        & (k_pos[None, :] <= q_pos[:, None])             # [Tq, S]
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)          # [Hkv,G,Tq,1]
    p = jnp.where(mask[None, None], jnp.exp(scores - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(l > 0, l, 1.0)
    return jnp.sum(p, axis=(0, 1, 2))                    # [S]


def dequantize_paged_ref(x, scale):
    """Dequantize a head-major int8 paged arena [NB, Hkv, bs, D] with
    per-(block, kv-head) f32 scales [NB, Hkv]."""
    return x.astype(jnp.float32) * scale[:, :, None, None]


def fused_paged_attention_ref(q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos,
                              prefix_table, suffix_table, k_scale=None,
                              v_scale=None, *, causal: bool = True,
                              window: int = 0, rope_theta=None,
                              p_off=None, p_skip=None,
                              prefix_causal: bool = False):
    """Oracle for the fused single-pass cascade prefill kernel.

    BY CONSTRUCTION this is the exact multi-launch composition — prefix
    partial (causal=False) + suffix partial (causal) + LSE merge — so
    the ``fused=True`` serving path on the XLA backend, which runs this
    composition, is bitwise-identical to multi-launch, and the Pallas
    single-pass kernel (whose accumulator visits the same keys in the
    same order but renormalizes incrementally) is gated against it by
    allclose + end-to-end greedy-token identity.  When
    ``k_scale``/``v_scale`` [NBp, Hkv] are given the prefix arena is
    int8 and is dequantized before the prefix partial (int8 mode is
    otherwise off for oracles).  ``rope_theta``/``p_off``/``p_skip``
    mirror the canonical-K kernel (read-time rotation at effective
    positions; see ``paged_attention_partial_ref``); ``prefix_causal``
    makes the prefix partial causal on effective positions — the serving
    path sets it whenever rotating, since composed prompts interleave
    fresh gap tokens with cached segment positions (vacuous for the
    chain layout).  Returns the normalized output only.
    """
    if k_scale is not None:
        pk = dequantize_paged_ref(pk, k_scale)
        pv = dequantize_paged_ref(pv, v_scale)
    o1, m1, l1 = paged_attention_partial_ref(
        q, pk, pv, q_pos, p_kpos, prefix_table, causal=prefix_causal,
        window=window, rope_theta=rope_theta, offsets=p_off, skips=p_skip)
    o2, m2, l2 = paged_attention_partial_ref(
        q, sk, sv, q_pos, s_kpos, suffix_table, causal=causal, window=window,
        rope_theta=rope_theta)
    out, _, _ = merge_partials_ref(o1, m1, l1, o2, m2, l2)
    return out


def fused_paged_decode_gqa_ref(q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos,
                               prefix_table, suffix_table, k_scale=None,
                               v_scale=None, *, window: int = 0,
                               rope_theta=None, p_off=None, p_skip=None):
    """Oracle for the fused single-pass cascade decode kernel: the exact
    multi-launch decode composition (both partials causal) with optional
    int8 prefix dequantization and canonical-K read-time rotation /
    composition offsets (see ``paged_attention_partial_ref``).
    q: [B, Hq, D]; returns [B, Hq, D]."""
    if k_scale is not None:
        pk = dequantize_paged_ref(pk, k_scale)
        pv = dequantize_paged_ref(pv, v_scale)
    o1, m1, l1 = paged_decode_gqa_partial_ref(
        q, pk, pv, q_pos, p_kpos, prefix_table, window=window,
        rope_theta=rope_theta, offsets=p_off, skips=p_skip)
    o2, m2, l2 = paged_decode_gqa_partial_ref(
        q, sk, sv, q_pos, s_kpos, suffix_table, window=window,
        rope_theta=rope_theta)
    out, _, _ = merge_partials_ref(o1, m1, l1, o2, m2, l2)
    return out


def decode_gqa_ref(q, k, v, q_pos, k_pos, *, window: int = 0):
    """Single-token GQA decode oracle.

    q: [B, Hq, D]; k, v: [B, Hkv, S, D]; q_pos: [B]; k_pos: [B, S].
    """
    out = prefix_attention_ref(q[:, :, None, :], k, v, q_pos[:, None], k_pos,
                               causal=True, window=window)
    return out[:, :, 0, :]


def ssm_scan_ref(x, dt, B, C, A, h0=None):
    """Mamba selective-scan oracle.

    x, dt: [Bt, T, Di]; B, C: [Bt, T, N]; A: [Di, N]; h0: [Bt, Di, N] or None.
    Returns (y [Bt, T, Di], h_final [Bt, Di, N]); float32 math.
    """
    bt, t, di = x.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bt, di, n), jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * A)
        db = dt_t[..., None] * b_t[:, None, :]
        h = da * h + db * x_t[..., None]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), h_final


def rglru_scan_ref(x, a_log, h0=None):
    """RG-LRU recurrence oracle.

    x (gated input), a_log (log decay, <= 0): [B, T, W]; h0: [B, W] or None.
    h_t = exp(a_log_t) h_{t-1} + sqrt(1 - exp(2 a_log_t)) x_t
    Returns (y [B, T, W] = all h_t, h_final [B, W]).
    """
    b, t, w = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)

    def step(h, inp):
        x_t, al_t = inp
        a = jnp.exp(al_t)
        h = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x_t
        return h, h

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(a_log, 1, 0).astype(jnp.float32))
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), h_final
