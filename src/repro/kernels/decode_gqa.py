"""Pallas TPU kernel: single-token GQA decode attention.

decode_32k / long_500k hot spot: one query token against a long KV cache
is purely memory-bound (arithmetic intensity ~ 1 FLOP/byte), so the win
is reading each KV block exactly once.  GQA lets the whole q-head *group*
share one KV stream: the q block is [group, d] (all q heads of one kv
head), giving an MXU-shaped [group, block_k] score tile.

Grid (B, Hkv, nk), KV minor; online softmax scratch persists over nk.
Ring-buffer caches just work: masking is positional (slot position array),
so slot order is irrelevant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, window: int, nk: int, scale: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # [g, d]
    k = k_ref[0, 0].astype(jnp.float32)                    # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)
    qp = qpos_ref[0, 0]                                    # scalar int32
    kp = kpos_ref[0]                                       # [bk]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = (kp >= 0) & (kp <= qp)
    if window:
        mask = mask & (qp - kp < window)
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(mask[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = alpha * l_ref[:, 0] + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new

    @pl.when(j == nk - 1)
    def _done():
        l = l_ref[:, 0]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_gqa(q, k, v, q_pos, k_pos, *, window: int = 0, block_k: int = 128,
               interpret: bool = True):
    """q: [B, Hq, D]; k, v: [B, Hkv, S, D]; q_pos: [B]; k_pos: [B, S]."""
    b, hq, d = q.shape
    hkv, s_len = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = d ** -0.5

    bk = min(block_k, s_len)
    s_p = ((s_len + bk - 1) // bk) * bk
    if s_p != s_len:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, s_p - s_len), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, s_p - s_len), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, s_p - s_len)), constant_values=-1)
    nk = s_p // bk

    qg = q.reshape(b, hkv, group, d)
    qp2 = q_pos.reshape(b, 1).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, window=window, nk=nk, scale=scale),
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h, j: (b_, 0)),               # q_pos
            pl.BlockSpec((1, bk), lambda b_, h, j: (b_, j)),              # k_pos
            pl.BlockSpec((1, 1, group, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, j: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, j: (b_, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda b_, h, j: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp2, k_pos, qg, k, v)
    return out.reshape(b, hq, d)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_gqa(q, k, v, q_pos, k_pos, page_table, *, window: int = 0,
                     interpret: bool = True):
    """Single-token GQA decode over ONE paged KV stream.

    q: [B, Hq, D]; k, v: [NB, Hkv, bs, D] block arena; k_pos: [NB, bs];
    page_table: [B, NP] int32 (NULL-block padded rows).  The partial
    kernel's output is already l-normalized, and with a single key
    stream there is nothing to merge — this is the whole decode.  Used
    when a request's entire KV (no prefix/suffix split) lives in the
    block arena; the cascade path merges two partials instead.
    """
    from repro.kernels.shared_prefix import paged_decode_gqa_partial
    out, _, _ = paged_decode_gqa_partial(q, k, v, q_pos, k_pos, page_table,
                                         window=window, interpret=interpret)
    return out.astype(q.dtype)
