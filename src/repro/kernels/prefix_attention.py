"""Pallas TPU kernel: masked flash attention over a (prefix-)KV cache.

This is the compute hot-spot of SubGCache: after the representative
subgraph's KV prefix is cached, each member query's suffix tokens attend
over [cached prefix ++ fresh suffix KV].  Masking is purely positional
(slot position arrays), which also covers plain causal prefill and
sliding-window attention with the same kernel.

Tiling: grid (B, Hq, nq, nk) with the KV dimension minor, streaming KV
HBM->VMEM in (block_k, head_dim) tiles; online-softmax state (m, l, acc)
lives in VMEM scratch and persists across the nk loop.  MXU-relevant dims
(block_q, block_k, head_dim) are 128-multiples for the TPU target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, causal: bool, window: int, nk: int,
            scale: float):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, d]
    qp = qpos_ref[0]                                     # [bq] int32
    kp = kpos_ref[0]                                     # [bk] int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = kp[None, :] >= 0
    if causal:
        mask = mask & (kp[None, :] <= qp[:, None])
    if window:
        mask = mask & (qp[:, None] - kp[None, :] < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                                 # [bq]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)                          # kill exp(NEG_INF-m)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[:, 0] + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(j == nk - 1)
    def _done():
        l = l_ref[:, 0]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def prefix_attention(q, k, v, q_pos, k_pos, *, causal: bool = True,
                     window: int = 0, block_q: int = 128, block_k: int = 128,
                     interpret: bool = True):
    """q: [B,Hq,Tq,D]; k,v: [B,Hkv,S,D]; q_pos: [B,Tq]; k_pos: [B,S]."""
    b, hq, tq, d = q.shape
    hkv, s_len = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = d ** -0.5

    bq = min(block_q, tq)
    bk = min(block_k, s_len)
    # pad to block multiples; padded kv slots get pos -1 (masked),
    # padded q rows are sliced off below.
    tq_p = ((tq + bq - 1) // bq) * bq
    s_p = ((s_len + bk - 1) // bk) * bk
    if tq_p != tq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, tq_p - tq), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, tq_p - tq)), constant_values=0)
    if s_p != s_len:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, s_p - s_len), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, s_p - s_len), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, s_p - s_len)), constant_values=-1)

    nq, nk = tq_p // bq, s_p // bk
    grid = (b, hq, nq, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, window=window, nk=nk,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq), lambda b_, h, i, j: (b_, i)),          # q_pos
            pl.BlockSpec((1, bk), lambda b_, h, i, j: (b_, j)),          # k_pos
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),     # acc
            pltpu.VMEM((bq, 1), jnp.float32),     # m
            pltpu.VMEM((bq, 1), jnp.float32),     # l
        ],
        interpret=interpret,
    )(q_pos, k_pos, q, k, v)
    return out[:, :, :tq, :]
