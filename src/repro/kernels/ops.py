"""Public jit'd wrappers for every Pallas kernel.

``interpret`` defaults to True off-TPU (CPU validation per the repo's
target/runtime split) and False on real TPU backends.
"""
from __future__ import annotations

import jax

from repro.kernels import decode_gqa as _decode
from repro.kernels import fused_cascade as _fused
from repro.kernels import prefix_attention as _prefix
from repro.kernels import ref as _ref
from repro.kernels import rglru_scan as _rglru
from repro.kernels import shared_prefix as _shared
from repro.kernels import ssm_scan as _ssm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def prefix_attention(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                     block_q=128, block_k=128):
    return _prefix.prefix_attention(
        q, k, v, q_pos, k_pos, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret())


def attention_partial(q, k, v, q_pos, k_pos, *, causal=True,
                      window=0, block_q=128, block_k=128):
    """Partial (online-softmax) attention; KV batch may be 1 (shared
    prefix, read once per kv-head group) or the query batch.  Paged
    multi-prefix batches use ``paged_attention_partial`` instead."""
    return _shared.attention_partial(
        q, k, v, q_pos, k_pos, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret())


def decode_gqa_partial(q, k, v, q_pos, k_pos, *, window=0, block_k=128):
    """Single-token decode attention in partial form (decode-shaped
    [group, d] q tiles; KV batch may be 1 = shared prefix).  Paged
    multi-prefix decode uses ``paged_decode_gqa_partial`` instead."""
    return _shared.decode_gqa_partial(q, k, v, q_pos, k_pos,
                                      window=window, block_k=block_k,
                                      interpret=_interpret())


def paged_attention_partial(q, k, v, q_pos, k_pos, page_table, *,
                            causal=False, window=0, block_q=128):
    """Partial attention over a paged KV arena [NB, Hkv, bs, D]: the
    scalar-prefetched ``page_table`` [B, NP] steers one-block-per-step
    DMA (DESIGN.md §8); no gather is materialized."""
    return _shared.paged_attention_partial(
        q, k, v, q_pos, k_pos, page_table, causal=causal, window=window,
        block_q=block_q, interpret=_interpret())


def paged_decode_gqa_partial(q, k, v, q_pos, k_pos, page_table, *,
                             window=0):
    """Single-token decode partial over a paged KV arena (decode-shaped
    [group, d] q tiles; the KV loop walks ``page_table`` [B, NP])."""
    return _shared.paged_decode_gqa_partial(
        q, k, v, q_pos, k_pos, page_table, window=window,
        interpret=_interpret())


def paged_decode_gqa(q, k, v, q_pos, k_pos, page_table, *, window=0):
    """Normalized single-stream paged decode (see decode_gqa.py)."""
    return _decode.paged_decode_gqa(q, k, v, q_pos, k_pos, page_table,
                                    window=window, interpret=_interpret())


def fused_paged_attention(q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos,
                          prefix_table, suffix_table, k_scale=None,
                          v_scale=None, *, causal=True, window=0,
                          block_q=128):
    """Fused single-pass cascade prefill: ONE kernel walks the
    concatenated prefix-chain + suffix page tables, carrying the
    (o, m, l) accumulator in VMEM across every segment; int8 prefix
    tiles dequantize in-register when scales are passed (DESIGN.md
    §11).  Replaces per-segment ``paged_attention_partial`` launches
    plus the LSE fold."""
    return _fused.fused_paged_attention(
        q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos, prefix_table,
        suffix_table, k_scale, v_scale, causal=causal, window=window,
        block_q=block_q, interpret=_interpret())


def fused_paged_decode_gqa(q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos,
                           prefix_table, suffix_table, k_scale=None,
                           v_scale=None, *, window=0):
    """Fused single-pass cascade decode (decode-shaped [group, d] q
    tiles over the concatenated page walk); see
    ``fused_paged_attention``."""
    return _fused.fused_paged_decode_gqa(
        q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos, prefix_table,
        suffix_table, k_scale, v_scale, window=window,
        interpret=_interpret())


def fold_partials(partials, *, block_q=128):
    """Associative N-way LSE fold over disjoint key sets: the dense
    prefix CHAIN cascade (one partial per chain segment + the suffix
    partial, DESIGN.md §10).  The paged serving path folds in-kernel
    now (``fused_paged_*``), so the pairwise Pallas merge kernel is
    gone; this left-folds ``kernels.ref.merge_partials_ref`` — jnp,
    jit-safe, and the canonical evaluation order shared with
    ``fold_partials_ref``.  ``block_q`` is accepted for API
    compatibility and ignored."""
    del block_q
    assert partials, "need at least one partial"
    o, m, l = partials[0]
    for o2, m2, l2 in partials[1:]:
        o, m, l = _ref.merge_partials_ref(o, m, l, o2, m2, l2)
    return o, m, l


def decode_gqa(q, k, v, q_pos, k_pos, *, window=0, block_k=128):
    return _decode.decode_gqa(q, k, v, q_pos, k_pos, window=window,
                              block_k=block_k, interpret=_interpret())


def ssm_scan(x, dt, B, C, A, h0=None, *, block_d=256, block_t=256):
    return _ssm.ssm_scan(x, dt, B, C, A, h0, block_d=block_d,
                         block_t=block_t, interpret=_interpret())


def rglru_scan(x, a_log, h0=None, *, block_w=512, block_t=256):
    return _rglru.rglru_scan(x, a_log, h0, block_w=block_w, block_t=block_t,
                             interpret=_interpret())
