"""Public jit'd wrappers for every Pallas kernel.

``interpret`` defaults to True off-TPU (CPU validation per the repo's
target/runtime split) and False on real TPU backends.

Mesh-aware paged serving (DESIGN.md §13): after ``configure_mesh``
installs a device mesh with a >1 'model' axis, the paged/fused
wrappers route through ``shard_map`` whenever the call's kv-head count
divides the axis — each device walks its own HEAD-slice of the arena
(kernel-facing layout is head-major ``[NB, Hkv, bs, D]``; the shard
axis is dim 1).  Attention never reduces across heads, so the sharded
launch needs no collectives and stays bitwise identical to the
single-device kernel.  Calls whose head count does not divide the mesh
(or made before/without ``configure_mesh``) take the plain path
unchanged; a Dh-sharded arena also takes the plain path and lets GSPMD
insert the contraction collectives itself (``distributed/
kv_sharding.py``).  ``shard_map`` runs with ``check_rep=False``:
``pallas_call`` carries no replication rule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import decode_gqa as _decode
from repro.kernels import fused_cascade as _fused
from repro.kernels import prefix_attention as _prefix
from repro.kernels import ref as _ref
from repro.kernels import rglru_scan as _rglru
from repro.kernels import shared_prefix as _shared
from repro.kernels import ssm_scan as _ssm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# device mesh the paged/fused wrappers shard over (None = single device)
_MESH = None


def configure_mesh(mesh) -> None:
    """Install (``mesh=None``: clear) the mesh for head-parallel paged
    serving.  Call BEFORE an engine builds its jitted serving functions
    — those traces are lru-cached and pin whichever path was active."""
    global _MESH
    _MESH = mesh


def _model_shards(num_kv_heads: int) -> int:
    """The 'model'-axis size when the head-parallel shard_map path
    engages for a call with ``num_kv_heads`` kv heads, else 0."""
    m = _MESH
    if m is None or "model" not in m.axis_names:
        return 0
    nm = int(m.shape["model"])
    if nm <= 1 or num_kv_heads % nm:
        return 0
    return nm


# head-major shard specs: [B|NB, H, ...] arrays split dim 1
_H4 = P(None, "model", None, None)   # q prefill / k / v / out
_H3 = P(None, "model", None)         # decode q & out / prefill m & l
_H2 = P(None, "model")               # decode m & l / quant scales
_R = P()                             # tables, positions — replicated


def _sharded(fn, in_specs, out_specs):
    return shard_map(fn, mesh=_MESH, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def prefix_attention(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                     block_q=128, block_k=128):
    return _prefix.prefix_attention(
        q, k, v, q_pos, k_pos, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret())


def attention_partial(q, k, v, q_pos, k_pos, *, causal=True,
                      window=0, block_q=128, block_k=128):
    """Partial (online-softmax) attention; KV batch may be 1 (shared
    prefix, read once per kv-head group) or the query batch.  Paged
    multi-prefix batches use ``paged_attention_partial`` instead."""
    return _shared.attention_partial(
        q, k, v, q_pos, k_pos, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret())


def decode_gqa_partial(q, k, v, q_pos, k_pos, *, window=0, block_k=128):
    """Single-token decode attention in partial form (decode-shaped
    [group, d] q tiles; KV batch may be 1 = shared prefix).  Paged
    multi-prefix decode uses ``paged_decode_gqa_partial`` instead."""
    return _shared.decode_gqa_partial(q, k, v, q_pos, k_pos,
                                      window=window, block_k=block_k,
                                      interpret=_interpret())


def paged_attention_partial(q, k, v, q_pos, k_pos, page_table, *,
                            causal=False, window=0, block_q=128):
    """Partial attention over a paged KV arena [NB, Hkv, bs, D]: the
    scalar-prefetched ``page_table`` [B, NP] steers one-block-per-step
    DMA (DESIGN.md §8); no gather is materialized.  Head-parallel over
    a configured mesh (module docstring)."""
    def call(q_, k_, v_, qp, kp, pt):
        return _shared.paged_attention_partial(
            q_, k_, v_, qp, kp, pt, causal=causal, window=window,
            block_q=block_q, interpret=_interpret())
    if _model_shards(k.shape[1]):
        call = _sharded(call, (_H4, _H4, _H4, _R, _R, _R),
                        (_H4, _H3, _H3))
    return call(q, k, v, q_pos, k_pos, page_table)


def paged_decode_gqa_partial(q, k, v, q_pos, k_pos, page_table, *,
                             window=0):
    """Single-token decode partial over a paged KV arena (decode-shaped
    [group, d] q tiles; the KV loop walks ``page_table`` [B, NP]).
    Head-parallel over a configured mesh (module docstring)."""
    def call(q_, k_, v_, qp, kp, pt):
        return _shared.paged_decode_gqa_partial(
            q_, k_, v_, qp, kp, pt, window=window, interpret=_interpret())
    if _model_shards(k.shape[1]):
        call = _sharded(call, (_H3, _H4, _H4, _R, _R, _R),
                        (_H3, _H2, _H2))
    return call(q, k, v, q_pos, k_pos, page_table)


def paged_decode_gqa(q, k, v, q_pos, k_pos, page_table, *, window=0):
    """Normalized single-stream paged decode (see decode_gqa.py).
    Head-parallel over a configured mesh (module docstring)."""
    def call(q_, k_, v_, qp, kp, pt):
        return _decode.paged_decode_gqa(q_, k_, v_, qp, kp, pt,
                                        window=window,
                                        interpret=_interpret())
    if _model_shards(k.shape[1]):
        call = _sharded(call, (_H3, _H4, _H4, _R, _R, _R), _H3)
    return call(q, k, v, q_pos, k_pos, page_table)


def fused_paged_attention(q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos,
                          prefix_table, suffix_table, k_scale=None,
                          v_scale=None, *, causal=True, window=0,
                          block_q=128, rope_theta=None, p_off=None,
                          p_skip=None, prefix_causal=False):
    """Fused single-pass cascade prefill: ONE kernel walks the
    concatenated prefix-chain + suffix page tables, carrying the
    (o, m, l) accumulator in VMEM across every segment; int8 prefix
    tiles dequantize in-register when scales are passed (DESIGN.md
    §11).  Replaces per-segment ``paged_attention_partial`` launches
    plus the LSE fold.  ``rope_theta`` turns on canonical-K read-time
    rotation; ``p_off``/``p_skip`` [Bp, NPP] carry the per-prefix-block
    composition offset/skip tables (DESIGN.md §14) and ride replicated
    like the page tables.  Head-parallel over a configured mesh (module
    docstring); int8 scales [NBp, Hkv] shard on their head dim."""
    if p_off is None:
        p_off = jnp.zeros(prefix_table.shape, jnp.int32)
    if p_skip is None:
        p_skip = jnp.zeros(prefix_table.shape, jnp.int32)

    def call(q_, pk_, pv_, sk_, sv_, qp, pkp, skp, pt, st, poff, pskip,
             *scales):
        ks, vs = scales if scales else (None, None)
        return _fused.fused_paged_attention(
            q_, pk_, pv_, sk_, sv_, qp, pkp, skp, pt, st, ks, vs,
            poff, pskip, causal=causal, window=window, block_q=block_q,
            rope_theta=rope_theta, prefix_causal=prefix_causal,
            interpret=_interpret())
    args = (q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos,
            prefix_table, suffix_table, p_off, p_skip)
    specs = (_H4, _H4, _H4, _H4, _H4, _R, _R, _R, _R, _R, _R, _R)
    if k_scale is not None:
        args += (k_scale, v_scale)
        specs += (_H2, _H2)
    if _model_shards(pk.shape[1]):
        call = _sharded(call, specs, _H4)
    return call(*args)


def fused_paged_decode_gqa(q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos,
                           prefix_table, suffix_table, k_scale=None,
                           v_scale=None, *, window=0, rope_theta=None,
                           p_off=None, p_skip=None):
    """Fused single-pass cascade decode (decode-shaped [group, d] q
    tiles over the concatenated page walk); see
    ``fused_paged_attention``."""
    if p_off is None:
        p_off = jnp.zeros(prefix_table.shape, jnp.int32)
    if p_skip is None:
        p_skip = jnp.zeros(prefix_table.shape, jnp.int32)

    def call(q_, pk_, pv_, sk_, sv_, qp, pkp, skp, pt, st, poff, pskip,
             *scales):
        ks, vs = scales if scales else (None, None)
        return _fused.fused_paged_decode_gqa(
            q_, pk_, pv_, sk_, sv_, qp, pkp, skp, pt, st, ks, vs,
            poff, pskip, window=window, rope_theta=rope_theta,
            interpret=_interpret())
    args = (q, pk, pv, sk, sv, q_pos, p_kpos, s_kpos,
            prefix_table, suffix_table, p_off, p_skip)
    specs = (_H3, _H4, _H4, _H4, _H4, _R, _R, _R, _R, _R, _R, _R)
    if k_scale is not None:
        args += (k_scale, v_scale)
        specs += (_H2, _H2)
    if _model_shards(pk.shape[1]):
        call = _sharded(call, specs, _H3)
    return call(*args)


def fold_partials(partials, *, block_q=128):
    """Associative N-way LSE fold over disjoint key sets: the dense
    prefix CHAIN cascade (one partial per chain segment + the suffix
    partial, DESIGN.md §10).  The paged serving path folds in-kernel
    now (``fused_paged_*``), so the pairwise Pallas merge kernel is
    gone; this left-folds ``kernels.ref.merge_partials_ref`` — jnp,
    jit-safe, and the canonical evaluation order shared with
    ``fold_partials_ref``.  ``block_q`` is accepted for API
    compatibility and ignored."""
    del block_q
    assert partials, "need at least one partial"
    o, m, l = partials[0]
    for o2, m2, l2 in partials[1:]:
        o, m, l = _ref.merge_partials_ref(o, m, l, o2, m2, l2)
    return o, m, l


def decode_gqa(q, k, v, q_pos, k_pos, *, window=0, block_k=128):
    return _decode.decode_gqa(q, k, v, q_pos, k_pos, window=window,
                              block_k=block_k, interpret=_interpret())


def ssm_scan(x, dt, B, C, A, h0=None, *, block_d=256, block_t=256):
    return _ssm.ssm_scan(x, dt, B, C, A, h0, block_d=block_d,
                         block_t=block_t, interpret=_interpret())


def rglru_scan(x, a_log, h0=None, *, block_w=512, block_t=256):
    return _rglru.rglru_scan(x, a_log, h0, block_w=block_w, block_t=block_t,
                             interpret=_interpret())
