"""Architecture registry + assigned input shapes + input_specs().

``--arch <id>`` resolution for every launcher, plus the four assigned
input shapes as ShapeDtypeStruct factories (no device allocation — the
dry-run lowers against these).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig

_MODULES = {
    "tinyllama-1.1b": "tinyllama_1_1b",
    "internlm2-20b": "internlm2_20b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "mixtral-8x22b": "mixtral_8x22b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "arctic-480b": "arctic_480b",
    "command-r-35b": "command_r_35b",
    "mistral-large-123b": "mistral_large_123b",
    "llama32-3b": "llama32_3b",          # the paper's own backbone scale
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "llama32-3b")


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.reduced()


def list_archs():
    return sorted(_MODULES)


# ----------------------------------------------------------------------
# shape applicability (DESIGN.md §4)
# ----------------------------------------------------------------------
def shape_supported(cfg: ModelConfig, shape: str,
                    swa_override: int = 0) -> tuple:
    """Returns (supported: bool, note: str)."""
    s = INPUT_SHAPES[shape]
    if shape == "long_500k":
        if cfg.supports_long_context:
            return True, "native sub-quadratic decode state"
        if swa_override:
            return True, f"swa-override window={swa_override}"
        return False, ("pure full attention: 500k decode KV unbounded; "
                       "run with --swa-override (DESIGN.md §4)")
    return True, ""


def apply_swa_override(cfg: ModelConfig, window: int) -> ModelConfig:
    """Give a dense arch a sliding-window serving mode (beyond-paper knob
    that lets every assigned arch lower the long_500k shape)."""
    return cfg.replace(sliding_window=window)


# ----------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; weak-type-correct, shardable)
# ----------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: str,
                cache_capacity: Optional[int] = None) -> dict:
    """ShapeDtypeStructs for every model input of (arch x shape).

    train  -> {tokens, labels, mask (+ enc_frames | img_embeds)}
    prefill-> {tokens, positions, valid (+ enc_frames | img_embeds)}
    decode -> {token, positions, cache}  (cache capacity = seq_len bounded
              by window for SWA/local archs; recurrent state for SSM)
    """
    s = INPUT_SHAPES[shape]
    b, t = s.global_batch, s.seq_len
    i32, f32 = jnp.int32, jnp.float32
    from repro.models.layers import dtype_of
    dt = dtype_of(cfg.dtype)

    def modality(batch):
        out = {}
        if cfg.is_encdec:
            out["enc_frames"] = _sds((batch, cfg.encoder_seq,
                                      cfg.frontend_dim), dt)
        elif cfg.num_image_tokens:
            out["img_embeds"] = _sds((batch, cfg.num_image_tokens,
                                      cfg.frontend_dim), dt)
        return out

    if s.kind == "train":
        return {"tokens": _sds((b, t), i32),
                "labels": _sds((b, t), i32),
                "mask": _sds((b, t), f32), **modality(b)}

    if s.kind == "prefill":
        return {"tokens": _sds((b, t), i32),
                "positions": _sds((b, t), i32),
                "valid": _sds((b, t), jnp.bool_), **modality(b)}

    # decode: one token against a cache of capacity ~ seq_len
    cap = cache_capacity or t
    enc_len = cfg.encoder_seq if cfg.is_encdec else cfg.num_image_tokens
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, b, cap, enc_len=enc_len))
    return {"token": _sds((b, 1), i32),
            "positions": _sds((b, 1), i32),
            "cache": cache}


def model_flops(cfg: ModelConfig, shape: str) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE); D = tokens
    processed by the step (decode: batch tokens; train: fwd+bwd -> 6ND
    already accounts for that with N params and D tokens)."""
    s = INPUT_SHAPES[shape]
    n_active = cfg.active_param_count()
    if s.kind == "train":
        return 6.0 * n_active * s.global_batch * s.seq_len
    if s.kind == "prefill":
        return 2.0 * n_active * s.global_batch * s.seq_len
    return 2.0 * n_active * s.global_batch      # decode: 1 token / seq
