"""internlm2-20b — dense GQA [arXiv:2403.17297].

48L, d_model 6144, 48 heads (GQA kv=8), d_ff 16384, vocab 92544.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92544, head_dim=128,
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="internlm2-20b-smoke", num_layers=2, d_model=384,
        num_heads=6, num_kv_heads=2, head_dim=64, d_ff=768,
        vocab_size=512, dtype="float32")
