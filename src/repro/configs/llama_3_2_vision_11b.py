"""llama-3.2-vision-11b — VLM with periodic cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

40L decoder, d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 128256;
every 5th layer (8 total) cross-attends to vision tokens.  The ViT vision
encoder + projector frontend is stubbed per the assignment carve-out:
``input_specs`` supplies precomputed patch embeddings [B, 1600, 1280].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    cross_attn_period=5, cross_attn_offset=3,
    num_image_tokens=1600, frontend_dim=1280,
    rope_theta=500_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llama-3.2-vision-11b-smoke", num_layers=5, d_model=256,
        num_heads=8, num_kv_heads=2, head_dim=32, d_ff=512,
        vocab_size=512, num_image_tokens=16, frontend_dim=64,
        dtype="float32")
