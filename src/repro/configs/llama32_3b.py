"""llama32-3b — the paper's primary LLM backbone scale (Llama-3.2-3B).

28L, d_model 3072, 24 heads (GQA kv=8), d_ff 8192, vocab 128256.
Included as the paper's own architecture next to the 10 assigned ones.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama32-3b", family="dense",
    num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, head_dim=128,
    rope_theta=500_000.0, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llama32-3b-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, head_dim=32, d_ff=512,
        vocab_size=512, dtype="float32")
