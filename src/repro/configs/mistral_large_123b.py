"""mistral-large-123b — dense GQA [hf:mistralai/Mistral-Large-Instruct-2407].

88L, d_model 12288, 96 heads (GQA kv=8), d_ff 28672, vocab 32768.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=28672, vocab_size=32768, head_dim=128,
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="mistral-large-123b-smoke", num_layers=2, d_model=384,
        num_heads=6, num_kv_heads=2, head_dim=64, d_ff=896,
        vocab_size=512, dtype="float32")
