"""command-r-35b — dense GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

40L, d_model 8192, 64 heads (GQA kv=8), d_ff 22528, vocab 256000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22528, vocab_size=256000, head_dim=128,
    use_qkv_bias=False, rope_theta=8_000_000.0,
    tie_embeddings=True,        # command-r ties input/output embeddings
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="command-r-35b-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, head_dim=32, d_ff=704,
        vocab_size=512, dtype="float32")
