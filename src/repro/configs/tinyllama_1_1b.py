"""tinyllama-1.1b — dense GQA Llama-2-arch small [arXiv:2401.02385].

22L, d_model 2048, 32 heads (GQA kv=4), d_ff 5632, vocab 32000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=5632, vocab_size=32000, head_dim=64,
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    """2L / d_model<=512 smoke variant of the same family."""
    return CONFIG.replace(
        name="tinyllama-1.1b-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, head_dim=32, d_ff=512,
        vocab_size=512, dtype="float32")
