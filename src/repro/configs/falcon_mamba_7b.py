"""falcon-mamba-7b — attention-free Mamba-1 SSM [arXiv:2410.05355].

64L, d_model 4096 (d_inner 8192, expand 2), ssm_state 16, vocab 65024.
O(1) decode state -> runs long_500k natively.  SubGCache's KV reuse is
adapted as SSM prefix-state reuse (DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="falcon-mamba-7b-smoke", num_layers=2, d_model=256,
        vocab_size=512, ssm_state=8, dtype="float32")
