"""seamless-m4t-large-v2 — encoder-decoder audio backbone [arXiv:2308.11596].

24 encoder + 24 decoder layers, d_model 1024, 16 heads (kv=16 -> MHA),
d_ff 8192, vocab 256206.  The speech frontend (mel-spectrogram + conformer
feature extractor) is stubbed per the assignment carve-out:
``input_specs`` supplies precomputed frame embeddings [B, encoder_seq,
frontend_dim]; this config implements the transformer backbone that
consumes them.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, num_encoder_layers=24,
    d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206, head_dim=64,
    encoder_seq=1536, frontend_dim=1024,
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="seamless-m4t-large-v2-smoke", num_layers=2,
        num_encoder_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=32, d_ff=256, vocab_size=512, encoder_seq=24,
        frontend_dim=64, dtype="float32")
