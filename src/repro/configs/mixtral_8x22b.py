"""mixtral-8x22b — MoE 8 experts top-2 with sliding-window attention
[arXiv:2401.04088].

56L, d_model 6144, 48 heads (GQA kv=8), expert d_ff 16384, vocab 32768.
SWA window 4096 (per the assignment card) bounds the decode KV cache, so
this arch runs the long_500k shape natively.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    num_experts=8, num_experts_per_tok=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="mixtral-8x22b-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, head_dim=32, d_ff=512,
        vocab_size=512, num_experts=4, num_experts_per_tok=2,
        sliding_window=64, dtype="float32")
