"""arctic-480b — MoE 128 experts top-2 with always-on dense residual MLP
[hf:Snowflake/snowflake-arctic-base].

35L, d_model 7168, 56 heads (GQA kv=8), expert d_ff 4864 + dense residual
d_ff 4864, vocab 32000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000, head_dim=128,
    num_experts=128, num_experts_per_tok=2,
    dense_residual_d_ff=4864,
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="arctic-480b-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=512, num_experts=4, num_experts_per_tok=2,
        dense_residual_d_ff=256, dtype="float32")
