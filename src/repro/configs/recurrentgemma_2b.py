"""recurrentgemma-2b — hybrid RG-LRU + local attention, 1:2 ratio
[arXiv:2402.19427].

26L, d_model 2560, 10 heads (MQA kv=1, head_dim 256), d_ff 7680,
vocab 256000; block pattern (rglru, rglru, local-attn), local window 2048.
Bounded decode state (recurrent + windowed KV) -> runs long_500k natively.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    block_pattern=("rglru", "rglru", "attn_local"),
    local_window=2048, lru_width=2560, ssm_conv=4,
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="recurrentgemma-2b-smoke", num_layers=3, d_model=256,
        num_heads=4, num_kv_heads=1, head_dim=64, d_ff=512,
        vocab_size=512, local_window=32, lru_width=256, dtype="float32")
