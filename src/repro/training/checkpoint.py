"""npz checkpointing with flattened key paths (sharding-agnostic).

Arrays are pulled to host (fully replicated view) and restored with the
caller's sharding applied afterwards; metadata rides along as JSON.
"""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(params: Any) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[key] = np.asarray(leaf)
    return out


def _base(path: str) -> str:
    return path[:-4] if path.endswith(".npz") else path


def save(path: str, params: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(params)
    base = _base(path)
    np.savez(base + ".npz", **flat)
    with open(base + ".meta.json", "w") as f:
        json.dump({"metadata": metadata or {},
                   "keys": sorted(flat.keys())}, f, indent=2)


def load(path: str, like: Any) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (a params pytree or
    eval_shape thereof).  Returns (params, metadata)."""
    base = _base(path)
    data = np.load(base + ".npz")
    with open(base + ".meta.json") as f:
        meta = json.load(f)["metadata"]
    flat = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    leaves = []
    for kp, proto in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        arr = data[key]
        assert arr.shape == tuple(proto.shape), (key, arr.shape, proto.shape)
        leaves.append(jnp.asarray(arr, dtype=proto.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta
