"""Training loop substrate: jitted train step, grad accumulation, eval."""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.training import optimizer as opt


def make_train_step(cfg: ModelConfig, opt_cfg: opt.AdamWConfig,
                    trainable: Optional[Callable[[str], bool]] = None,
                    grad_accum: int = 1):
    """Returns jitted (params, opt_state, batch) -> (params, opt_state, metrics).

    With ``grad_accum > 1`` the batch's leading dim is split into
    microbatches consumed by a scan (bounds activation memory for the
    ≥100B training shapes)."""

    def loss_fn(params, batch):
        return M.train_loss(params, cfg, batch)

    def step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def accum(carry, mb):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (loss_acc + l / grad_accum,
                        jax.tree.map(lambda a, b: a + b / grad_accum,
                                     grad_acc, g)), None

            zero = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                params)
            (loss, grads), _ = jax.lax.scan(accum, (0.0, zero), micro)
        params, opt_state, metrics = opt.apply_updates(
            params, grads, opt_state, opt_cfg, trainable)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return jax.jit(step, donate_argnums=(0, 1))


def train(params: Any, cfg: ModelConfig, opt_cfg: opt.AdamWConfig,
          batches: Iterable[dict], num_steps: int,
          trainable: Optional[Callable[[str], bool]] = None,
          log_every: int = 20, log_fn=print):
    """Simple host loop; returns (params, history)."""
    state = opt.init_state(params)
    step_fn = make_train_step(cfg, opt_cfg, trainable)
    history = []
    t0 = time.perf_counter()
    it = iter(batches)
    for i in range(num_steps):
        batch = next(it)
        params, state, metrics = step_fn(params, state, batch)
        if (i + 1) % log_every == 0 or i == 0:
            loss = float(metrics["loss"])
            history.append({"step": i + 1, "loss": loss,
                            "grad_norm": float(metrics["grad_norm"])})
            log_fn(f"step {i+1:4d}  loss {loss:.4f}  "
                   f"gnorm {float(metrics['grad_norm']):.2f}  "
                   f"({time.perf_counter()-t0:.1f}s)")
    return params, history
