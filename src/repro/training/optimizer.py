"""AdamW with decoupled weight decay and global-norm clipping (pure JAX).

Matches the paper's training setup (App. A.2: AdamW, lr 1e-5, wd 0.05)
without external optimizer deps.  Optimizer state mirrors the param tree,
so the distributed layer shards it with the same partition rules as the
params (ZeRO-style when params are 2D-sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 1e-5
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.05
    clip_norm: float = 1.0
    warmup_steps: int = 0


def init_state(params: Any) -> dict:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def _global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    lr = jnp.asarray(cfg.learning_rate, jnp.float32)
    if cfg.warmup_steps > 0:
        warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
        lr = lr * warm
    return lr


def apply_updates(params: Any, grads: Any, state: dict, cfg: AdamWConfig,
                  trainable: Optional[Callable[[str], bool]] = None):
    """Returns (new_params, new_state, metrics).

    ``trainable``: optional predicate on the flattened param path; frozen
    params (e.g. the frozen LLM backbone in G-Retriever training) get
    zero updates but keep their state entries.
    """
    count = state["count"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) \
        if cfg.clip_norm else 1.0
    lr = schedule(cfg, state["count"])

    flat_params = jax.tree_util.tree_flatten_with_path(params)[0]
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
             for kp, _ in flat_params]
    is_trainable = [True if trainable is None else trainable(p) for p in paths]

    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(state["m"])
    v_leaves = treedef.flatten_up_to(state["v"])

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    new_p, new_m, new_v = [], [], []
    for p, g, m, v, tr in zip(p_leaves, g_leaves, m_leaves, v_leaves,
                              is_trainable):
        gf = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        upd = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * upd
        if not tr:
            p2, m2, v2 = p.astype(jnp.float32), m, v
        new_p.append(p2.astype(p.dtype))
        new_m.append(m2)
        new_v.append(v2)

    params2 = jax.tree_util.tree_unflatten(treedef, new_p)
    state2 = {"m": jax.tree_util.tree_unflatten(treedef, new_m),
              "v": jax.tree_util.tree_unflatten(treedef, new_v),
              "count": count}
    return params2, state2, {"grad_norm": gnorm, "lr": lr}
