"""Activation-sharding hints: mesh-agnostic model code, mesh-aware launchers.

Model code calls ``constrain(x, tag)`` at propagation-hostile points
(scatter-fed buffers, scan boundaries).  By default it is the identity;
a launcher installs a hint function (tag, array) -> array that applies
``with_sharding_constraint`` with the right NamedSharding.  GSPMD's
propagation gives up at scatters from freshly-created zeros (the MoE
dispatch buffer) — without the hint it replicates the batch dim and
multiplies expert-FFN flops by the model-axis size.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Callable, Optional

_active: contextvars.ContextVar[Optional[Callable]] = \
    contextvars.ContextVar("repro_shard_hints", default=None)


def constrain(x, tag: str):
    fn = _active.get()
    return fn(x, tag) if fn is not None else x


@contextlib.contextmanager
def use_hints(fn: Callable):
    token = _active.set(fn)
    try:
        yield
    finally:
        _active.reset(token)


def make_batch_hint(mesh, cfg=None, *, seq_shard_boundary: bool = False):
    """Standard hint: leading dim = batch over the data axes; MoE
    dispatch buffers additionally shard the expert dim over 'model'
    when expert-parallel.

    ``seq_shard_boundary``: Megatron-sequence-parallel style — layer
    boundary activations [B, T, D] additionally shard T over 'model'
    (bounds remat-saved bytes; perf-iteration knob)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed import sharding as S

    b_ax = S.batch_axes(mesh)
    bspec = b_ax[0] if len(b_ax) == 1 else tuple(b_ax)

    def hint(x, tag):
        ndim = x.ndim
        if tag == "layer_boundary":
            if not seq_shard_boundary or ndim != 3:
                return x
            raw = (bspec, "model", None)
        elif tag == "moe_expert_in" and cfg is not None \
                and S._moe_expert_parallel(cfg, mesh):
            raw = (bspec, "model") + (None,) * (ndim - 2)
        else:
            raw = (bspec,) + (None,) * (ndim - 1)
        spec = S.sanitize(raw, tuple(x.shape), mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return hint
