"""Sharded KV block arenas over a device mesh (DESIGN.md §13).

The paged serving stack through PR 7 is single-device: one
``KVBlockPool`` arena, one engine.  This module is the tensor-parallel
half of the replica serving subsystem — it places the per-layer
``[num_blocks, block_size, Hkv, Dh]`` arena leaves under
``NamedSharding`` so one logical engine spans the mesh's 'model' axis:

* **heads mode** — ``Hkv % model_shards == 0`` (every big zoo config:
  mixtral_8x22b 8 kv-heads, arctic_480b 8, command_r_35b 8): K/V shard
  on the kv-head dim, quantization scales ``[NB, Hkv]`` on their head
  dim, positions/page tables replicate.  Attention is embarrassingly
  parallel per head (softmax never crosses heads), so the Pallas
  kernel wrappers in ``kernels/ops.py`` run under ``shard_map`` with
  each device walking its own head-slice of the arena — NO collectives
  inside the kernel, which is why sharded serving is token-IDENTICAL
  to the single-device engine (same per-head reduction order,
  bitwise).
* **Dh fallback** — ``Hkv`` not divisible (small validation configs on
  a wide mesh) but ``head_dim`` is: K/V shard on the head_dim axis.
  That splits the QK contraction, so the shard_map fast path stays OFF
  (it would need in-kernel collectives and change reduction order);
  the wrappers fall through to the plain call and GSPMD partitions the
  XLA gather path, inserting the collectives itself.
* **replicate** — neither divides: full arena on every device.

The jnp oracle path (``attend_paged`` with ``impl="xla"``) needs no
wrapper in ANY mode: its arena gather happens inside jit, and GSPMD
propagates the arena's NamedSharding through it for free.

``shard_engine`` is the one-call entry: replicate the params, shard
the pool's arena(s), and install the mesh into ``kernels.ops`` —
BEFORE the engine's lru-cached jits trace, so every serving path
compiles against the sharded layout.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import sanitize
from repro.models.config import ModelConfig


def model_shards(mesh: Optional[Mesh]) -> int:
    """Size of the mesh's 'model' axis (1 when absent / no mesh)."""
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return int(mesh.shape["model"])


def kv_shard_mode(cfg: ModelConfig, mesh: Optional[Mesh]) -> str:
    """'heads' | 'dh' | 'replicate' — how this config's arenas split
    over the mesh (see module docstring)."""
    nm = model_shards(mesh)
    if nm <= 1:
        return "replicate"
    if cfg.num_kv_heads % nm == 0:
        return "heads"
    if cfg.head_dim_ % nm == 0:
        return "dh"
    return "replicate"


def arena_leaf_spec(key: str, shape, cfg: ModelConfig, mesh: Mesh) -> P:
    """PartitionSpec for one block-arena leaf in STORAGE (seq-major)
    layout: k/v ``[.., NB, bs, Hkv, Dh]``, pos ``[.., NB, bs]``,
    scales ``[.., NB, Hkv]``.  Leading scanned-group dims replicate."""
    mode = kv_shard_mode(cfg, mesh)
    ndim = len(shape)
    if key in ("k", "v"):
        if mode == "heads":
            spec = (None, None, "model", None)
        elif mode == "dh":
            spec = (None, None, None, "model")
        else:
            spec = (None,) * 4
    elif key in ("k_scale", "v_scale"):
        spec = (None, "model") if mode == "heads" else (None, None)
    else:                                   # pos (and anything unknown)
        spec = (None,) * ndim
    if len(spec) < ndim:
        spec = (None,) * (ndim - len(spec)) + tuple(spec)
    return sanitize(tuple(spec), tuple(shape), mesh)


def arena_pspecs(arena, cfg: ModelConfig, mesh: Mesh):
    """Map a block-arena pytree (main or quantized) to PartitionSpecs."""
    def spec(path, leaf):
        key = getattr(path[-1], "key", None)
        return arena_leaf_spec(key, leaf.shape, cfg, mesh)
    return jax.tree_util.tree_map_with_path(spec, arena)


def shard_arena(arena, cfg: ModelConfig, mesh: Mesh):
    """``device_put`` an arena pytree under its NamedShardings."""
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                             arena_pspecs(arena, cfg, mesh),
                             is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(arena, shardings)


def shard_pool(pool, mesh: Mesh) -> None:
    """Re-home a ``KVBlockPool``'s arena(s) onto the mesh in place.
    Host-side state (allocators, token counters) is untouched — block
    ids address full rows regardless of how a row's heads split."""
    pool.arena = shard_arena(pool.arena, pool.cfg, mesh)
    if pool.qarena is not None:
        pool.qarena = shard_arena(pool.qarena, pool.cfg, mesh)


def shard_engine(engine, mesh: Mesh) -> str:
    """Make a ``ServingEngine`` serve over ``mesh``: replicate params,
    shard the block arenas, and install the mesh into ``kernels.ops``
    so the paged/fused Pallas wrappers shard_map in heads mode.

    MUST run before the engine serves anything — the engine's jitted
    serving functions are lru-cached per shape, and a trace taken
    without the mesh pins the unsharded layout for that shape.
    Returns the shard mode actually engaged.
    """
    from repro.kernels import ops as kops
    mode = kv_shard_mode(engine.cfg, mesh)
    replicated = NamedSharding(mesh, P())
    engine.params = jax.device_put(engine.params, replicated)
    shard_pool(engine.block_pool, mesh)
    kops.configure_mesh(mesh)
    return mode
