"""Partition rules: param/cache pytrees -> PartitionSpecs.

Naming-based rules (leaf names are unique per role across the model zoo).
Tensor-parallel ('model' axis) shards:
  * attention q/o on heads, k/v on kv-heads,
  * FFN on d_ff,
  * MoE on the expert dim when num_experts >= mesh model size
    (arctic 128e), else inside the expert on d_ff (mixtral 8e),
  * Mamba / RG-LRU on the inner channel dim,
  * embeddings / lm head on vocab.
Training adds a ZeRO-style 'data' axis on the complementary dim so
params + AdamW moments shard over the full mesh.
Batch dims shard over ('pod','data') on the multi-pod mesh.

Scanned layer stacks carry a leading group dim -> a leading None is
prepended automatically (detected from leaf rank vs rule rank).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def sanitize(spec: tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes whose size does not divide the dim (pjit input
    shardings require divisibility; e.g. batch=1 on long_500k, or the
    256206-token seamless vocab on a 16-way model axis)."""
    out = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            out.append(None)
            continue
        if dim % _axis_size(mesh, entry) == 0:
            out.append(entry)
            continue
        # try a prefix of a composite axis tuple
        if isinstance(entry, (tuple, list)):
            kept = []
            for a in entry:
                if dim % (_axis_size(mesh, tuple(kept + [a]))) == 0:
                    kept.append(a)
            out.append(tuple(kept) if kept else None)
        else:
            out.append(None)
    return P(*out)


# perf-iteration override: None (auto) | "heads" | "seq"
KV_SHARD_OVERRIDE = None


def _moe_expert_parallel(cfg: ModelConfig, mesh: Mesh) -> bool:
    return cfg.num_experts >= mesh.shape["model"]


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ModelConfig, mesh: Mesh, zero: bool = False) -> P:
    """PartitionSpec for one param leaf addressed by its flattened path."""
    name = path[-1]
    dp = "data" if zero else None
    ndim = len(shape)

    def base() -> Optional[tuple]:
        if name in ("ln1", "ln2", "ln_cross", "final_norm", "norm",
                    "b_a", "b_i", "lambda", "dt_bias", "D",
                    "bq", "bk", "bv", "b"):
            return (None,) * ndim_base
        if name == "embed":
            return ("model", dp)
        if name == "lm_head":
            return (dp, "model")
        if name == "frontend_proj":
            return (None, None)
        # attention
        if name in ("wq", "wk", "wv"):
            return (dp, "model")
        if name == "wo":
            return ("model", dp)
        # mlp vs moe (same names, different rank)
        if name in ("w_gate", "w_up"):
            if ndim_base == 3:          # [E, D, F]
                if _moe_expert_parallel(cfg, mesh):
                    return ("model", dp, None)
                return (None, dp, "model")
            return (dp, "model")
        if name == "w_down":
            if ndim_base == 3:          # [E, F, D]
                if _moe_expert_parallel(cfg, mesh):
                    return ("model", None, dp)
                return (None, "model", dp)
            return ("model", dp)
        if name == "router":
            return (None, None)
        # mamba
        if name == "in_proj":
            return (dp, "model")
        if name == "x_proj":
            return ("model", dp)
        if name == "dt_proj":
            return (dp, "model")
        if name == "A_log":
            return ("model", None)
        if name == "out_proj":
            return ("model", dp)
        if name == "w":                 # depthwise conv [W, C]
            return (None, "model")
        # rglru
        if name in ("in_x", "in_gate"):
            return (dp, "model")
        if name in ("w_a", "w_i"):
            return (dp, "model")
        if name == "out":
            return ("model", dp)
        return (None,) * ndim_base

    # figure out the base rank by stripping a possible leading group dim:
    # rules are written for the unstacked layer shapes.
    ndim_base = ndim
    spec = base()
    if spec is not None and len(spec) < ndim:
        spec = (None,) * (ndim - len(spec)) + tuple(spec)
    if spec is None or len(spec) != ndim:
        spec = (None,) * ndim
    return sanitize(spec, shape, mesh)


def param_pspecs(cfg: ModelConfig, params_shape: Any, mesh: Mesh,
                 zero: bool = False) -> Any:
    """Map a params (or eval_shape) pytree to PartitionSpecs."""
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    treedef = jax.tree_util.tree_structure(params_shape)
    specs = []
    for kp, leaf in flat:
        path = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in kp)
        specs.append(param_spec(path, tuple(leaf.shape), cfg, mesh, zero))
    return jax.tree_util.tree_unflatten(treedef, specs)


def cache_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ModelConfig, mesh: Mesh) -> P:
    """PartitionSpec for a KV/state cache leaf.

    KV: [.., B, C, Hkv, D] (seq-major) — batch on data axes; heads on
    'model' when kv_heads >= model shards, else sequence (flash-decode
    style; GSPMD inserts the partial-softmax collectives).
    """
    name = path[-1]
    b_ax = batch_axes(mesh)
    bspec = b_ax if len(b_ax) == 1 else (b_ax,)
    nm = mesh.shape["model"]

    def base():
        if name in ("k", "v", "cross_k", "cross_v"):
            mode = KV_SHARD_OVERRIDE
            if mode is None:
                mode = ("heads" if cfg.num_kv_heads
                        and cfg.num_kv_heads >= nm else "seq")
            if mode == "heads":
                return (*bspec, None, "model", None)   # [B, C, H, D]
            return (*bspec, "model", None, None)       # seq-sharded
        if name == "pos":
            return (*bspec, None)
        if name == "conv":               # [B, W-1, C]
            return (*bspec, None, "model")
        if name == "state":
            if len(shape) >= 3 and shape[-1] == cfg.ssm_state:
                return (*bspec, "model", None)   # [B, Di, N]
            return (*bspec, "model")             # [B, W]
        return None

    spec = base()
    ndim = len(shape)
    if spec is not None and len(spec) < ndim:
        spec = (None,) * (ndim - len(spec)) + tuple(spec)
    if spec is None or len(spec) != ndim:
        spec = (None,) * ndim
    return sanitize(spec, shape, mesh)


def cache_pspecs(cfg: ModelConfig, cache_shape: Any, mesh: Mesh) -> Any:
    flat = jax.tree_util.tree_flatten_with_path(cache_shape)[0]
    treedef = jax.tree_util.tree_structure(cache_shape)
    specs = []
    for kp, leaf in flat:
        path = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in kp)
        specs.append(cache_spec(path, tuple(leaf.shape), cfg, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspecs(batch_shape: Any, mesh: Mesh) -> Any:
    """Shard every batch leaf's leading dim over the data axes."""
    b_ax = batch_axes(mesh)
    bspec = b_ax if len(b_ax) == 1 else (b_ax,)

    def spec(leaf):
        raw = (*bspec, *(None,) * (len(leaf.shape) - 1))
        return sanitize(raw, tuple(leaf.shape), mesh)

    return jax.tree.map(spec, batch_shape)


def named(mesh: Mesh, pspecs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
