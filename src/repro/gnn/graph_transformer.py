"""Graph Transformer encoder (UniMP-style) — G-Retriever's graph encoder.

Edge-list message passing with per-head attention over incoming edges
(segment-softmax), supporting edge features.  Pure JAX; graphs are small
(retrieved subgraphs), so this runs on host-side CPU during serving and
its pooled output is both the soft prompt input and SubGCache's
subgraph embedding (paper §3.2: same pretrained GNN for both roles).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_graph_transformer(key, in_dim: int, hidden: int, num_layers: int,
                           num_heads: int, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, num_layers + 1)
    layers = []
    for i in range(num_layers):
        k = jax.random.split(keys[i], 6)
        d_in = in_dim if i == 0 else hidden
        layers.append({
            "wq": dense_init(k[0], d_in, hidden, dtype),
            "wk": dense_init(k[1], d_in, hidden, dtype),
            "wv": dense_init(k[2], d_in, hidden, dtype),
            "we": dense_init(k[3], in_dim, hidden, dtype),     # edge feats
            "wo": dense_init(k[4], hidden, hidden, dtype),
            "skip": dense_init(k[5], d_in, hidden, dtype),
        })
    return {"layers": layers, "num_heads": num_heads}


def _segment_softmax(logits, segments, num_segments):
    seg_max = jax.ops.segment_max(logits, segments, num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    ex = jnp.exp(logits - seg_max[segments])
    seg_sum = jax.ops.segment_sum(ex, segments, num_segments)
    return ex / (seg_sum[segments] + 1e-9)


def apply_graph_transformer(params: dict, x: jnp.ndarray,
                            senders: jnp.ndarray, receivers: jnp.ndarray,
                            edge_feat: jnp.ndarray) -> jnp.ndarray:
    """x: [N, F]; senders/receivers: [E]; edge_feat: [E, F] -> [N, H]."""
    h = params["num_heads"]
    n = x.shape[0]
    for layer in params["layers"]:
        hidden = layer["wq"].shape[1]
        dh = hidden // h
        q = (x @ layer["wq"]).reshape(n, h, dh)
        k = (x @ layer["wk"]).reshape(n, h, dh)
        v = (x @ layer["wv"]).reshape(n, h, dh)
        e = (edge_feat @ layer["we"]).reshape(-1, h, dh)

        k_e = k[senders] + e                                  # [E, h, dh]
        v_e = v[senders] + e
        logits = jnp.sum(q[receivers] * k_e, axis=-1) / (dh ** 0.5)  # [E, h]
        alpha = jnp.stack(
            [_segment_softmax(logits[:, j], receivers, n) for j in range(h)],
            axis=1)                                           # [E, h]
        msg = alpha[..., None] * v_e                          # [E, h, dh]
        agg = jax.ops.segment_sum(msg.reshape(-1, hidden), receivers, n)
        x = jax.nn.relu(agg @ layer["wo"] + x @ layer["skip"])
    return x


def mean_pool(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(x, axis=0)
