"""Soft-prompt projector: pooled GNN embedding -> LLM soft tokens.

G-Retriever/GRAG condition the frozen LLM on the retrieved subgraph both
via the textualized prompt and a projected graph embedding prepended as
soft token(s); this is the trained component (the LLM stays frozen).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_projector(key, gnn_dim: int, d_model: int, num_soft_tokens: int = 1,
                   dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    hidden = max(gnn_dim, d_model)
    return {
        "w1": dense_init(k1, gnn_dim, hidden, dtype),
        "w2": dense_init(k2, hidden, num_soft_tokens * d_model, dtype),
        "num_soft_tokens": num_soft_tokens,
        "d_model": d_model,
    }


def apply_projector(p: dict, graph_embedding: jnp.ndarray) -> jnp.ndarray:
    """[gnn_dim] -> [num_soft_tokens, d_model]."""
    h = jax.nn.relu(graph_embedding @ p["w1"])
    out = h @ p["w2"]
    return out.reshape(int(p["num_soft_tokens"]), int(p["d_model"]))
