"""GAT encoder with edge features — GRAG's graph encoder (paper App. A.2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.gnn.graph_transformer import _segment_softmax
from repro.models.layers import dense_init


def init_gat(key, in_dim: int, hidden: int, num_layers: int, num_heads: int,
             dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, num_layers)
    layers = []
    for i in range(num_layers):
        k = jax.random.split(keys[i], 5)
        d_in = in_dim if i == 0 else hidden
        dh = hidden // num_heads
        layers.append({
            "w": dense_init(k[0], d_in, hidden, dtype),
            "we": dense_init(k[1], in_dim, hidden, dtype),
            "a_src": (jax.random.normal(k[2], (num_heads, dh)) * 0.1),
            "a_dst": (jax.random.normal(k[3], (num_heads, dh)) * 0.1),
            "a_edge": (jax.random.normal(k[4], (num_heads, dh)) * 0.1),
            "skip": dense_init(jax.random.fold_in(k[0], 7), d_in, hidden, dtype),
        })
    return {"layers": layers, "num_heads": num_heads}


def apply_gat(params: dict, x: jnp.ndarray, senders: jnp.ndarray,
              receivers: jnp.ndarray, edge_feat: jnp.ndarray) -> jnp.ndarray:
    h = params["num_heads"]
    n = x.shape[0]
    for layer in params["layers"]:
        hidden = layer["w"].shape[1]
        dh = hidden // h
        z = (x @ layer["w"]).reshape(n, h, dh)
        e = (edge_feat @ layer["we"]).reshape(-1, h, dh)
        logit = (jnp.sum(z[senders] * layer["a_src"], -1)
                 + jnp.sum(z[receivers] * layer["a_dst"], -1)
                 + jnp.sum(e * layer["a_edge"], -1))          # [E, h]
        logit = jax.nn.leaky_relu(logit, 0.2)
        alpha = jnp.stack(
            [_segment_softmax(logit[:, j], receivers, n) for j in range(h)],
            axis=1)
        msg = alpha[..., None] * (z[senders] + e)
        agg = jax.ops.segment_sum(msg.reshape(-1, hidden), receivers, n)
        x = jax.nn.elu(agg + x @ layer["skip"])
    return x
