"""Latency metrics matching the paper's definitions (App. A.3).

Per query (all in seconds; reported in ms):
  RT    = end-to-end: retrieval + prompt build + prefill + full decode
  TTFT  = up to the first generated token
  PFTT  = the LLM prefill + first-token portion of TTFT (the part KV-cache
          reuse directly attacks)

Shared work (cluster processing, representative-prefix prefill) is
amortized uniformly over the cluster's members, mirroring how the paper's
per-query averages absorb shared batch work.

Online serving adds ``queue_wait_s`` — the time a request sat in the
arrival queue before its micro-batch started (zero for the offline
pipeline, where every query is present at t=0 by construction).  It
counts toward TTFT: a streaming user experiences the wait.

Attribution exactness (DESIGN.md §9): drain-serve batches can only
split a batch's decode time uniformly (``t / n`` shares — a row that
hit EOS on step 1 is billed the same as one that burned the whole
budget).  Continuous in-flight batching records EXACT per-row decode
attribution: each decode chunk's wall time is shared by the rows that
were actually live in it, and ``decode_steps`` counts the steps the row
really consumed (a retired row stops accruing).  ``trace_summary``
reduces a record list to the benchmark quantities (mean/p50/p95 TTFT
and queue wait).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class QueryRecord:
    query: str
    answer: str
    generated: str
    correct: bool
    retrieval_s: float = 0.0
    queue_wait_s: float = 0.0         # arrival-queue wait (online serving)
    cluster_share_s: float = 0.0      # clustering + rep-subgraph build / members
    prompt_build_s: float = 0.0
    prefix_share_s: float = 0.0       # representative prefix prefill / members
    prefill_s: float = 0.0            # own (suffix) prefill
    first_token_s: float = 0.0
    decode_s: float = 0.0             # tokens after the first
    decode_steps: int = 0             # decode-scan steps the row consumed
                                      # (exact under continuous serving)
    prompt_tokens: int = 0            # full prompt incl. soft-prompt embeds
    cached_tokens: int = 0            # tokens served from the prefix cache
    replica: int = 0                  # serving replica (router traces;
                                      # 0 for single-engine serving)

    @property
    def pftt(self) -> float:
        return self.prefix_share_s + self.prefill_s + self.first_token_s

    @property
    def ttft(self) -> float:
        return (self.queue_wait_s + self.retrieval_s + self.cluster_share_s
                + self.prompt_build_s + self.pftt)

    @property
    def rt(self) -> float:
        return self.ttft + self.decode_s


@dataclasses.dataclass
class RunSummary:
    name: str
    acc: float
    rt_ms: float
    ttft_ms: float
    pftt_ms: float
    num_queries: int
    cluster_processing_ms: float = 0.0
    prefill_savings: float = 1.0

    @staticmethod
    def from_records(name: str, records: List["QueryRecord"],
                     cluster_processing_s: float = 0.0,
                     prefill_savings: float = 1.0) -> "RunSummary":
        return RunSummary(
            name=name,
            acc=100.0 * float(np.mean([r.correct for r in records])),
            rt_ms=1e3 * float(np.mean([r.rt for r in records])),
            ttft_ms=1e3 * float(np.mean([r.ttft for r in records])),
            pftt_ms=1e3 * float(np.mean([r.pftt for r in records])),
            num_queries=len(records),
            cluster_processing_ms=1e3 * cluster_processing_s,
            prefill_savings=prefill_savings,
        )

    def row(self) -> str:
        return (f"{self.name:28s} ACC {self.acc:6.2f}  RT {self.rt_ms:8.2f}ms  "
                f"TTFT {self.ttft_ms:8.2f}ms  PFTT {self.pftt_ms:8.2f}ms")


def trace_summary(records: List[QueryRecord], stats=None) -> dict:
    """Reduce one served trace to the streaming-latency quantities the
    serving benchmarks compare (all in ms): mean/p50/p95 TTFT, mean/p95
    arrival-queue wait, mean decode time and steps.  p95 queue wait is
    the head-of-line-blocking witness — a drain-serve loop parks late
    arrivals behind a whole batch's decode, which the mean hides.

    Pass the trace's ``CacheStats`` window as ``stats`` to append the
    prefix-TREE reuse quantities (DESIGN.md §10): tokens prefilled vs
    reused per chain level, the ancestor-hit rate, and the resident
    segment/token gauges — the numbers that make a tree benchmark's
    savings claim auditable from the report alone."""
    ttft = np.array([r.ttft for r in records], np.float64)
    wait = np.array([r.queue_wait_s for r in records], np.float64)
    dec = np.array([r.decode_s for r in records], np.float64)
    out = {
        "mean_ttft_ms": round(1e3 * float(np.mean(ttft)), 3),
        "p50_ttft_ms": round(1e3 * float(np.median(ttft)), 3),
        "p95_ttft_ms": round(1e3 * float(np.percentile(ttft, 95)), 3),
        "mean_queue_wait_ms": round(1e3 * float(np.mean(wait)), 3),
        "p95_queue_wait_ms": round(1e3 * float(np.percentile(wait, 95)), 3),
        "mean_decode_ms": round(1e3 * float(np.mean(dec)), 3),
        "mean_decode_steps": round(
            float(np.mean([r.decode_steps for r in records])), 3),
    }
    if stats is not None:
        out["prefill_tokens_total"] = (stats.prefix_tokens_computed
                                       + stats.suffix_tokens_computed)
        out["tree"] = tree_report(stats)
        out["tier"] = tier_report(stats)
        out["compose"] = compose_report(stats)
    if any(r.replica for r in records):
        out["replicas"] = {
            str(i): {
                "queries": len(grp),
                "mean_ttft_ms": round(
                    1e3 * float(np.mean([r.ttft for r in grp])), 3),
                "p95_ttft_ms": round(1e3 * float(np.percentile(
                    [r.ttft for r in grp], 95)), 3),
            }
            for i, grp in sorted(_by_replica(records).items())}
    return out


def _by_replica(records: List[QueryRecord]) -> dict:
    out: dict = {}
    for r in records:
        out.setdefault(r.replica, []).append(r)
    return out


def router_report(router, records: Optional[List[QueryRecord]] = None
                  ) -> dict:
    """Reduce a ``ReplicaRouter`` run to the placement/balance
    quantities the scaling and skew benches assert on (DESIGN.md §13).

    Per replica: queries routed/retired, cluster spawns, the
    cluster-affinity hit rate (fraction of routed queries that landed
    on a cluster already placed there — prefix locality, THE router
    policy's claim), migrations in/out, pool hit rate and arena
    occupancy from the replica's own ``CacheStats`` window, and —
    when ``records`` is passed — mean TTFT over the queries it served.
    Aggregate: total migrations and the imbalance gauge (max/mean of
    per-replica routed counts; 1.0 = perfectly even)."""
    by_rep = _by_replica(records) if records is not None else {}
    per = {}
    for r in router.replicas:
        st = r.stats
        row = {
            "routed": r.routed,
            "retired": r.retired,
            "spawns": r.spawns,
            "affinity_hit_rate": round(
                router.affinity_hit_rate(r.idx), 4),
            "migrations_in": st.migrations_in,
            "migrations_out": st.migrations_out,
            "pool_hit_rate": round(st.pool_hit_rate, 4),
            "block_occupancy": round(st.block_occupancy, 4),
            "clusters_placed": sum(
                1 for v in router.placement.values() if v == r.idx),
        }
        grp = by_rep.get(r.idx)
        if grp:
            row["mean_ttft_ms"] = round(
                1e3 * float(np.mean([q.ttft for q in grp])), 3)
        per[str(r.idx)] = row
    return {
        "replicas": per,
        "num_replicas": len(router.replicas),
        "migrations": router.migrations,
        "imbalance": round(router.imbalance(), 4),
        "clusters": len(router.placement),
    }


def tree_report(stats) -> dict:
    """Per-level prefix-chain accounting from a ``CacheStats`` window
    (all-zero / empty for flat serving)."""
    levels = sorted(set(stats.tree_prefill_tokens)
                    | set(stats.tree_reused_tokens))
    return {
        "levels": {
            str(lv): {
                "prefill_tokens": stats.tree_prefill_tokens.get(lv, 0),
                "reused_tokens": stats.tree_reused_tokens.get(lv, 0),
                "hits": stats.tree_hits.get(lv, 0),
                "misses": stats.tree_misses.get(lv, 0),
            } for lv in levels},
        "ancestor_hits": stats.ancestor_hits,
        "ancestor_misses": stats.ancestor_misses,
        "ancestor_hit_rate": round(stats.ancestor_hit_rate, 4),
        "segments_resident": stats.tree_segments_resident,
        "prefix_tokens_resident": stats.tree_tokens_resident,
    }


def tier_report(stats) -> dict:
    """Host-tier traffic accounting from a ``CacheStats`` window
    (DESIGN.md §12; all-zero when no tier is attached).  The headline
    numbers: ``promotion_rate`` — the fraction of would-be re-prefills
    the host copy absorbed (promotions / (promotions + re-prefills)) —
    and ``prefetch_hit_rate`` — how many speculative promotions a real
    query then consumed (speculation precision).  ``promotion_wait_ms``
    is the RESIDUAL wall time spent blocking on promotion transfers at
    the scheduler's sync points, i.e. what the async ``device_put``
    failed to overlap — near zero is the overlap claim, measured."""
    return {
        "demotions": stats.tier_demotions,
        "promotions": stats.tier_promotions,
        "prefetch_promotions": stats.tier_prefetch_promotions,
        "prefetch_hits": stats.tier_prefetch_hits,
        "prefetch_hit_rate": round(stats.prefetch_hit_rate, 4),
        "promotion_failures": stats.tier_promotion_failures,
        "promotion_rate": round(stats.tier_promotion_rate, 4),
        "demoted_bytes": stats.tier_demoted_bytes,
        "promoted_bytes": stats.tier_promoted_bytes,
        "promotion_wait_ms": round(1e3 * stats.tier_promotion_wait_s, 3),
        "host_discards": stats.host_discards,
        "host_segments": stats.host_segments,
        "host_bytes_in_use": stats.host_bytes_in_use,
        "host_bytes_peak": stats.host_bytes_peak,
    }


def compose_report(stats) -> dict:
    """Segment-composition accounting from a ``CacheStats`` window
    (DESIGN.md §14/§15; all-zero when composition never engaged).  The
    drift gauges make the selective-recompute claim auditable: how many
    splices carried a drift mask, how many tokens their masks re-
    prefilled, and the summed attention-drift score those tokens
    covered.  ``declines`` counts engages the admission cost model
    refused (served through the chain instead); ``gap_spans_cached`` /
    ``gap_tokens_cached`` are the composition gap prefills captured
    into content-addressed blocks for repeat traffic."""
    return {
        "requests": stats.compose_requests,
        "segments_spliced": stats.compose_segments,
        "spliced_tokens": stats.compose_spliced_tokens,
        "recomputed_tokens": stats.compose_recomputed_tokens,
        "drift_splices": stats.compose_drift_splices,
        "drift_recomputed_tokens": stats.compose_drift_tokens,
        "drift_score_covered": round(stats.compose_drift_score, 4),
        "declines": stats.compose_declines,
        "gap_spans_cached": stats.gap_spans_cached,
        "gap_tokens_cached": stats.gap_tokens_cached,
    }


def speedup(base: RunSummary, ours: RunSummary) -> dict:
    return {
        "acc_delta": ours.acc - base.acc,
        "rt_x": base.rt_ms / max(ours.rt_ms, 1e-9),
        "ttft_x": base.ttft_ms / max(ours.ttft_ms, 1e-9),
        "pftt_x": base.pftt_ms / max(ours.pftt_ms, 1e-9),
    }
