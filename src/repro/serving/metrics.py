"""Latency metrics matching the paper's definitions (App. A.3).

Per query (all in seconds; reported in ms):
  RT    = end-to-end: retrieval + prompt build + prefill + full decode
  TTFT  = up to the first generated token
  PFTT  = the LLM prefill + first-token portion of TTFT (the part KV-cache
          reuse directly attacks)

Shared work (cluster processing, representative-prefix prefill) is
amortized uniformly over the cluster's members, mirroring how the paper's
per-query averages absorb shared batch work.

Online serving adds ``queue_wait_s`` — the time a request sat in the
arrival queue before its micro-batch started (zero for the offline
pipeline, where every query is present at t=0 by construction).  It
counts toward TTFT: a streaming user experiences the wait.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class QueryRecord:
    query: str
    answer: str
    generated: str
    correct: bool
    retrieval_s: float = 0.0
    queue_wait_s: float = 0.0         # arrival-queue wait (online serving)
    cluster_share_s: float = 0.0      # clustering + rep-subgraph build / members
    prompt_build_s: float = 0.0
    prefix_share_s: float = 0.0       # representative prefix prefill / members
    prefill_s: float = 0.0            # own (suffix) prefill
    first_token_s: float = 0.0
    decode_s: float = 0.0             # tokens after the first
    prompt_tokens: int = 0
    cached_tokens: int = 0            # tokens served from the prefix cache

    @property
    def pftt(self) -> float:
        return self.prefix_share_s + self.prefill_s + self.first_token_s

    @property
    def ttft(self) -> float:
        return (self.queue_wait_s + self.retrieval_s + self.cluster_share_s
                + self.prompt_build_s + self.pftt)

    @property
    def rt(self) -> float:
        return self.ttft + self.decode_s


@dataclasses.dataclass
class RunSummary:
    name: str
    acc: float
    rt_ms: float
    ttft_ms: float
    pftt_ms: float
    num_queries: int
    cluster_processing_ms: float = 0.0
    prefill_savings: float = 1.0

    @staticmethod
    def from_records(name: str, records: List["QueryRecord"],
                     cluster_processing_s: float = 0.0,
                     prefill_savings: float = 1.0) -> "RunSummary":
        return RunSummary(
            name=name,
            acc=100.0 * float(np.mean([r.correct for r in records])),
            rt_ms=1e3 * float(np.mean([r.rt for r in records])),
            ttft_ms=1e3 * float(np.mean([r.ttft for r in records])),
            pftt_ms=1e3 * float(np.mean([r.pftt for r in records])),
            num_queries=len(records),
            cluster_processing_ms=1e3 * cluster_processing_s,
            prefill_savings=prefill_savings,
        )

    def row(self) -> str:
        return (f"{self.name:28s} ACC {self.acc:6.2f}  RT {self.rt_ms:8.2f}ms  "
                f"TTFT {self.ttft_ms:8.2f}ms  PFTT {self.pftt_ms:8.2f}ms")


def speedup(base: RunSummary, ours: RunSummary) -> dict:
    return {
        "acc_delta": ours.acc - base.acc,
        "rt_x": base.rt_ms / max(ours.rt_ms, 1e-9),
        "ttft_x": base.ttft_ms / max(ours.ttft_ms, 1e-9),
        "pftt_x": base.pftt_ms / max(ours.pftt_ms, 1e-9),
    }
