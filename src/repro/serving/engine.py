"""Batched serving engine with SubGCache prefix reuse over a paged KV
block pool.

The serving API is one call (DESIGN.md §8)::

    requests = [Request(suffix_tokens=..., prefix=state_or_None), ...]
    outputs, timing = engine.serve(requests)

Every request carries its own (optional) ``PrefixState``; one batch may
mix members of any number of clusters.  Backends:

  * **paged** (attention-only stacks, the default) — prefixes and
    suffixes live in ONE refcounted block arena (``core/paged.py``).
    ``serve`` builds two page tables per row: the prefix table maps the
    row onto its cluster's shared prefix blocks (members share
    physically — no replication, no padded stacking), the suffix table
    onto freshly allocated private blocks.  One suffix prefill + one
    greedy decode serve the whole batch; attention cascades over
    [prefix pages ++ suffix pages] with an exact LSE merge, walking the
    tables via scalar-prefetch DMA on the Pallas path and a gather on
    the XLA path.  Suffix blocks free when the batch completes.
  * **dense** (stateful / cross-attention stacks, or ``paged=False``) —
    requests group by prefix and each group is served through the
    dense split cascade (DESIGN.md §5) or, for recurrent state, the
    ``PrefixState.broadcast`` fallback in equal-length sub-batches.
    Same ``serve`` facade: callers never branch on architecture.

``generate_with_prefix`` / ``generate_multi_prefix`` remain as thin
wrappers that build ``Request`` lists; ``generate`` is the vanilla
no-cache baseline.  ``decode_step`` exposes the decode scan in fixed
step chunks for continuous in-flight batching
(``serving/continuous.py``, DESIGN.md §9) — ``serve`` keeps the
monolithic scan and is its drain-serve A/B oracle.  ``prefill_prefix`` computes the representative
prefix at batch 1 and (paged backend) immediately re-homes it into
arena blocks — the returned ``PrefixState`` is a page table, not a
buffer.

Timing dicts carry aggregate ``prefill_s``/``decode_s`` plus per-member
``prefill_share``/``decode_share`` lists — sub-batched serving (dense
fallback) costs each member its OWN sub-batch's share.

Shapes are bucketed (``serving/bucketing.py``): suffix lengths to
multiples of ``bucket``, batches and page-table widths to powers of
two — lengths are data, not shapes (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import (ClusterCacheManager, PrefixState,
                              SegmentComposition)
from repro.core.paged import (NULL_BLOCK, KVBlockPool, OutOfBlocks,
                              PageTable)
from repro.data.tokenizer import EOS, PAD, Tokenizer
from repro.kernels.fused_cascade import drift_probe
from repro.kernels.ref import drift_mass_ref
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, linear, rms_norm
from repro.serving.bucketing import (blocks_for, bucket_capacity, bucket_len,
                                     bucket_pow2)


@dataclasses.dataclass
class Request:
    """One serving request: a suffix to prefill+decode behind an
    optional shared-prefix state (None = no cached prefix; the row
    attends nothing but its own tokens).

    ``composition`` (mutually exclusive with ``prefix``) serves the row
    against a ``SegmentComposition`` plan instead (DESIGN.md §14): the
    prompt context ``[0, total_len)`` is a splice of re-based cached
    segments plus fresh gap spans, and ``suffix_tokens`` follow at
    ``total_len`` as the final fresh span (the query text — the plan
    must end in fresh tokens so the first decode logit exists)."""
    suffix_tokens: List[int]
    prefix: Optional[PrefixState] = None
    composition: Optional[SegmentComposition] = None

    def __post_init__(self):
        assert self.prefix is None or self.composition is None, \
            "a request carries a prefix state OR a composition plan"


class ServingEngine:
    """Executes serving traffic for one model (see module docstring).

    Owns the jitted prefill/decode builders (lru-cached per shape
    bucket), the ``KVBlockPool`` block arena (paged backend), the
    ``ClusterCacheManager`` that accounts ``CacheStats``, and the
    backend policy decision.  Tensor conventions follow ``kernels/``:
    embeddings ``[B, T, D]``, positions/valid ``[B, T]``, KV caches
    seq-major ``{"k","v": [B, C, Hkv, Dh], "pos": [B, C]}``; the block
    arena is the same layout with ``B = num_blocks`` and
    ``C = block_size``.

    ``max_cache_len``: hard capacity ceiling per sequence.
    ``max_new_tokens``: greedy-decode budget (EOS stops earlier).
    ``bucket``: suffix-length bucket.  ``split_prefix``: force-disable
    the dense split cascade with ``False`` (A/B comparisons).
    ``paged``: force-disable the paged backend with ``False`` (the
    dense cascade then serves; A/B + exactness tests); default
    auto-enables it on attention-only stacks.  ``block_size``: arena
    block granularity (must divide the capacity buckets, i.e. be a
    power of two <= 128 in practice).  ``arena_blocks``: usable blocks
    in the arena (defaults to a generous multiple of
    ``max_cache_len``); together with ``block_size`` this IS the paged
    HBM byte budget.
    """

    def __init__(self, params, cfg: ModelConfig, tokenizer: Tokenizer, *,
                 max_cache_len: int = 768, max_new_tokens: int = 32,
                 bucket: int = 32, split_prefix: Optional[bool] = None,
                 paged: Optional[bool] = None, block_size: int = 64,
                 arena_blocks: Optional[int] = None, fused: bool = True,
                 quantize_prefix: bool = False):
        # recorded BEFORE any defaulting mutates the locals, so
        # ``clone()`` rebuilds a replica from the caller's own spec
        self._ctor_kwargs = dict(
            max_cache_len=max_cache_len, max_new_tokens=max_new_tokens,
            bucket=bucket, split_prefix=split_prefix, paged=paged,
            block_size=block_size, arena_blocks=arena_blocks,
            fused=fused, quantize_prefix=quantize_prefix)
        self.params = params
        self.cfg = cfg
        self.tok = tokenizer
        self.max_cache_len = max_cache_len
        self.max_new_tokens = max_new_tokens
        self.bucket = bucket
        # fused=True routes the paged Pallas path through the
        # single-pass cascade kernels (kernels/fused_cascade.py); on
        # XLA the fused composition IS the multi-launch cascade, so the
        # flag only changes which Pallas kernels launch (DESIGN.md §11)
        self.fused = bool(fused)
        self.cache_mgr = ClusterCacheManager()
        self._prefill_jit = functools.lru_cache(maxsize=64)(self._make_prefill)
        self._decode_jit = functools.lru_cache(maxsize=16)(self._make_decode)
        self._decode_step_jit = functools.lru_cache(maxsize=32)(
            self._make_decode_step)
        # Recurrent mixers (Mamba / RG-LRU) carry state through every
        # consumed token — right-padding would corrupt it (attention masks
        # padded slots; scans cannot).  Such archs get length-exact
        # processing: no pad tokens ever enter the scan.
        from repro.models.config import MAMBA, RGLRU
        self._stateful = any(s.mixer in (MAMBA, RGLRU)
                             for s in cfg.layer_specs())
        # Prefix-cascade serving covers attention-only stacks: recurrent
        # state is not a set of positional slots and cross-attention KV
        # is per-state, so both fall back to PrefixState.broadcast.
        has_cross = any(s.cross_attn for s in cfg.layer_specs())
        can_split = not self._stateful and not has_cross
        self.use_split_prefix = (can_split if split_prefix is None
                                 else bool(split_prefix) and can_split)
        # Paged backend: the cascade generalized to page tables over one
        # block arena (DESIGN.md §8).  Subsumes the dense split path for
        # serving; the dense path remains for A/B and as the oracle the
        # paged exactness tests compare against.
        self.use_paged = (self.use_split_prefix if paged is None
                          else bool(paged) and self.use_split_prefix)
        self.block_size = block_size
        if self.use_paged:
            assert max_cache_len % block_size == 0, (
                "block_size must divide max_cache_len so capacity "
                "buckets are whole blocks")
            if arena_blocks is None:
                arena_blocks = 8 * max_cache_len // block_size + 32
            self.block_pool: Optional[KVBlockPool] = KVBlockPool(
                cfg, arena_blocks + 1, block_size,    # +1: NULL block
                quantize_prefix=quantize_prefix)
        else:
            self.block_pool = None
        self.quantize_prefix = bool(quantize_prefix) and self.use_paged
        # gap-span capture (DESIGN.md §15): after a composed serve, gap
        # spans at least ``gap_min_tokens`` long are repacked from the
        # suffix rows into content-addressed prefix blocks and offered
        # to ``gap_admit(tokens, state) -> bool`` (installed by the
        # scheduler; False = caller declined ownership, the state is
        # released here).  None disables capture entirely.
        self.gap_admit = None
        self.gap_min_tokens = block_size

    def clone(self) -> "ServingEngine":
        """A fresh engine over the SAME params/config/tokenizer with a
        PRIVATE block arena, cache manager, and jit caches — one serving
        replica (DESIGN.md §13).  Params are shared by reference (pure
        reads), so N replicas cost N arenas, not N models."""
        return ServingEngine(self.params, self.cfg, self.tok,
                             **self._ctor_kwargs)

    # ------------------------------------------------------------------
    # jitted building blocks (cached per shape bucket)
    # ------------------------------------------------------------------
    def _make_prefill(self, batch: int, seqlen: int):
        """One builder serves all backends: broadcast callers pass
        ``prefix=None`` and no page tables; dense split callers pass the
        live batch-1 prefix buffers as an ordinary non-donated argument,
        read in place; paged callers pass the (donated) block arena as
        ``cache`` plus per-row prefix/suffix page tables and per-row
        ``slot_offset``."""
        cfg = self.cfg
        fused = self.fused

        def prefill(params, embeds, positions, valid, cache, prefix,
                    slot_offset, prefix_pages, suffix_pages,
                    prefix_offsets=None, prefix_skips=None):
            hidden, cache, _ = M.forward(params, cfg, embeds, positions,
                                         cache=cache, valid=valid,
                                         prefix=prefix,
                                         slot_offset=slot_offset,
                                         prefix_pages=prefix_pages,
                                         suffix_pages=suffix_pages,
                                         prefix_offsets=prefix_offsets,
                                         prefix_skips=prefix_skips,
                                         fused=fused)
            lengths = jnp.sum(valid.astype(jnp.int32), axis=1)      # [B]
            last = jnp.take_along_axis(
                hidden, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1)
            logits = M.unembed(params, cfg, last)[:, 0]             # [B, V]
            return cache, logits, lengths

        return jax.jit(prefill, donate_argnums=(4,))

    def _make_decode(self, batch: int):
        """The decode scan closes over the prefix source / page tables
        as invariants — never carried, donated, or copied per step.
        The carry is only what decode WRITES: the dense member cache,
        or (paged) the compact suffix sub-arena extracted for this
        batch — the main arena rides in ``prefix`` read-only, so the
        scan never copies it."""
        cfg = self.cfg
        steps = self.max_new_tokens - 1
        fused = self.fused

        def decode(params, first_token, lengths, cache, prefix, slot_offset,
                   prefix_pages, suffix_pages,
                   prefix_offsets=None, prefix_skips=None):
            def body(carry, _):
                cache, tok, pos, done = carry
                emb = M.embed_tokens(params, tok[:, None])
                hidden, cache, _ = M.forward(params, cfg, emb, pos[:, None],
                                             cache=cache, prefix=prefix,
                                             slot_offset=slot_offset,
                                             prefix_pages=prefix_pages,
                                             suffix_pages=suffix_pages,
                                             prefix_offsets=prefix_offsets,
                                             prefix_skips=prefix_skips,
                                             fused=fused)
                logits = M.unembed(params, cfg, hidden)[:, 0]
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                done = done | (tok == EOS)
                nxt = jnp.where(done, EOS, nxt)
                return (cache, nxt, pos + 1, done), nxt

            init = (cache, first_token, lengths,
                    jnp.zeros((batch,), bool))
            (cache, _, _, _), toks = jax.lax.scan(body, init, None,
                                                  length=steps)
            return jnp.concatenate([first_token[:, None], toks.T],
                                   axis=1), cache

        return jax.jit(decode, donate_argnums=(3,))

    def _make_decode_step(self, batch: int, steps: int):
        """Chunked decode for continuous in-flight batching
        (DESIGN.md §9): the same greedy scan body as ``_make_decode``
        but over a FIXED chunk of ``steps`` tokens with the carry
        (token / position / done) passed in and the emitted tokens
        returned — the host retires finished rows and admits newly
        arrived ones between chunks instead of burning the whole
        ``max_new_tokens`` budget per batch.  Chunking a scan preserves
        carry semantics exactly, so the emitted stream is
        token-identical to the monolithic decode.  The carried
        ``cache`` is the compact per-slot suffix sub-arena
        (``KVBlockPool.sub_arena``); the main arena rides in ``prefix``
        read-only."""
        cfg = self.cfg
        fused = self.fused

        def decode_step(params, tok, pos, done, cache, prefix, slot_offset,
                        prefix_pages, suffix_pages,
                        prefix_offsets=None, prefix_skips=None):
            def body(carry, _):
                cache, tok, pos, done = carry
                emb = M.embed_tokens(params, tok[:, None])
                hidden, cache, _ = M.forward(params, cfg, emb, pos[:, None],
                                             cache=cache, prefix=prefix,
                                             slot_offset=slot_offset,
                                             prefix_pages=prefix_pages,
                                             suffix_pages=suffix_pages,
                                             prefix_offsets=prefix_offsets,
                                             prefix_skips=prefix_skips,
                                             fused=fused)
                logits = M.unembed(params, cfg, hidden)[:, 0]
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                done = done | (tok == EOS)
                nxt = jnp.where(done, EOS, nxt)
                return (cache, nxt, pos + 1, done), nxt

            (cache, *_), toks = jax.lax.scan(body, (cache, tok, pos, done),
                                             None, length=steps)
            return toks.T, cache

        return jax.jit(decode_step, donate_argnums=(4,))

    def decode_step(self, tok, pos, done, sub, offs, prefix_rows,
                    suffix_rows, *, steps: int,
                    prefix_offsets=None, prefix_skips=None):
        """Run one ``steps``-token decode chunk over an in-flight batch
        (continuous serving facade; see ``serving/continuous.py``).

        ``sub`` is DONATED: callers must treat their handle as consumed
        and re-home the returned sub-arena (exception-safe, like
        ``_with_arena``).  ``prefix_offsets``/``prefix_skips`` [B, NBP]
        carry composed rows' per-block re-base deltas and boundary
        masks (DESIGN.md §14; None for chain-only batches — a separate
        trace, not a zero-filled operand, so chain serving keeps its
        executable).  Returns ``(tokens [B, steps], sub)``."""
        fn = self._decode_step_jit(int(len(tok)), int(steps))
        po = (None if prefix_offsets is None
              else jnp.asarray(prefix_offsets, jnp.int32))
        ps = (None if prefix_skips is None
              else jnp.asarray(prefix_skips, jnp.int32))
        return fn(self.params, jnp.asarray(tok, jnp.int32),
                  jnp.asarray(pos, jnp.int32), jnp.asarray(done, bool),
                  sub, self.block_pool.prefix_source(),
                  jnp.asarray(offs, jnp.int32), jnp.asarray(prefix_rows),
                  jnp.asarray(suffix_rows), po, ps)

    # ------------------------------------------------------------------
    # embedding helpers
    # ------------------------------------------------------------------
    def _embed_padded(self, token_lists: Sequence[List[int]],
                      soft: Optional[np.ndarray], pos_offset,
                      pad_to: Optional[int] = None):
        """Right-pad token lists (+ optional shared soft-prompt embeds
        prepended) into (embeds [B,T,D], positions [B,T], valid [B,T]).

        ``pos_offset`` shifts the absolute positions: a scalar applies
        to every row (single shared prefix); a [B] array gives each row
        its own start (multi-prefix serving — each row sits behind its
        own cluster's prefix length)."""
        n_soft = 0 if soft is None else soft.shape[0]
        lens = [len(t) + n_soft for t in token_lists]
        t_pad = pad_to or bucket_len(max(lens), self.bucket)
        b = len(token_lists)
        ids = np.full((b, t_pad), PAD, np.int32)
        valid = np.zeros((b, t_pad), bool)
        for i, toks in enumerate(token_lists):
            ids[i, n_soft:n_soft + len(toks)] = toks
            valid[i, :lens[i]] = True
        embeds = M.embed_tokens(self.params, jnp.asarray(ids))
        if soft is not None:
            embeds = embeds.at[:, :n_soft].set(
                jnp.asarray(soft)[None].astype(embeds.dtype))
        off = jnp.asarray(pos_offset, jnp.int32)
        off = off[:, None] if off.ndim == 1 else off[None, None]
        positions = off + jnp.arange(t_pad, dtype=jnp.int32)[None]
        positions = jnp.broadcast_to(positions, (b, t_pad))
        return embeds, positions, jnp.asarray(valid), np.asarray(lens)

    # ------------------------------------------------------------------
    # capacity buckets
    # ------------------------------------------------------------------
    def _capacity_for(self, prefix_len: int, suffix_headroom: int = 64) -> int:
        """Cache capacity bucket covering prefix + suffix + decode."""
        return bucket_capacity(
            prefix_len + suffix_headroom + self.max_new_tokens + 8, 512,
            self.max_cache_len, "prompt")

    def _prefix_capacity_for(self, prefix_len: int) -> int:
        """Capacity bucket for a split-mode prefix state: prefix tokens
        only — suffix and decode live in the per-member suffix cache."""
        return bucket_capacity(prefix_len, 128, self.max_cache_len, "prefix")

    def _suffix_capacity_for(self, suffix_len: int) -> int:
        """Capacity bucket for the per-member suffix+decode cache."""
        return bucket_capacity(
            suffix_len + self.max_new_tokens + 8, 64, self.max_cache_len,
            "suffix")

    # ------------------------------------------------------------------
    # prefix prefill
    # ------------------------------------------------------------------
    def prefill_prefix(self, prefix_tokens: List[int],
                       soft: Optional[np.ndarray] = None,
                       enc: Optional[np.ndarray] = None,
                       _record: bool = True) -> Tuple[PrefixState, float]:
        """Representative-subgraph prefix prefill at batch=1.

        Paged backend: the dense batch-1 result is immediately re-homed
        into ``ceil(P / block_size)`` arena blocks and the dense buffer
        dropped — the returned state is a page table (refcount 1,
        caller-owned; ``release()`` or pool eviction frees it).  Dense
        backends size the state for the cascade (prefix only) or for
        broadcast mode (prefix + suffix + decode headroom).
        """
        t0 = time.perf_counter()
        embeds, positions, valid, lens = self._embed_padded(
            [prefix_tokens], soft, 0,
            pad_to=None if not self._stateful else
            len(prefix_tokens) + (0 if soft is None else soft.shape[0]))
        use_split = self.use_split_prefix and enc is None
        capacity = (self._prefix_capacity_for(int(lens[0])) if use_split
                    else self._capacity_for(int(lens[0])))
        if _record:
            # prefix cost accrues when COMPUTED: a state reused across
            # several serve calls still cost one prefill
            self.cache_mgr.stats.record_prefix(int(lens[0]), split=use_split)
        cache = M.init_cache(self.cfg, 1, capacity,
                             enc_len=0 if enc is None else enc.shape[1])
        prefill = self._prefill_jit(1, embeds.shape[1])
        cache, _, _ = prefill(self.params, embeds, positions, valid, cache,
                              None, 0, None, None)
        n_soft = 0 if soft is None else int(soft.shape[0])
        if self.use_paged and enc is None:
            page = self.block_pool.write_prefix(cache, int(lens[0]))
            jax.block_until_ready(self.block_pool.arena)
            dt = time.perf_counter() - t0
            return PrefixState(cache=None, prefix_len=int(lens[0]),
                               capacity=capacity, page=page,
                               block_pool=self.block_pool,
                               n_soft=n_soft), dt
        jax.block_until_ready(cache)
        dt = time.perf_counter() - t0
        state = PrefixState(cache=cache, prefix_len=int(lens[0]),
                            capacity=capacity,
                            enc_len=0 if enc is None else enc.shape[1],
                            n_soft=n_soft)
        return state, dt

    def prefill_prefix_extension(self, parent: PrefixState,
                                 ext_tokens: List[int],
                                 _record: bool = True
                                 ) -> Tuple[PrefixState, float]:
        """Extend a prefix chain by one segment (DESIGN.md §10).

        Prefills ``ext_tokens`` at batch 1 BEHIND the parent's full
        chain (the cascade: parent path as the read-only prefix source,
        fresh KV into this segment's own storage), so the returned
        child state's path KV is token-identical to flat-prefilling the
        concatenated path — the ancestor segments are stored once and
        referenced, never recomputed or copied.

        Paged backend: the extension's KV lands in exactly
        ``ceil(len / block_size)`` fresh arena blocks (the segment's
        own page); the child takes per-lifetime block references on
        every ancestor block, so a pool-evicted ancestor can never be
        recycled under a live descendant.  Dense split backend: the
        segment gets its own batch-1 cache and the chain is served as
        a tuple of segment caches through the N-way LSE fold.
        Attention-only stacks only (the engine's callers gate).
        """
        assert parent.enc_len == 0, \
            "prefix chains do not cover cross-attention states"
        t0 = time.perf_counter()
        embeds, positions, valid, lens = self._embed_padded(
            [list(ext_tokens)], None, parent.prefix_len)
        n_ext = int(lens[0])
        total = parent.prefix_len + n_ext
        # capacity-bucket the FULL path first: an over-long chain must
        # raise before any refcount or allocation side effect
        capacity = self._prefix_capacity_for(total)
        if _record:
            self.cache_mgr.stats.record_prefix(n_ext, split=True)
        prefill = self._prefill_jit(1, embeds.shape[1])
        if self.use_paged:
            assert parent.is_paged and parent.block_pool is self.block_pool, \
                "chain extension needs a page-table parent from this engine"
            pool = self.block_pool
            chain = parent.chain_blocks()
            nbp = bucket_pow2(len(chain))
            prow = np.full((1, nbp), NULL_BLOCK, np.int32)
            prow[0, :len(chain)] = chain
            # the child's lifetime references on its ancestors: taken
            # BEFORE the allocation below, whose reclaim pass may evict
            # the parent from the pool mid-extension
            pool.incref(chain)
            stage: Optional[List[int]] = None
            bids: Optional[List[int]] = None
            try:
                stage = pool.alloc_suffix(blocks_for(n_ext, self.block_size))
                srow = np.asarray(stage, np.int32).reshape(1, -1)
                # quantized pools read ancestor blocks from the int8
                # arena (pool.qarena; None otherwise — the prefix is
                # then read from the donated arena itself).  Never pass
                # pool.arena here: it IS the donated cache argument.
                self._with_arena(lambda a: prefill(
                    self.params, embeds, positions, valid, a, pool.qarena,
                    jnp.int32(parent.prefix_len), jnp.asarray(prow),
                    jnp.asarray(srow)))
                if pool.qarena is not None:
                    # the tail becomes prefix-resident in the int8 space;
                    # the compute-dtype staging rows return to the suffix
                    # free list (no dead rows — ROADMAP known debt)
                    bids = pool.alloc(len(stage))
                    pool.quantize_blocks(stage, bids)
                    pool.decref(stage, suffix=True)
                    stage = None
                else:
                    bids, stage = stage, None
                pool.note_tokens(bids, n_ext)
                jax.block_until_ready(pool.arena)
            except BaseException:
                pool.decref(chain)
                if stage is not None:
                    pool.decref(stage, suffix=True)
                if bids is not None:
                    pool.decref(bids)
                raise
            self.cache_mgr.stats.record_blocks(pool)
            dt = time.perf_counter() - t0
            return PrefixState(
                cache=None, prefix_len=total, capacity=capacity,
                page=PageTable(blocks=bids, length=n_ext),
                block_pool=pool, n_soft=parent.n_soft, parent=parent,
                seg_len=n_ext, ancestor_blocks=chain), dt
        # dense split backend: the segment's own batch-1 suffix-style
        # cache, prefilled through the N-way cascade over the chain
        assert self.use_split_prefix and parent.cache is not None, \
            "dense chain extension needs the split cascade " \
            "(stateful / cross-attention stacks serve flat prefixes)"
        cache = M.init_suffix_cache(self.cfg, 1,
                                    self._prefix_capacity_for(n_ext))
        prefix = tuple(s.cache for s in parent.chain())
        cache, _, _ = prefill(self.params, embeds, positions, valid, cache,
                              prefix, jnp.int32(parent.prefix_len),
                              None, None)
        jax.block_until_ready(cache)
        dt = time.perf_counter() - t0
        return PrefixState(cache=cache, prefix_len=total,
                           capacity=self._prefix_capacity_for(n_ext),
                           n_soft=parent.n_soft, parent=parent,
                           seg_len=n_ext), dt

    # ------------------------------------------------------------------
    # the serving API
    # ------------------------------------------------------------------
    def serve(self, requests: Sequence[Request], _record: bool = True
              ) -> Tuple[List[List[int]], dict]:
        """Serve one batch of requests; THE serving path (DESIGN.md §8).

        Rows may reference any mix of prefix states, or none — the
        paged backend gives prefixless rows an all-NULL prefix table,
        the dense fallback routes them through a no-prefix group.
        Attention-only stacks run the paged backend; stateful and
        cross-attention stacks transparently take the dense fallback —
        callers never branch on architecture.
        """
        n = len(requests)
        assert n > 0, "serve() needs at least one request"
        if any(r.composition is not None for r in requests):
            assert self.use_paged, \
                "composition plans need the paged backend (DESIGN.md §14)"
            outs, timing = self._serve_composed(requests)
        elif self.use_paged and not any(
                r.prefix is not None and r.prefix.enc_len for r in requests):
            outs, timing = self._serve_paged(requests)
        else:
            outs, timing = self._serve_dense(requests)
        if _record:
            # members count only once actually served: a capacity error
            # above must not inflate prefill_savings
            stats = self.cache_mgr.stats
            stats.record_served(n)
            for r in requests:
                if r.composition is not None:
                    plen = r.composition.total_len
                    stats.record_compose(r.composition)
                else:
                    plen = r.prefix.prefix_len if r.prefix is not None else 0
                stats.record_member(plen + len(r.suffix_tokens),
                                    len(r.suffix_tokens))
            stats.finalize()
        return outs, timing

    def generate_with_prefix(self, state: PrefixState,
                             suffix_token_lists: Sequence[List[int]],
                             _record: bool = True
                             ) -> Tuple[List[List[int]], dict]:
        """All members of ONE cluster behind one shared prefix state
        (thin wrapper over ``serve``)."""
        return self.serve([Request(suffix_tokens=list(t), prefix=state)
                           for t in suffix_token_lists], _record=_record)

    def generate_multi_prefix(self, states: Sequence[PrefixState],
                              prefix_ids: Sequence[int],
                              suffix_token_lists: Sequence[List[int]],
                              _record: bool = True
                              ) -> Tuple[List[List[int]], dict]:
        """One batch mixing members of SEVERAL clusters:
        ``prefix_ids[i]`` indexes the state row ``i`` is served against
        (thin wrapper over ``serve``)."""
        n = len(suffix_token_lists)
        assert len(prefix_ids) == n, (len(prefix_ids), n)
        assert all(0 <= p < len(states) for p in prefix_ids)
        return self.serve(
            [Request(suffix_tokens=list(t), prefix=states[p])
             for p, t in zip(prefix_ids, suffix_token_lists)],
            _record=_record)

    # ------------------------------------------------------------------
    # paged backend
    # ------------------------------------------------------------------
    def _serve_paged(self, requests: Sequence[Request]
                     ) -> Tuple[List[List[int]], dict]:
        """Page-table serving over the block arena (see module
        docstring).  Builds [B, NBP] prefix and [B, NBS] suffix tables,
        pins prefix blocks for the duration, runs one prefill + decode,
        frees the suffix blocks."""
        pool = self.block_pool
        n = len(requests)
        b = bucket_pow2(n)
        suffixes = [list(r.suffix_tokens) for r in requests] \
            + [[EOS]] * (b - n)                      # batch padding rows
        states = [r.prefix for r in requests] + [None] * (b - n)
        for st in states:
            if st is not None:
                assert st.is_paged and st.block_pool is pool, \
                    "paged serve needs page-table states from this engine"

        t0 = time.perf_counter()
        offs = np.asarray([st.prefix_len if st else 0 for st in states],
                          np.int32)
        # prefix page tables: members of one cluster map the SAME blocks
        # (rows share physically); width is a power-of-two bucket so a
        # handful of executables cover any prefix length.  Block refs
        # are pinned per distinct state for the duration of the batch —
        # a pool eviction mid-flight cannot recycle them under us.  The
        # pins happen inside the try: any failure below (suffix-capacity
        # overflow, arena exhaustion, a compile error) must drop them,
        # or the blocks leak phantom references forever.
        # a chain state's row is the CONCATENATION of its ancestors' and
        # its own blocks (DESIGN.md §10) — masking is positional, so the
        # N-segment cascade is just a wider page walk; pins cover the
        # full path (snapshotted: an eviction mid-batch drops the
        # state's own handle, never the list we increfed)
        nbp = bucket_pow2(max(1, max(
            (len(st.chain_blocks()) for st in states if st is not None),
            default=1)))
        pinned: dict = {}
        flat: Optional[List[int]] = None
        try:
            for st in states:
                if st is not None and st.uid not in pinned:
                    blocks = st.chain_blocks()
                    pool.incref(blocks)
                    pinned[st.uid] = blocks
            if len(pinned) == 1 and all(st is not None for st in states[:n]):
                # single-cluster micro-batch (common under temporally
                # clustered traffic): a [1, NBP] SHARED table — every row
                # walks the same blocks, streamed once per kv-head group
                # like the dense batch-1 cascade, not once per member.
                # Batch-padding rows ride along (outputs discarded).
                one = next(st for st in states if st is not None)
                prefix_rows = one.page_row(nbp)[None]
                offs = np.full(b, one.prefix_len, np.int32)
            else:
                prefix_rows = np.full((b, nbp), NULL_BLOCK, np.int32)
                for i, st in enumerate(states):
                    if st is not None:
                        prefix_rows[i] = st.page_row(nbp)
            embeds, positions, valid, lens = self._embed_padded(
                suffixes, None, offs)
            suffix_cap = self._suffix_capacity_for(embeds.shape[1])
            nbs = blocks_for(suffix_cap, self.block_size)
            flat = pool.alloc_suffix(b * nbs)        # private, pos reset
            suffix_rows = np.asarray(flat, np.int32).reshape(b, nbs)
            # charge what prefill is about to store BEFORE the gauge is
            # read: observing freshly allocated (zero-token) suffix
            # blocks would overstate fragmentation for the whole batch
            for i in range(b):
                pool.note_tokens(suffix_rows[i], int(lens[i]), suffix=True)
            # observe the HBM high-water mark: resident prefixes + every
            # in-flight suffix block (gauge re-read after frees below)
            self.cache_mgr.stats.record_blocks(pool)
            prow = jnp.asarray(prefix_rows)
            srow = jnp.asarray(suffix_rows)
            offj = jnp.asarray(offs)
            prefill = self._prefill_jit(b, embeds.shape[1])
            # quantized pools read prefix blocks from the int8 arena
            # (pool.qarena; None otherwise — then read from the donated
            # arena itself, never pool.arena, which IS the donated arg)
            arena, logits, _ = self._with_arena(
                lambda a: prefill(self.params, embeds, positions, valid,
                                  a, pool.qarena, offj, prow, srow))
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            jax.block_until_ready(first)
            t_prefill = time.perf_counter() - t0

            t0 = time.perf_counter()
            lengths = jnp.asarray(offs + lens, jnp.int32)
            decode = self._decode_jit(b)
            # Decode writes only this batch's suffix blocks, so the
            # scan carries a compact extraction of them (remapped
            # table: row i owns sub-rows [i*nbs, (i+1)*nbs)); the main
            # arena rides along READ-ONLY as the prefix source — a
            # full-arena carry would be copied once per token on
            # backends where donation cannot alias.  The extraction is
            # discarded with the suffix blocks; nothing scatters back.
            sub = pool.extract(flat)
            sub_pages = jnp.arange(b * nbs, dtype=jnp.int32).reshape(b, nbs)
            out, _ = decode(self.params, first, lengths, sub,
                            pool.prefix_source(), offj, prow, sub_pages)
            out = np.asarray(jax.block_until_ready(out))
            t_decode = time.perf_counter() - t0
            # reconcile token counts at row retirement: a row that hit
            # EOS early stored fewer decode tokens than the
            # ``max_new_tokens`` budget — charging the budget would
            # understate the fragmentation the gauge exists to expose
            for i in range(b):
                row = out[i].tolist()
                gen = (row.index(EOS) + 1 if EOS in row else len(row))
                pool.note_tokens(suffix_rows[i], int(lens[i]) + gen,
                                 suffix=True)
            self.cache_mgr.stats.record_blocks(pool)
        finally:
            if flat is not None:
                pool.decref(flat, suffix=True)       # suffix blocks free
            for blocks in pinned.values():
                pool.decref(blocks)
        self.cache_mgr.stats.record_blocks(pool)
        toks = [self._cut(out[i]) for i in range(n)]
        return toks, {"prefill_s": t_prefill, "decode_s": t_decode,
                      "batch": b, "split_prefix": True, "paged": True,
                      "num_prefixes": len(pinned),
                      "prefill_share": [t_prefill / n] * n,
                      "decode_share": [t_decode / n] * n}

    # ------------------------------------------------------------------
    # composed serving (DESIGN.md §14)
    # ------------------------------------------------------------------
    def _row_plan(self, req: Request) -> dict:
        """Host-side serving plan for one request under the composed
        path: the prefix-row layout (blocks + per-block offsets/skips,
        PINNED — caller decrefs ``pinned``), the fresh token/position
        stream the prefill must compute, the suffix-table slot offset,
        and the total prompt length.  A chain/prefixless request is the
        degenerate plan (zero offsets, zero skips, contiguous fresh
        suffix) — one code path serves mixed batches."""
        pool = self.block_pool
        sfx = list(req.suffix_tokens)
        if req.composition is not None:
            comp = req.composition
            assert sfx, "a composed request needs suffix tokens — the " \
                "prompt must end in fresh tokens for the first decode logit"
            crow = pool.compose(comp)            # pins segment blocks
            ids: List[int] = []
            pos: List[int] = []
            for off, toks in comp.fresh_spans():
                ids.extend(toks)
                pos.extend(range(off, off + len(toks)))
            ids.extend(sfx)
            pos.extend(range(comp.total_len, comp.total_len + len(sfx)))
            return dict(blocks=crow.blocks, offsets=crow.offsets,
                        skips=crow.skips, pinned=crow.pinned, ids=ids,
                        pos=pos, slot_off=pos[0] if ids else 0,
                        prompt_len=comp.total_len + len(sfx))
        st = req.prefix
        if st is None:
            return dict(blocks=[], offsets=[], skips=[], pinned=[],
                        ids=sfx, pos=list(range(len(sfx))), slot_off=0,
                        prompt_len=len(sfx))
        assert st.is_paged and st.block_pool is pool, \
            "paged serve needs page-table states from this engine"
        blocks = st.chain_blocks()
        pool.incref(blocks)
        plen = st.prefix_len
        return dict(blocks=blocks, offsets=[0] * len(blocks),
                    skips=[0] * len(blocks), pinned=blocks, ids=sfx,
                    pos=list(range(plen, plen + len(sfx))), slot_off=plen,
                    prompt_len=plen + len(sfx))

    # ------------------------------------------------------------------
    # drift scoring (DESIGN.md §15)
    # ------------------------------------------------------------------
    def _layer0_params(self):
        """Layer-0 parameters (ln1 + attention mixer) regardless of the
        stacked/unrolled parameter layout — the drift probe reads them
        to build exact layer-0 Q/K from token ids alone."""
        dec = self.params["dec"]
        if dec.get("groups"):
            return jax.tree.map(lambda x: x[0], dec["groups"]["0"])
        return dec["rest"][0]

    def drift_scores(self, comp: SegmentComposition,
                     probe_tokens: Sequence[int] = ()
                     ) -> List[List[float]]:
        """Per-segment per-block drift scores for a composition plan
        (the ``scorer`` argument of ``plan_composition``; DESIGN.md
        §15).

        The score of a composed key is the causal attention mass the
        plan's FRESH tokens (gap spans + the probe suffix — the query
        text) direct at it under layer-0 attention, weighted by the
        key's STALENESS prior.  Layer-0 Q/K are context-independent
        (embed → rms_norm → projection → RoPE), so the full composed
        key set is computable densely from token ids alone — no arena
        reads, exact even when cached blocks are int8.

        The staleness prior captures what the probe alone cannot: a
        spliced token's V is wrong in proportion to the attention its
        ORIGINAL prefill paid into the left context the splice
        replaced.  Token ``j`` of a segment prefilled behind
        ``base_pos`` tokens of old context had an attention window of
        ``base_pos + j + 1`` keys, ``base_pos`` of which are now gone —
        so its expected-staleness weight is
        ``base_pos / (base_pos + j + 1)``, largest at the splice's
        leading edge and decaying as intra-segment context dominates.
        The product (fresh attention INTO the key) x (how wrong the
        key's V is) is the expected contribution of that key to output
        error; the recompute budget is spent there.  Dispatch follows
        ``cfg.attention_impl``: the Pallas two-phase score kernel, or
        the dense oracle (``kernels/ref.py``)."""
        bs = self.block_size
        nb = lambda s: (len(s.tokens) + bs - 1) // bs
        toks = np.zeros(comp.total_len, np.int64)
        for s in comp.segments:
            toks[s.target_offset:s.target_offset + len(s.tokens)] = s.tokens
        for off, g in comp.gaps:
            toks[off:off + len(g)] = g
        probe = list(probe_tokens)
        full = (np.concatenate([toks, np.asarray(probe, np.int64)])
                if probe else toks)
        q_idx = [off + i for off, g in comp.gaps for i in range(len(g))]
        q_idx += list(range(comp.total_len, comp.total_len + len(probe)))
        q_idx.sort()
        if not q_idx:
            return [[0.0] * nb(s) for s in comp.segments]
        cfg = self.cfg
        p0 = self._layer0_params()
        mx = p0["mixer"]
        hd = cfg.head_dim_
        length = int(full.shape[0])
        h = M.embed_tokens(self.params, jnp.asarray(full, jnp.int32)[None])
        h = rms_norm(h, p0["ln1"], cfg.norm_eps)
        k = linear(h, mx["wk"])
        if "bk" in mx:
            k = k + mx["bk"]
        kpos = jnp.arange(length, dtype=jnp.int32)
        k = k.reshape(1, length, cfg.num_kv_heads, hd)
        k = apply_rope(k, kpos[None, :, None], cfg.rope_theta)
        k = k.transpose(0, 2, 1, 3)[0]               # [Hkv, L, hd]
        qi = jnp.asarray(q_idx, jnp.int32)
        hq = jnp.take(h, qi, axis=1)
        q = linear(hq, mx["wq"])
        if "bq" in mx:
            q = q + mx["bq"]
        q = q.reshape(1, len(q_idx), cfg.num_heads, hd).transpose(0, 2, 1, 3)
        q = apply_rope(q, qi[None, None, :], cfg.rope_theta)[0]  # [Hq,Tq,hd]
        if cfg.attention_impl == "pallas":
            mass = drift_probe(q, k, qi, kpos, block_k=bs)
        else:
            mass = drift_mass_ref(q, k, qi, kpos)
        mass = np.asarray(jax.block_until_ready(mass))
        out = []
        for s in comp.segments:
            seg = mass[s.target_offset:s.target_offset + len(s.tokens)]
            j = np.arange(len(s.tokens), dtype=np.float64)
            stale = s.state.base_pos / (s.state.base_pos + j + 1.0)
            seg = seg * stale
            out.append([float(seg[b * bs:(b + 1) * bs].sum())
                        for b in range(nb(s))])
        return out

    def _capture_gaps(self, requests: Sequence[Request],
                      plans: Sequence[dict], suffix_rows,
                      src=None) -> None:
        """Register freshly prefilled composition gap spans as
        content-addressed prefix segments (DESIGN.md §15): each
        ``gap_parts`` sub-span at least ``gap_min_tokens`` long is
        repacked from the row's suffix blocks into new prefix blocks
        (``KVBlockPool.cache_span``) and offered to ``gap_admit``.
        Runs while the suffix blocks are still live — before the
        serve's ``finally`` frees them.  ``src`` overrides the arena
        the spans are gathered from (the continuous path's sub-arena,
        where ``suffix_rows`` are then slot-row indices).  Capture is
        opportunistic: an arena shortage skips the span, never fails
        the serve."""
        pool = self.block_pool
        for i, (r, p) in enumerate(zip(requests, plans)):
            comp = r.composition
            if comp is None or not comp.gap_parts:
                continue
            for off, gtoks in comp.gap_parts:
                if len(gtoks) < self.gap_min_tokens:
                    continue
                start = off - p["slot_off"]
                assert start >= 0, (off, p["slot_off"])
                try:
                    bids = pool.cache_span(suffix_rows[i], start,
                                           len(gtoks), src=src)
                except OutOfBlocks:
                    continue
                state = PrefixState(
                    cache=None, prefix_len=off + len(gtoks),
                    capacity=self._prefix_capacity_for(off + len(gtoks)),
                    page=PageTable(blocks=bids, length=len(gtoks)),
                    block_pool=pool, seg_len=len(gtoks))
                if self.gap_admit(tuple(gtoks), state):
                    self.cache_mgr.stats.record_gap_cached(len(gtoks))
                else:
                    state.release()          # duplicate / declined

    def _serve_composed(self, requests: Sequence[Request]
                        ) -> Tuple[List[List[int]], dict]:
        """Serve a batch containing composition plans (DESIGN.md §14).

        Differs from ``_serve_paged`` in three ways: the prefix tables
        carry per-block position offsets and leading-slot skips; the
        prefill computes a NON-CONTIGUOUS fresh stream (gap spans +
        boundary recompute windows + the suffix) at explicit absolute
        positions; and each row's suffix table anchors at its first
        fresh position (``slot_off``) so fresh KV and the decode tail
        share one table — blocks spanning cached holes are allocated
        and unused, the price of a uniform slot mapping.  Chain and
        prefixless rows ride along as degenerate plans."""
        pool = self.block_pool
        n = len(requests)
        b = bucket_pow2(n)
        t0 = time.perf_counter()
        plans: List[dict] = []
        flat: Optional[List[int]] = None
        try:
            for r in requests:
                plans.append(self._row_plan(r))
            pad = dict(blocks=[], offsets=[], skips=[], pinned=[],
                       ids=[EOS], pos=[0], slot_off=0, prompt_len=1)
            plans += [pad] * (b - n)                 # batch padding rows
            nbp = bucket_pow2(max(1, max(len(p["blocks"])
                                         for p in plans)))
            prow = np.full((b, nbp), NULL_BLOCK, np.int32)
            poff = np.zeros((b, nbp), np.int32)
            pskip = np.zeros((b, nbp), np.int32)
            for i, p in enumerate(plans):
                w = len(p["blocks"])
                prow[i, :w] = p["blocks"]
                poff[i, :w] = p["offsets"]
                pskip[i, :w] = p["skips"]
            lens = np.asarray([len(p["ids"]) for p in plans], np.int32)
            t_pad = bucket_len(int(lens.max()), self.bucket)
            ids = np.full((b, t_pad), PAD, np.int32)
            pos = np.zeros((b, t_pad), np.int32)
            valid = np.zeros((b, t_pad), bool)
            for i, p in enumerate(plans):
                ids[i, :lens[i]] = p["ids"]
                pos[i, :lens[i]] = p["pos"]
                valid[i, :lens[i]] = True
            embeds = M.embed_tokens(self.params, jnp.asarray(ids))
            offs = np.asarray([p["slot_off"] for p in plans], np.int32)
            # suffix tables span [slot_off, prompt_end + decode tail]
            # per row; width is the batch max (holes over cached spans
            # stay unwritten)
            need = max(int(p["prompt_len"]) - int(p["slot_off"])
                       for p in plans)
            suffix_cap = self._suffix_capacity_for(need)
            nbs = blocks_for(suffix_cap, self.block_size)
            flat = pool.alloc_suffix(b * nbs)
            suffix_rows = np.asarray(flat, np.int32).reshape(b, nbs)
            for i in range(b):
                pool.note_tokens(suffix_rows[i], int(lens[i]), suffix=True)
            self.cache_mgr.stats.record_blocks(pool)
            prowj = jnp.asarray(prow)
            poffj = jnp.asarray(poff)
            pskipj = jnp.asarray(pskip)
            srow = jnp.asarray(suffix_rows)
            offj = jnp.asarray(offs)
            prefill = self._prefill_jit(b, t_pad)
            arena, logits, _ = self._with_arena(
                lambda a: prefill(self.params, embeds, jnp.asarray(pos),
                                  jnp.asarray(valid), a, pool.qarena,
                                  offj, prowj, srow, poffj, pskipj))
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            jax.block_until_ready(first)
            t_prefill = time.perf_counter() - t0

            t0 = time.perf_counter()
            lengths = jnp.asarray([p["prompt_len"] for p in plans],
                                  jnp.int32)
            decode = self._decode_jit(b)
            sub = pool.extract(flat)
            sub_pages = jnp.arange(b * nbs, dtype=jnp.int32).reshape(b, nbs)
            out, _ = decode(self.params, first, lengths, sub,
                            pool.prefix_source(), offj, prowj, sub_pages,
                            poffj, pskipj)
            out = np.asarray(jax.block_until_ready(out))
            t_decode = time.perf_counter() - t0
            for i in range(b):
                row = out[i].tolist()
                gen = (row.index(EOS) + 1 if EOS in row else len(row))
                pool.note_tokens(suffix_rows[i], int(lens[i]) + gen,
                                 suffix=True)
            if self.gap_admit is not None:
                self._capture_gaps(requests, plans, suffix_rows)
            self.cache_mgr.stats.record_blocks(pool)
        finally:
            if flat is not None:
                pool.decref(flat, suffix=True)
            for p in plans:
                if p["pinned"]:
                    pool.decref(p["pinned"])
        self.cache_mgr.stats.record_blocks(pool)
        toks = [self._cut(out[i]) for i in range(n)]
        return toks, {"prefill_s": t_prefill, "decode_s": t_decode,
                      "batch": b, "split_prefix": True, "paged": True,
                      "composed": True,
                      "num_prefixes": sum(
                          1 for p in plans if p["pinned"]),
                      "prefill_share": [t_prefill / n] * n,
                      "decode_share": [t_decode / n] * n}

    def _with_arena(self, fn):
        """Run a jitted call that consumes the (donated) block arena and
        returns the updated arena as its FIRST output; re-home it on
        ``block_pool`` even when the call raises.  Donation is
        best-effort (on CPU the buffer survives un-donated), so
        restoring the input handle on failure keeps the engine
        servable — a None arena would brick every later paged call."""
        pool = self.block_pool
        arena_in, pool.arena = pool.arena, None
        try:
            out = fn(arena_in)
        except BaseException:
            pool.arena = arena_in
            raise
        pool.arena = out[0]
        return out

    # ------------------------------------------------------------------
    # dense fallback backend
    # ------------------------------------------------------------------
    def _serve_dense(self, requests: Sequence[Request]
                     ) -> Tuple[List[List[int]], dict]:
        """Group rows by prefix state and serve each group through the
        dense cascade / broadcast fallback (stateful and cross-attention
        stacks, or ``paged=False`` engines).  Per-member shares come
        from each member's own sub-batch."""
        m = len(requests)
        groups: dict = {}
        for i, r in enumerate(requests):
            # prefixless rows form their own group and take the
            # no-prefix path — the paged backend serves them fine, so
            # the stateful / cross-attn fallback must too (callers
            # never branch on architecture)
            uid = r.prefix.uid if r.prefix is not None else None
            groups.setdefault(uid, (r.prefix, []))[1].append(i)
        outs: List = [None] * m
        agg = {"prefill_s": 0.0, "decode_s": 0.0, "batch": 0,
               "split_prefix": False, "paged": False,
               "num_prefixes": sum(1 for k in groups if k is not None),
               "prefill_share": [0.0] * m, "decode_share": [0.0] * m}
        for state, idxs in groups.values():
            sub, t = self._serve_with_prefix(
                state, [requests[i].suffix_tokens for i in idxs])
            for j, i in enumerate(idxs):
                outs[i] = sub[j]
                agg["prefill_share"][i] = t["prefill_share"][j]
                agg["decode_share"][i] = t["decode_share"][j]
            agg["prefill_s"] += t["prefill_s"]
            agg["decode_s"] += t["decode_s"]
            agg["batch"] = max(agg["batch"], t["batch"])
            agg["split_prefix"] = agg["split_prefix"] or t["split_prefix"]
        return outs, agg

    def _serve_with_prefix(self, state: Optional[PrefixState],
                           suffix_token_lists: Sequence[List[int]]
                           ) -> Tuple[List[List[int]], dict]:
        """Serve one prefix group (``state=None`` = the prefixless
        group: rows attend nothing but their own tokens, exactly like
        ``generate`` but batched)."""
        if self._stateful:
            groups = {}
            for i, tkl in enumerate(suffix_token_lists):
                groups.setdefault(len(tkl), []).append(i)
            if len(groups) > 1:
                m = len(suffix_token_lists)
                outs = [None] * m
                agg = {"prefill_s": 0.0, "decode_s": 0.0, "batch": 0,
                       "split_prefix": False,
                       "prefill_share": [0.0] * m,
                       "decode_share": [0.0] * m}
                for length, idxs in sorted(groups.items()):
                    sub, t = self._serve_with_prefix(
                        state, [suffix_token_lists[i] for i in idxs])
                    # per-member attribution: each member pays its OWN
                    # sub-batch's share — dividing the summed time by m
                    # would bill short-suffix members for long ones
                    for j, i in enumerate(idxs):
                        outs[i] = sub[j]
                        agg["prefill_share"][i] = t["prefill_share"][j]
                        agg["decode_share"][i] = t["decode_share"][j]
                    agg["prefill_s"] += t["prefill_s"]
                    agg["decode_s"] += t["decode_s"]
                    agg["batch"] = max(agg["batch"], t["batch"])
                return outs, agg
        n = len(suffix_token_lists)
        b = bucket_pow2(n)
        pads = [list(t) for t in suffix_token_lists] + \
               [[EOS]] * (b - n)                        # batch padding rows
        plen = state.prefix_len if state is not None else 0
        use_split = (state is not None and self.use_split_prefix
                     and state.enc_len == 0)
        t0 = time.perf_counter()
        pad_to = len(suffix_token_lists[0]) if self._stateful else None
        if self._stateful:
            pads = [list(t)[:pad_to] + [EOS] * (pad_to - len(t))
                    if len(t) < pad_to else list(t) for t in pads]
        embeds, positions, valid, lens = self._embed_padded(
            pads, None, plen, pad_to=pad_to)
        if use_split:
            # Split cascade: B members cost prefix_capacity + B×suffix
            # slots of HBM; the prefix KV is attended in place.  A chain
            # state passes its segments as a TUPLE of batch-1 caches —
            # one partial per segment, folded by the N-way LSE cascade
            # (DESIGN.md §10).
            cache = M.init_suffix_cache(
                self.cfg, b, self._suffix_capacity_for(embeds.shape[1]))
            prefix = (tuple(s.cache for s in state.chain())
                      if state.parent is not None else state.cache)
            offset = jnp.int32(state.prefix_len)
        elif state is None:
            # no-prefix path: a fresh cache sized for suffix + decode;
            # the row's own tokens are the whole sequence
            cache = M.init_cache(
                self.cfg, b, self._suffix_capacity_for(embeds.shape[1]))
            prefix, offset = None, 0
        else:
            assert state.parent is None, \
                "chain states require the split cascade (broadcast " \
                "would replicate only the leaf segment)"
            template = jax.eval_shape(
                lambda: M.init_cache(self.cfg, b, state.capacity,
                                     enc_len=state.enc_len))
            cache = state.broadcast(template)
            prefix, offset = None, 0
        prefill = self._prefill_jit(b, embeds.shape[1])
        cache, logits, _ = prefill(self.params, embeds, positions, valid,
                                   cache, prefix, offset, None, None)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(first)
        t_prefill = time.perf_counter() - t0

        t0 = time.perf_counter()
        lengths = jnp.asarray(plen + lens, jnp.int32)
        decode = self._decode_jit(b)
        out, _ = decode(self.params, first, lengths, cache, prefix, offset,
                        None, None)
        out = np.asarray(jax.block_until_ready(out))
        t_decode = time.perf_counter() - t0
        toks = [self._cut(out[i]) for i in range(n)]
        return toks, {"prefill_s": t_prefill, "decode_s": t_decode,
                      "batch": b, "split_prefix": use_split,
                      "prefill_share": [t_prefill / n] * n,
                      "decode_share": [t_decode / n] * n}

    # ------------------------------------------------------------------
    # baseline path
    # ------------------------------------------------------------------
    def generate(self, prompt_tokens: List[int],
                 soft: Optional[np.ndarray] = None
                 ) -> Tuple[List[int], dict]:
        """Vanilla single-query generation (the paper's baseline)."""
        t0 = time.perf_counter()
        embeds, positions, valid, lens = self._embed_padded(
            [prompt_tokens], soft, 0,
            pad_to=None if not self._stateful else
            len(prompt_tokens) + (0 if soft is None else soft.shape[0]))
        cache = M.init_cache(self.cfg, 1,
                             self._capacity_for(int(lens[0]),
                                                suffix_headroom=0))
        prefill = self._prefill_jit(1, embeds.shape[1])
        cache, logits, _ = prefill(self.params, embeds, positions, valid,
                                   cache, None, 0, None, None)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(first)
        t_prefill = time.perf_counter() - t0

        t0 = time.perf_counter()
        decode = self._decode_jit(1)
        out, _ = decode(self.params, first, jnp.asarray(lens, jnp.int32),
                        cache, None, 0, None, None)
        out = np.asarray(jax.block_until_ready(out))
        t_decode = time.perf_counter() - t0
        return self._cut(out[0]), {"prefill_s": t_prefill,
                                   "decode_s": t_decode}

    def _cut(self, ids: np.ndarray) -> List[int]:
        out = []
        for t in ids.tolist():
            if t == EOS:
                break
            out.append(int(t))
        return out

    # ------------------------------------------------------------------
    # warmup (pre-compile shape buckets; excluded from timings)
    # ------------------------------------------------------------------
    def warmup(self, suffix_len: int = 32, batches: Sequence[int] = (1,)):
        """Pre-compile the common shape buckets (excluded from timings).
        Warmup traffic is not real serving: keep it out of CacheStats."""
        for b in batches:
            dummy = [[EOS] * suffix_len for _ in range(b)]
            if b == 1:
                self.generate(dummy[0])
            else:
                st, _ = self.prefill_prefix([EOS] * suffix_len,
                                            _record=False)
                self.generate_with_prefix(st, dummy, _record=False)
                st.release()             # warmup must not hold arena blocks

    def warmup_pooled(self, prefix_len, suffix_len: int = 32,
                      batches: Sequence[int] = (1, 2, 4),
                      num_prefixes: Sequence[int] = (1, 2, 4)):
        """Pre-compile the multi-prefix (batch, page-width) bucket grid
        for online serving: micro-batch composition depends on arrival
        dynamics, so an online trace can touch any combination of
        member-batch and prefix-count buckets at any moment — compile
        them up front so no trace lands in a timed region.

        ``prefix_len`` — an int, or a sequence of ints covering the
        representative lengths the trace will serve.  On the paged
        backend each DISTINCT page-table width bucket
        (``bucket_pow2(ceil(P / block_size))``) is its own compiled
        shape, so pass one length per width bucket the traffic spans
        (a single max length only compiles the widest tables).  Not
        recorded; paged states are released afterwards."""
        plens = ([prefix_len] if isinstance(prefix_len, int)
                 else list(prefix_len))
        if self.use_paged:
            # one representative per distinct page-width bucket
            seen, keep = set(), []
            for p in sorted(plens):
                w = bucket_pow2(blocks_for(p, self.block_size))
                if w not in seen:
                    seen.add(w)
                    keep.append(p)
            plens = keep
        for plen in plens:
            states = []
            for _ in range(max(num_prefixes)):
                st, _ = self.prefill_prefix([EOS] * plen, _record=False)
                states.append(st)
            try:
                for np_ in num_prefixes:
                    for b in batches:
                        dummy = [[EOS] * suffix_len for _ in range(b)]
                        pids = [i % np_ for i in range(b)]
                        self.generate_multi_prefix(states[:np_], pids,
                                                   dummy, _record=False)
            finally:
                for st in states:
                    st.release()
